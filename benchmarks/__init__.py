"""Benchmark harness — one module per paper table/figure (dpBento §5–§8).

Each bench module declares a measurement BOX (the paper's declarative job
description) and is executed by ``benchmarks.run`` through the framework's
Runner, exactly the workflow of paper Fig. 3. Results land in
``results/bench/<figure>.csv`` and a combined CSV goes to stdout.
"""
