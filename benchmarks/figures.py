"""Per-figure measurement boxes (paper Figs. 4–15 → our TPU-adapted tasks).

Every entry is a plain dict in the box JSON schema — the same text a user
would put in a ``.json`` file — so the harness exercises the declarative
path end-to-end. Parameter lists here are trimmed for CPU wall-clock sanity
(the full spaces live in each task's ``param_space`` and can be swept with
``python -m repro.core.runner <box.json>``).
"""
from __future__ import annotations

# fig id -> box dict. Order matters: run.py executes in this order.
FIGURES: dict[str, dict] = {
    # ---- §5.1 compute: primitive arithmetic (Fig. 4) ----------------------
    "fig4_arithmetic": {
        "name": "fig4_arithmetic",
        "tasks": [
            {
                "task": "compute",
                "params": {
                    "data_type": ["int8", "int32", "bfloat16", "float32"],
                    "operation": ["add", "sub", "mul", "div", "matmul"],
                },
                "metrics": ["ops_per_s", "min_latency_us"],
            }
        ],
    },
    # ---- §5.1 compute: string ops (Fig. 5) ---------------------------------
    "fig5_strings": {
        "name": "fig5_strings",
        "tasks": [
            {
                "task": "strings",
                "params": {
                    "width": ["str10", "str64", "str256", "str1024"],
                    "operation": ["cmp", "cat", "xfrm"],
                },
                "metrics": ["ops_per_s"],
            }
        ],
    },
    # ---- §5.2 hardware acceleration (Fig. 6) -------------------------------
    # DPU ASIC accelerators → Pallas/MXU kernels vs plain jnp ("SIMD on CPU"),
    # plus int8 quantization as the compression analogue.
    "fig6_accelerators": {
        "name": "fig6_accelerators",
        "tasks": [
            {
                "task": "pallas_accel",
                "params": {
                    "workload": ["attention", "gmm", "filter_agg"],
                    "size": ["small", "medium", "large"],
                    "impl": ["kernel", "jnp"],
                },
                "metrics": ["ops_per_s", "avg_latency_us"],
            },
            {
                "task": "quantize",
                "params": {
                    "operation": ["quantize", "dequantize", "roundtrip"],
                    "payload": ["64KB", "1MB", "16MB"],
                },
                "metrics": ["bandwidth_gb_s", "avg_latency_us"],
            },
        ],
    },
    # ---- §5.3 memory (Figs. 7 + 8) ------------------------------------------
    "fig7_memory": {
        "name": "fig7_memory",
        "tasks": [
            {
                "task": "memory",
                "params": {
                    "object_size": ["16KB", "4MB", "1GB"],
                    "pattern": ["sequential", "random"],
                    "operation": ["read", "write"],
                    "lanes": [1],
                },
                "metrics": ["ops_per_s", "bandwidth_gb_s"],
            }
        ],
    },
    "fig8_memory_scaling": {
        "name": "fig8_memory_scaling",
        "tasks": [
            {
                "task": "memory",
                "params": {
                    "object_size": ["16KB"],
                    "pattern": ["random"],
                    "operation": ["read"],
                    "lanes": [1, 4, 16],
                },
                "metrics": ["ops_per_s"],
            }
        ],
    },
    # ---- §6.1 storage (Figs. 9 + 10) ----------------------------------------
    "fig9_storage_throughput": {
        "name": "fig9_storage_throughput",
        "tasks": [
            {
                "task": "storage",
                "params": {
                    "io_type": ["h2d", "d2h", "ckpt_write", "ckpt_read"],
                    "access_size": ["256KB", "4MB", "64MB"],
                    "depth": [4],
                },
                "metrics": ["bandwidth_gb_s"],
            }
        ],
    },
    "fig10_storage_latency": {
        "name": "fig10_storage_latency",
        "tasks": [
            {
                "task": "storage",
                "params": {
                    "io_type": ["h2d", "d2h", "ckpt_write", "ckpt_read"],
                    "access_size": ["8KB", "4MB"],
                    "depth": [1],
                },
                "metrics": ["avg_latency_us", "p99_latency_us"],
            }
        ],
    },
    # ---- §6.2 network (Figs. 11 + 12) ---------------------------------------
    # TCP stack → default XLA collective schedule; RDMA → hand shard_map.
    "fig11_network_xla": {
        "name": "fig11_network_xla",
        "tasks": [
            {
                "task": "network",
                "params": {
                    "collective": ["all_reduce", "all_gather", "ppermute"],
                    "payload": ["32KB", "1MB", "32MB"],
                    "schedule": ["xla"],
                },
                "metrics": ["bandwidth_gb_s", "avg_latency_us", "p99_latency_us"],
            }
        ],
    },
    "fig12_network_shardmap": {
        "name": "fig12_network_shardmap",
        "tasks": [
            {
                "task": "network",
                "params": {
                    "collective": ["all_reduce", "all_gather", "ppermute"],
                    "payload": ["32KB", "1MB", "32MB"],
                    "schedule": ["shardmap"],
                },
                "metrics": ["bandwidth_gb_s", "avg_latency_us", "p99_latency_us"],
            }
        ],
    },
    # ---- §7.1 predicate pushdown (Fig. 13) ----------------------------------
    "fig13_pushdown": {
        "name": "fig13_pushdown",
        "tasks": [
            {
                "task": "pushdown",
                "params": {
                    "scale": ["0.01", "0.1"],
                    "selectivity": [0.01, 0.1, 0.5],
                    "plan": ["baseline", "pushdown", "pushdown_kernel"],
                },
                "metrics": ["items_per_s"],
            },
            # Fused-vs-unfused comparison rows: the same pushdown plan with
            # compaction routed through the block_compact kernel (impl of
            # the rows above defaults to the unfused jnp nonzero+gather).
            # Scale 1.0 runs here too: the HBM-streaming compaction path
            # lifts the old VMEM bound on the kernel rows' capacity.
            {
                "task": "pushdown",
                "params": {
                    "scale": ["0.01", "0.1", "1.0"],
                    "selectivity": [0.01, 0.1, 0.5],
                    "plan": ["pushdown"],
                    "impl": ["kernel"],
                },
                "metrics": ["items_per_s"],
            },
        ],
    },
    # ---- §7.2 index offloading (Fig. 14) ------------------------------------
    "fig14_index": {
        "name": "fig14_index",
        "tasks": [
            {
                "task": "index_offload",
                "params": {
                    "scale": ["1M"],
                    "operation": ["read", "write"],
                    "pattern": ["uniform", "skewed"],
                    "split_ratio": [0.0, 0.1, 0.3],
                    "lanes": [1],
                },
                "metrics": ["ops_per_s"],
            }
        ],
    },
    # ---- §8 full system (Fig. 15) -------------------------------------------
    "fig15_dbms": {
        "name": "fig15_dbms",
        "tasks": [
            # impl sweeps the execution plan: unfused jnp graph vs the
            # single-pass fused group_filter_agg kernel plan.
            {
                "task": "dbms",
                "params": {
                    "scale": ["0.001", "0.01", "0.1"],
                    "query": ["q1", "q6", "q12"],
                    "mode": ["cold", "hot"],
                    "impl": ["unfused", "fused"],
                },
                "metrics": ["avg_latency_us", "items_per_s"],
            },
            {
                "task": "app_step",
                "params": {
                    "arch": ["olmo-1b", "mamba2-2.7b", "kimi-k2-1t-a32b"],
                    "kind": ["train", "decode"],
                    "mode": ["hot"],
                },
                "metrics": ["avg_latency_us", "items_per_s"],
            },
        ],
    },
    # ---- serving: tail latency under open-loop load (ROADMAP item 1) --------
    # Per (query, batching) point: p50/p99 request latency (queueing
    # included), delivered QPS at the offered rate, and the closed-loop
    # saturation ceiling.  batching=true coalesces concurrent same-shape
    # requests into one scan-shared kernel pass.
    "fig16_serving": {
        "name": "fig16_serving",
        "tasks": [
            {
                "task": "serving",
                "params": {
                    "scale": ["0.001"],
                    "query": ["q1", "q6", "q12"],
                    "rate": [50.0],
                    "arrival": ["poisson"],
                    "batching": [True, False],
                    "duration": [0.5],
                    "queue_depth": [64],
                    "seed": [0],
                },
                "metrics": [
                    "p50_latency_us",
                    "p99_latency_us",
                    "qps",
                    "saturation_qps",
                    "shed_requests",
                ],
            }
        ],
    },
}
