"""Fleet soak: elastic-fleet correctness under sustained random faults.

The elastic-fleet layer's acceptance bar, run as a benchmark so CI pins it
per commit:

  1. **Baseline** — run the box sequentially (no fleet) for the reference
     report every later phase must byte-match.
  2. **Hang bound** — seed per-unit cost evidence with one clean fleet
     pass, then inject a 300 s ``hang`` fault (worker accepts the unit,
     never replies, keeps heartbeating — the worst case: membership can't
     see it) and time the pass.  The overhead over a clean pass must stay
     under :data:`HANG_BOUND_S`; before layered deadlines this was a 600 s
     socket-timeout wait.
  3. **Soak** — a :class:`repro.core.faults.FaultyFleet` of N registered
     loopback workers takes a seeded random fault (kill / hang / slow /
     partial) roughly every ``--fault-period`` seconds for ``--duration``
     seconds while sweep passes run back-to-back.  Killed workers respawn
     on fresh ports mid-pass, so the run exercises *leave* and *join*
     membership churn, not just failure.  Every pass's report is
     byte-diffed against the baseline; any divergence or task error fails
     the benchmark.

  4. **Control plane** — a 3-replica :class:`RegistryReplicas` membership
     plane serves discovery while passes run: kill+restart cycles on 0, 1,
     and 2 replicas, a full blackout (all 3 down, restarted EMPTY — they
     must re-converge from worker re-admission), then seeded worker chaos
     combined with seeded :class:`RegistryChaos`.  Every pass must stay
     byte-identical with ZERO re-dispatches attributable to the registry
     outages: losing the control plane defers joins/leaves, it never
     un-schedules placed work.

Results land in a BENCH JSON (``--out``); the control-plane phase also
writes its own report (``--control-out``, the BENCH_10 artifact: wall time
+ re-dispatch counts per replica-kill count, byte-diffed against baseline).

Usage: python -m benchmarks.fleet_soak [--out BENCH_7.json] [--workers 4]
       [--duration 60] [--seed 7] [--fault-period 1.0]
       [--control-out BENCH_10.json] [--control-duration 15]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from collections import Counter
from pathlib import Path

import threading

from repro.core import registry as reg
from repro.core.box import Box
from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor
from repro.core.faults import (
    FaultSpec,
    FaultyFleet,
    RegistryChaos,
    RegistryReplicas,
    inject,
)
from repro.core.remote import LocalWorker, wait_members
from repro.runtime.membership import MembershipRegistry, MembershipServer

#: Max extra seconds a hung worker may cost a pass (acceptance: seconds,
#: never the 600 s request timeout).
HANG_BOUND_S = 10.0

#: Heartbeat period for soak fleets: fast enough that kill detection is
#: bounded by ~3 x this, slow enough to not dominate loopback traffic.
BEAT_S = 0.5


def _make_plugin(root: Path, name: str) -> Path:
    """Deterministic directory-plugin task: metrics are pure functions of
    params, so reports are byte-comparable no matter which worker ran what."""
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "task.json").write_text(
        json.dumps(
            {
                "name": name,
                "param_space": {"a": [1, 2, 3, 4, 5, 6], "b": ["x", "y", "z"]},
                "metrics": ["avg_latency_us", "ops_per_s"],
            }
        )
    )
    (d / "run.py").write_text(
        # The real sleep stretches each pass to ~1 s so injected faults land
        # MID-pass (the interesting case); reported metrics stay pure
        # functions of params, so reports are byte-comparable regardless.
        "import time\n"
        "def main(ctx, params):\n"
        "    time.sleep(0.03 * params['a'])\n"
        "    t = 1e-4 * params['a'] * {'x': 1, 'y': 2, 'z': 3}[params['b']]\n"
        "    return {'times_s': [t, 2 * t], 'ops_per_iter': 100.0}\n"
    )
    return d


def _box(name: str) -> Box:
    return Box.from_dict(
        {
            "name": f"{name}_box",
            "tasks": [
                {"task": name, "params": {"a": [1, 2, 3, 4, 5, 6], "b": ["x", "y", "z"]}}
            ],
        }
    )


def _fleet_executor(
    registry_endpoint: str, cache: ResultCache, workers: int, transport: str = "async"
) -> SweepExecutor:
    return SweepExecutor(
        platforms=["cpu-host"],
        workers=workers,
        iters=1,
        warmup=0,
        fleet_registry=registry_endpoint,
        cache=cache,
        transport=transport,
    )


def phase_hang_bound(
    plugin: Path, box: Box, baseline_csv: str, tmp: Path, transport: str
) -> dict:
    """Measure the pass-time overhead of one wedged worker."""
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=BEAT_S)
    )
    srv.serve_in_thread()
    workers = [
        LocalWorker(
            plugin_dirs=[plugin], register=srv.endpoint,
            heartbeat_interval_s=BEAT_S, allow_faults=True,
        ).__enter__()
        for _ in range(2)
    ]
    try:
        wait_members(srv.endpoint, count=2, timeout=60)
        cache = ResultCache(tmp / "hang-cache.json", max_entries=0)
        ex = _fleet_executor(srv.endpoint, cache, workers=2, transport=transport)

        t0 = time.monotonic()
        clean = ex.run_box(box)  # also seeds the costs sidecar -> deadlines
        clean_s = time.monotonic() - t0
        assert clean.csv() == baseline_csv, "clean fleet pass diverged from baseline"
        cache.clear()

        inject(workers[0].endpoint, FaultSpec("hang", seconds=300))
        t0 = time.monotonic()
        faulted = ex.run_box(box)
        hang_s = time.monotonic() - t0
        assert faulted.stats.errors == 0, f"hang pass had {faulted.stats.errors} errors"
        assert faulted.csv() == baseline_csv, "hang pass diverged from baseline"
        overhead = hang_s - clean_s
        assert overhead < HANG_BOUND_S, (
            f"hang detection took {overhead:.1f}s over the {clean_s:.1f}s clean "
            f"pass — bound is {HANG_BOUND_S}s"
        )
        return {
            "clean_pass_s": round(clean_s, 3),
            "hang_pass_s": round(hang_s, 3),
            "hang_overhead_s": round(overhead, 3),
            "bound_s": HANG_BOUND_S,
            "redispatched": faulted.stats.redispatched,
        }
    finally:
        for w in workers:
            w.__exit__(None, None, None)
        srv.shutdown()
        srv.server_close()


def phase_soak(
    plugin: Path,
    box: Box,
    baseline_csv: str,
    tmp: Path,
    size: int,
    duration_s: float,
    seed: int,
    fault_period_s: float,
    transport: str,
) -> dict:
    """Back-to-back sweep passes under seeded random fleet chaos."""
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=BEAT_S)
    )
    srv.serve_in_thread()
    try:
        with FaultyFleet(
            size, register=srv.endpoint, plugin_dirs=[plugin], seed=seed,
            heartbeat_interval_s=BEAT_S,
        ) as fleet:
            cache = ResultCache(tmp / "soak-cache.json", max_entries=0)
            ex = _fleet_executor(srv.endpoint, cache, workers=size, transport=transport)
            ex.run_box(box)  # seed cost evidence before the chaos starts
            cache.clear()

            fleet.start(period_s=fault_period_s)
            passes = 0
            redispatched = blacklisted = speculated = 0
            t0 = time.monotonic()
            while time.monotonic() - t0 < duration_s or passes == 0:
                res = ex.run_box(box)
                assert res.stats.errors == 0, (
                    f"pass {passes} had {res.stats.errors} task errors"
                )
                assert res.csv() == baseline_csv, (
                    f"pass {passes} report diverged from the fault-free baseline"
                )
                redispatched += res.stats.redispatched
                blacklisted += res.stats.blacklisted
                speculated += res.stats.speculated
                passes += 1
                cache.clear()
            elapsed = time.monotonic() - t0
            events = fleet.stop()
        by_mode = Counter(e.spec.mode for e in events)
        return {
            "workers": size,
            "seed": seed,
            "duration_s": round(elapsed, 1),
            "passes": passes,
            "faults_injected": len(events),
            "faults_by_mode": dict(sorted(by_mode.items())),
            "respawns": fleet.respawns,
            "redispatched": redispatched,
            "speculated": speculated,
            "blacklisted": blacklisted,
            "identical": True,
        }
    finally:
        srv.shutdown()
        srv.server_close()


def phase_control_plane(
    plugin: Path,
    box: Box,
    baseline_csv: str,
    tmp: Path,
    size: int,
    seed: int,
    chaos_duration_s: float,
    transport: str,
    passes_per_case: int = 3,
) -> dict:
    """Sweep passes while the REGISTRY replicas (not the workers) misbehave.

    A 3-replica plane serves membership while a disruptor thread cycles
    kill+restart on 0, 1, and 2 replicas mid-pass, then a full blackout
    (all 3 down at once, restarted empty), then seeded worker chaos AND
    seeded registry chaos together.  The invariant everywhere: reports stay
    byte-identical to the fault-free baseline with zero re-dispatches
    attributable to the control plane — losing registries defers
    joins/leaves, it never un-schedules work already placed on sinks.
    """
    REPLICAS = 3
    with RegistryReplicas(REPLICAS, heartbeat_interval_s=BEAT_S) as plane:
        with FaultyFleet(
            size, register=plane.register, plugin_dirs=[plugin], seed=seed,
            heartbeat_interval_s=BEAT_S,
        ) as fleet:
            cache = ResultCache(tmp / "control-cache.json", max_entries=0)
            ex = _fleet_executor(plane.register, cache, workers=size, transport=transport)
            ex.run_box(box)  # seed cost evidence
            cache.clear()

            def run_passes(n: int) -> dict:
                t0 = time.monotonic()
                redispatched = poll_failures = 0
                for i in range(n):
                    res = ex.run_box(box)
                    assert res.stats.errors == 0, (
                        f"pass {i} had {res.stats.errors} task errors"
                    )
                    assert res.csv() == baseline_csv, (
                        f"pass {i} report diverged from the fault-free baseline"
                    )
                    redispatched += res.stats.redispatched
                    poll_failures = max(
                        poll_failures, res.stats.registry_poll_failures
                    )
                    cache.clear()
                return {
                    "passes": n,
                    "wall_s": round(time.monotonic() - t0, 3),
                    "redispatched": redispatched,
                    "registry_poll_failures": poll_failures,
                }

            cases = []
            for kills in (0, 1, 2, REPLICAS):
                blackout = kills == REPLICAS
                stop = threading.Event()

                def disrupt(k=kills) -> None:
                    # Cycle: down for ~a suspect window, then back, repeat —
                    # every pass overlaps at least one kill or one recovery.
                    while not stop.is_set():
                        stop.wait(0.4)
                        if stop.is_set() or k == 0:
                            continue
                        for i in range(k):
                            plane.kill(i)
                        stop.wait(1.5)
                        for i in range(k):
                            plane.restart(i)

                t = threading.Thread(target=disrupt, daemon=True, name="registry-disruptor")
                t.start()
                try:
                    case = run_passes(passes_per_case)
                finally:
                    stop.set()
                    t.join(timeout=10.0)
                    for i in range(REPLICAS):
                        plane.repair(i)
                # Give the healed plane one settle window, then require the
                # full fleet visible again before the next case.
                wait_members(plane.register, count=size, timeout=60)
                assert case["redispatched"] == 0, (
                    f"{kills} replica kills caused {case['redispatched']} "
                    f"re-dispatches — registry loss must never un-schedule work"
                )
                case["kills"] = kills
                case["blackout"] = blackout
                cases.append(case)

            # Finale: worker chaos AND control-plane chaos, same seeds.
            chaos = RegistryChaos(plane, seed=seed, max_sleep_s=1.5, min_up=1)
            fleet.start(period_s=1.0)
            chaos.start(period_s=0.7)
            passes = 0
            redispatched = 0
            t0 = time.monotonic()
            while time.monotonic() - t0 < chaos_duration_s or passes == 0:
                res = ex.run_box(box)
                assert res.stats.errors == 0
                assert res.csv() == baseline_csv, (
                    f"chaos pass {passes} diverged from the fault-free baseline"
                )
                redispatched += res.stats.redispatched
                passes += 1
                cache.clear()
            worker_events = fleet.stop()
            registry_events = chaos.stop()
        return {
            "replicas": REPLICAS,
            "workers": size,
            "seed": seed,
            "kill_cases": cases,
            "chaos": {
                "duration_s": round(time.monotonic() - t0, 1),
                "passes": passes,
                "worker_faults": len(worker_events),
                "registry_faults": dict(
                    sorted(Counter(e.spec.mode for e in registry_events).items())
                ),
                "redispatched": redispatched,
                "identical": True,
            },
        }


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="benchmarks.fleet_soak", description="elastic-fleet fault-injection soak"
    )
    p.add_argument("--out", default=None, help="write BENCH JSON here")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--duration", type=float, default=60.0, metavar="SECONDS")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--fault-period", type=float, default=1.0, metavar="SECONDS")
    p.add_argument(
        "--transport", choices=("threaded", "async"), default="async",
        help="fleet sink wire strategy the soak drives (default: async)",
    )
    p.add_argument(
        "--control-out", default=None, metavar="PATH",
        help="also write the control-plane phase's own BENCH JSON here",
    )
    p.add_argument(
        "--control-duration", type=float, default=15.0, metavar="SECONDS",
        help="length of the combined worker+registry chaos sub-phase",
    )
    args = p.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="fleet-soak-") as tmpdir:
        tmp = Path(tmpdir)
        plugin = _make_plugin(tmp, "soak")
        reg.load_plugin_dir(plugin)
        box = _box("soak")

        print("# phase 1/4: sequential baseline", flush=True)
        baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
        assert baseline.stats.errors == 0
        baseline_csv = baseline.csv()

        print("# phase 2/4: hang detection bound", flush=True)
        hang = phase_hang_bound(plugin, box, baseline_csv, tmp, args.transport)
        print(
            f"#   clean={hang['clean_pass_s']}s hung={hang['hang_pass_s']}s "
            f"overhead={hang['hang_overhead_s']}s (bound {HANG_BOUND_S}s)",
            flush=True,
        )

        print(
            f"# phase 3/4: {args.duration:.0f}s soak, {args.workers} workers, "
            f"seed {args.seed}",
            flush=True,
        )
        soak = phase_soak(
            plugin, box, baseline_csv, tmp,
            size=args.workers, duration_s=args.duration,
            seed=args.seed, fault_period_s=args.fault_period,
            transport=args.transport,
        )
        print(
            f"#   {soak['passes']} passes, {soak['faults_injected']} faults "
            f"{soak['faults_by_mode']}, {soak['respawns']} respawns, "
            f"{soak['redispatched']} redispatches — all byte-identical",
            flush=True,
        )

        print(
            f"# phase 4/4: control-plane chaos (3 registry replicas, "
            f"{args.control_duration:.0f}s combined chaos)",
            flush=True,
        )
        control = phase_control_plane(
            plugin, box, baseline_csv, tmp,
            size=args.workers, seed=args.seed,
            chaos_duration_s=args.control_duration,
            transport=args.transport,
        )
        for case in control["kill_cases"]:
            print(
                f"#   kills={case['kills']}: {case['passes']} passes in "
                f"{case['wall_s']}s, {case['redispatched']} redispatches, "
                f"max dark-poll streak {case['registry_poll_failures']}",
                flush=True,
            )
        print(
            f"#   chaos: {control['chaos']['passes']} passes, "
            f"{control['chaos']['worker_faults']} worker faults + "
            f"{control['chaos']['registry_faults']} registry faults — "
            f"all byte-identical",
            flush=True,
        )

    bench = {
        "bench": "fleet_soak",
        "transport": args.transport,
        "units": box.total_tests(),
        "hang_bound": hang,
        "soak": soak,
        "control_plane": control,
    }
    text = json.dumps(bench, indent=1) + "\n"
    if args.out:
        Path(args.out).write_text(text)
    else:
        sys.stdout.write(text)
    if args.control_out:
        Path(args.control_out).write_text(
            json.dumps(
                {
                    "bench": "fleet_soak_control_plane",
                    "transport": args.transport,
                    "units": box.total_tests(),
                    **control,
                },
                indent=1,
            )
            + "\n"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
