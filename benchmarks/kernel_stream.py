"""Streaming-compaction perf smoke: cap sweep across the old VMEM ceiling.

The resident ``block_compact`` keeps its whole ``[C, cap + SUB]`` output in
VMEM, so its capacity tops out at :data:`repro.kernels.ops.VMEM_BUDGET_BYTES`
(~512K rows at 4 columns).  The streaming variant keeps the output in HBM
and emits tiles by double-buffered DMA — capacity becomes memory-bounded.
This job pins that claim per commit:

  1. **Correctness** — at every swept cap (below the ceiling, above it, and
     one >= 4M rows) the streamed output is byte-diffed against the
     ``nonzero(size=cap)`` oracle, including a cap far below the mask count
     (overflow clamping at scale).
  2. **No small-cap regression** — the ``stream="auto"`` dispatcher must be
     no slower than the resident kernel at caps under the ceiling (it
     routes to it, so this catches dispatch overhead).  The raw streaming
     kernel also gets a sanity floor against resident: on the CPU
     interpreter the widened carry-merge scatter costs ~2x the resident
     store trick, so the floor only flags collapse, not interpreter skew —
     on TPU the DMA overlap is the whole point.
  3. **Trajectory** — BENCH_9.json records rows_per_s per (cap, impl).

Usage: python -m benchmarks.kernel_stream [--out BENCH_9.json] [--n ROWS]
       [--iters N]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.timing import block
from repro.kernels import ops as kops
from repro.kernels.ref import block_compact_ref

C = 4
SELECTIVITY = 0.5

#: Fraction of resident throughput the auto dispatcher must reach at caps
#: below the VMEM ceiling (same kernel underneath; slack covers CI timer
#: jitter, which reaches ~15% between identical interpret-mode runs).
AUTO_FLOOR = 0.75
#: Interpreter-only sanity floor for the raw streaming kernel (see module
#: docstring) — catches collapse, not the expected ~2x scatter overhead.
STREAM_FLOOR = 0.25


def default_caps(n: int) -> list[int]:
    """Caps straddling the resident kernel's VMEM ceiling, plus >= 4M."""
    ceiling = kops.VMEM_BUDGET_BYTES // (C * 4)  # rows where resident tops out
    return [ceiling // 8, ceiling // 2, 2 * ceiling, max(4 * 1024 * 1024, 8 * ceiling)]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.kernel_stream")
    p.add_argument("--out", default="BENCH_9.json")
    p.add_argument("--n", type=int, default=1 << 21)
    p.add_argument("--iters", type=int, default=3)
    args = p.parse_args(argv)

    t0 = time.time()
    key = jax.random.PRNGKey(9)
    cols = jax.random.normal(key, (C, args.n), jnp.float32)
    mask = (
        jax.random.uniform(jax.random.fold_in(key, 1), (1, args.n)) < SELECTIVITY
    ).astype(jnp.int32)

    ceiling = kops.VMEM_BUDGET_BYTES // (C * 4)
    caps = default_caps(args.n)
    failures: list[str] = []
    entries: list[dict] = []
    rates: dict[tuple[int, str], float] = {}

    impls = (("resident", "never"), ("stream", "always"), ("auto", "auto"))
    for cap in caps:
        exp, ecnt = block_compact_ref(cols, mask, cap)
        fns = {
            impl: (lambda c, m, cap=cap, stream=stream:
                   kops.block_compact(c, m, cap, stream=stream))
            for impl, stream in impls
        }
        for impl, fn in fns.items():
            # Correctness byte-diff doubles as the compile warmup.
            out, cnt = fn(cols, mask)
            tag = f"cap={cap} impl={impl}"
            if int(cnt) != int(ecnt):
                failures.append(f"{tag}: count {int(cnt)} != oracle {int(ecnt)}")
            if not np.array_equal(np.asarray(out), np.asarray(exp)):
                bad = np.flatnonzero(
                    (np.asarray(out) != np.asarray(exp)).any(axis=0)
                )
                failures.append(f"{tag}: output differs at cols {bad[:8].tolist()}")
        # Interleave the timed iterations round-robin across impls: machine
        # drift (CI neighbors, thermal) then biases every impl equally
        # instead of landing wholesale on whichever ran last.
        times: dict[str, list[float]] = {impl: [] for impl in fns}
        for _ in range(max(1, args.iters)):
            for impl, fn in fns.items():
                ts = time.perf_counter()
                block(fn(cols, mask))
                times[impl].append(time.perf_counter() - ts)
        for impl in fns:
            rate = args.n / min(times[impl])
            rates[(cap, impl)] = rate
            entries.append(
                {"cap": cap, "impl": impl, "n": args.n,
                 "selectivity": SELECTIVITY, "rows_per_s": rate,
                 "above_vmem_ceiling": cap > ceiling}
            )
            print(f"# cap={cap} impl={impl}: {rate / 1e6:.1f}M rows/s "
                  f"({'above' if cap > ceiling else 'below'} ceiling)")

    for cap in caps:
        if cap > ceiling:
            continue
        auto_ratio = rates[(cap, "auto")] / rates[(cap, "resident")]
        if auto_ratio < AUTO_FLOOR:
            failures.append(
                f"cap={cap}: auto dispatch {auto_ratio:.2f}x of resident "
                f"(floor {AUTO_FLOOR})"
            )
        stream_ratio = rates[(cap, "stream")] / rates[(cap, "resident")]
        if stream_ratio < STREAM_FLOOR:
            failures.append(
                f"cap={cap}: raw stream collapsed to {stream_ratio:.2f}x of "
                f"resident (floor {STREAM_FLOOR})"
            )

    Path(args.out).write_text(
        json.dumps(
            {"bench": "kernel_stream", "vmem_ceiling_rows": ceiling,
             "auto_floor": AUTO_FLOOR, "stream_floor": STREAM_FLOOR,
             "failures": failures, "entries": entries},
            indent=1,
        )
        + "\n"
    )
    print(f"# wrote {args.out}: {len(entries)} entries in {time.time() - t0:.1f}s")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
