"""Query-perf smoke: fused vs unfused plans, value-diffed and timed.

Two halves, both in interpret mode (CPU CI):

  1. **Equivalence** — for every query x scale, run the unfused jnp plan and
     the fused kernel plan, then (a) byte-diff the canonical JSON of every
     integer-exact output (group counts, Q1's quantity sums and their
     ratios, all of Q12's conditional counts — f32 accumulates these
     without rounding) and (b) bound the relative error of the float
     product-sums by FLOAT_RTOL (blocked kernel accumulation and
     segment_sum add in different orders; the values cannot be bit-equal
     and anything beyond ~1e-4 is a real bug, not ulps).  Same for the
     pushdown plans' qualifying-row counts.  Any mismatch fails the job.
  2. **Perf trajectory** — run the dbms and pushdown boxes (hot mode)
     through the sweep executor on both impls and record ``items_per_s``
     per (workload, query/plan, scale, impl) into BENCH_5.json, so fused
     vs unfused finally has data points per commit.  The job asserts the
     fused q1 plan at scale >= 0.1 is at least as fast as the unfused one
     on some platform (the tentpole's headline win); interpret-mode wall
     clock is NOT kernel speed, but the fused plan's single-pass shape
     already beats the unfused segment_sum graph on CPU too.

Usage: python -m benchmarks.query_smoke [--out BENCH_5.json] [--iters N]
       [--min-time S] [--platforms cpu-host dpu-sim]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

# Float product-sum tolerance: blocked accumulation vs segment_sum order
# drifts up to ~5e-4 relative at 600k f32 rows (worst on sum_disc: 77k
# ~0.05-magnitude addends into a ~4e3 sum); 1e-3 flags real bugs, not ulps.
FLOAT_RTOL = 1e-3

# Outputs that are integer-exact in f32 (counts, integer sums, and exact
# ratios of those): byte-diffed with NO tolerance.
EXACT_KEYS = {
    "q1": ("count", "sum_qty", "avg_qty"),
    "q6": ("rows",),
    "q12": ("high_line_count", "low_line_count", "count"),
}

DBMS_SCALES = ["0.001", "0.01", "0.1"]
PUSHDOWN_SCALES = ["0.01", "0.1"]
SELECTIVITIES = [0.01, 0.1, 0.5]


def _canon_exact(res: dict, keys) -> str:
    return json.dumps(
        {k: [float(x) for x in np.asarray(res[k], np.float64).reshape(-1)] for k in keys},
        sort_keys=True,
    )


def check_query_equivalence() -> list[str]:
    """Diff fused vs unfused results; returns mismatch descriptions."""
    from repro.engine import datagen, queries

    key = jax.random.PRNGKey(3)
    failures = []
    for scale, rows in [("0.001", 6_000), ("0.01", 60_000), ("0.1", 600_000)]:
        li = datagen.lineitem(key, rows=rows)
        od = datagen.orders(key, rows=max(rows // 4, 256))
        for qname in ("q1", "q6", "q12"):
            call = (lambda f: f(li, od)) if qname == "q12" else (lambda f: f(li))
            unfused = call(jax.jit(queries.QUERIES[qname]))
            fused = call(jax.jit(queries.FUSED_QUERIES[qname]))
            tag = f"dbms/{qname}@{scale}"
            if set(unfused) != set(fused):
                failures.append(f"{tag}: result keys differ {set(unfused) ^ set(fused)}")
                continue
            exact_keys = EXACT_KEYS[qname]
            a = _canon_exact(unfused, exact_keys)
            b = _canon_exact(fused, exact_keys)
            if a.encode() != b.encode():
                failures.append(
                    f"{tag}: exact outputs differ\n  unfused={a}\n  fused  ={b}"
                )
                continue
            worst = 0.0
            for k in unfused:
                if k in exact_keys:
                    continue
                u = np.asarray(unfused[k], np.float64).reshape(-1)
                f = np.asarray(fused[k], np.float64).reshape(-1)
                rel = float(np.max(np.abs(u - f) / np.maximum(np.abs(u), 1e-12)))
                worst = max(worst, rel)
                if rel > FLOAT_RTOL:
                    failures.append(f"{tag}: {k} drifted {rel:.2e} > {FLOAT_RTOL:g}")
            print(f"# {tag}: exact outputs byte-equal, float sums within {worst:.1e}")
    return failures


def check_pushdown_equivalence() -> list[str]:
    """All pushdown plans must report the same qualifying-row count."""
    from repro.engine import datagen, ops
    from repro.kernels import ops as kops
    from repro.tasks.pushdown import _pred_bounds, kernel_scan_columns

    key = jax.random.PRNGKey(7)
    failures = []
    for scale, rows in [("0.01", 60_000), ("0.1", 600_000)]:
        table = datagen.lineitem(key, rows=rows)
        scanned = table.select(
            "l_shipdate", "l_extendedprice", "l_discount", "l_quantity"
        )
        for sel in SELECTIVITIES:
            lo, hi = _pred_bounds(sel)
            cap = max(1024, int(1.5 * sel * rows))
            mask = ops.pred_between(scanned["l_shipdate"], lo, hi)
            baseline = int(ops.masked_count(mask))
            _, cnt_j = ops.compact(scanned, mask, cap)
            _, cnt_k = ops.compact(scanned, mask, cap, use_pallas=True)
            cnt_f = int(kops.filter_agg(kernel_scan_columns(table), lo, hi, -1.0, 1.0)[1])
            counts = {"baseline": baseline, "pushdown": int(cnt_j),
                      "pushdown+kernel": int(cnt_k), "pushdown_kernel": cnt_f}
            if len(set(counts.values())) != 1:
                failures.append(f"pushdown@{scale} sel={sel}: counts diverge {counts}")
            else:
                print(f"# pushdown@{scale} sel={sel}: all plans count {baseline}")
    return failures


def measure_boxes(platforms, iters, min_time, workers):
    """Run the dbms + pushdown perf boxes; returns BENCH entries."""
    from repro.core.box import Box
    from repro.core.executor import SweepExecutor

    executor = SweepExecutor(
        platforms=platforms,
        workers=workers,
        iters=iters,
        warmup=1,
        min_time_s=min_time,
    )
    boxes = [
        Box.from_dict(
            {
                "name": "query_smoke_dbms",
                "tasks": [
                    {
                        "task": "dbms",
                        "params": {
                            "scale": DBMS_SCALES,
                            "query": ["q1", "q6", "q12"],
                            "mode": ["hot"],
                            "impl": ["unfused", "fused"],
                        },
                        "metrics": ["items_per_s", "avg_latency_us"],
                    }
                ],
            }
        ),
        Box.from_dict(
            {
                "name": "query_smoke_pushdown",
                "tasks": [
                    {
                        "task": "pushdown",
                        "params": {
                            "scale": PUSHDOWN_SCALES,
                            "selectivity": [0.1],
                            "plan": ["baseline", "pushdown", "pushdown_kernel"],
                            "impl": ["jnp", "kernel"],
                        },
                        "metrics": ["items_per_s"],
                    }
                ],
            }
        ),
    ]
    entries = []
    for box in boxes:
        res = executor.run_box(box)
        if res.errors:
            for e in res.errors:
                print(f"ERROR {e['task']} {e['params']}: {e['error']}", file=sys.stderr)
            raise SystemExit(f"{box.name}: {len(res.errors)} unit error(s)")
        for r in res.results:
            entries.append(
                {
                    "workload": r.task,
                    "query": r.params.get("query") or r.params.get("plan"),
                    "scale": r.params.get("scale"),
                    "impl": r.params.get("impl", "unfused"),
                    "selectivity": r.params.get("selectivity"),
                    "platform": r.platform,
                    "items_per_s": r.metrics.get("items_per_s"),
                }
            )
    return entries


def assert_fused_wins(entries) -> str | None:
    """The tentpole claim: fused q1 >= unfused at scale >= 0.1 somewhere."""
    best = None
    for e in entries:
        if e["workload"] != "dbms" or e["query"] != "q1":
            continue
        if float(e["scale"]) < 0.1:
            continue
        peer = next(
            (
                p
                for p in entries
                if p["workload"] == "dbms"
                and p["query"] == "q1"
                and p["scale"] == e["scale"]
                and p["platform"] == e["platform"]
                and p["impl"] != e["impl"]
            ),
            None,
        )
        if e["impl"] == "fused" and peer is not None:
            ratio = e["items_per_s"] / max(peer["items_per_s"], 1e-12)
            print(f"# q1@{e['scale']} {e['platform']}: fused/unfused = {ratio:.2f}x")
            if best is None or ratio > best:
                best = ratio
    if best is None:
        return "no fused/unfused q1 pair at scale >= 0.1 was measured"
    if best < 1.0:
        return f"fused q1 never reached unfused throughput (best {best:.2f}x)"
    return None


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.query_smoke")
    p.add_argument("--out", default="BENCH_5.json")
    p.add_argument("--iters", type=int, default=2)
    p.add_argument("--min-time", type=float, default=0.2)
    p.add_argument("--workers", type=int, default=1)
    p.add_argument(
        "--platforms", nargs="+", default=["cpu-host"],
        help="execution platforms to record (e.g. cpu-host dpu-sim)",
    )
    args = p.parse_args(argv)

    t0 = time.time()
    failures = check_query_equivalence() + check_pushdown_equivalence()

    entries = measure_boxes(args.platforms, args.iters, args.min_time, args.workers)
    perf_failure = assert_fused_wins(entries)
    if perf_failure:
        failures.append(perf_failure)

    Path(args.out).write_text(
        json.dumps(
            {
                "bench": "query_smoke",
                "float_rtol": FLOAT_RTOL,
                "equivalence_failures": failures,
                "entries": entries,
            },
            indent=1,
        )
        + "\n"
    )
    print(f"# wrote {args.out}: {len(entries)} perf entries in {time.time() - t0:.1f}s")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
