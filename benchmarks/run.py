"""Benchmark orchestrator: run every paper-figure box through the framework.

Usage:
  python -m benchmarks.run                              # all figures
  python -m benchmarks.run --only fig13_pushdown fig15_dbms
  python -m benchmarks.run --iters 5 --warmup 2
  python -m benchmarks.run --workers 4                  # concurrent tests
  python -m benchmarks.run --platforms cpu-host dpu-sim # platform sweep
  python -m benchmarks.run --no-cache                   # force remeasure
  python -m benchmarks.run --shard 0/2                  # one hash-slice of each figure
  python -m benchmarks.run --shard 0/2@0.25             # weighted (cost-balanced) slice
  python -m benchmarks.run --shard 0/2@auto             # weights calibrated from fleet pings
  python -m benchmarks.run --shard 0/2 --shard-plan     # preview shard cost shares
  python -m benchmarks.run --merge                      # reassemble shard CSVs
  python -m benchmarks.run --remote 127.0.0.1:7177      # execute on a worker
  python -m benchmarks.run --remote hostA:7177,hostB:7177 --workers 4
                                                        # dynamic pull across a fleet
  python -m benchmarks.run --schedule static            # up-front LPT plan instead
  python -m benchmarks.run --list

Per figure: expand the box (paper §3.3), execute through the sweep
executor, write results/bench/<figure>.csv, and echo
`figure,task,params...,metric,value` lines to stdout — the combined CSV
consumed by bench_output.txt.  A persistent result cache (default
results/bench/cache.json) makes re-runs incremental: already-measured
(task, params, platform, iters) points are skipped and reported as
`cached=N` in the per-figure/total summary lines.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks.figures import FIGURES

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def _figure_csv(fig: str, shard=None) -> str:
    return f"{fig}.csv" if shard is None else f"{fig}.shard{shard.index}of{shard.count}.csv"


def run_figure(fig: str, executor, out_dir: Path, shard=None):
    from repro.core.box import Box

    box = Box.from_dict(FIGURES[fig])
    res = executor.run_box(box, shard=shard)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / _figure_csv(fig, shard)).write_text(res.csv())
    return res


def merge_figure(fig: str, out_dir: Path, platforms) -> int:
    """Merge every <fig>.shardIofN.csv in out_dir into <fig>.csv."""
    import re

    from repro.core.box import Box
    from repro.core.report import load_report_rows, merge_shard_reports, to_csv

    by_count: dict[int, list[Path]] = {}
    for f in sorted(out_dir.glob(f"{fig}.shard*of*.csv")):
        m = re.fullmatch(rf"{re.escape(fig)}\.shard(\d+)of(\d+)\.csv", f.name)
        if m:
            by_count.setdefault(int(m.group(2)), []).append(f)
    if not by_count:
        return 0
    if len(by_count) > 1:
        # Stale files from a previous different-N sharding would silently
        # shadow fresh rows; make the operator clean up instead.
        raise SystemExit(
            f"refusing to merge {fig}: shard files from different shard counts "
            f"{sorted(by_count)} coexist in {out_dir}; delete the stale set"
        )
    (count, shard_files), = by_count.items()
    rows = merge_shard_reports(
        [load_report_rows(f) for f in shard_files],
        box=Box.from_dict(FIGURES[fig]),
        platforms=platforms,
    )
    (out_dir / f"{fig}.csv").write_text(to_csv(rows))
    return len(rows)


def main(argv=None) -> int:
    from repro.core import config as config_mod

    p = argparse.ArgumentParser(prog="benchmarks.run")
    p.add_argument("--only", nargs="*", default=None, help="figure ids to run")
    # Shared sweep surface (core.config): same flags as repro.core.runner
    # and the serving CLI, with this orchestrator's defaults.
    config_mod.add_sweep_args(p, iters=3, warmup=1, platforms=["cpu-host"])
    p.add_argument(
        "--merge", action="store_true",
        help="merge existing per-figure shard CSVs into <figure>.csv and exit",
    )
    p.add_argument("--out", default=str(RESULTS))
    p.add_argument("--list", action="store_true")
    args = p.parse_args(argv)

    if args.list:
        for fig, box in FIGURES.items():
            n = sum(
                1
                for t in box["tasks"]
                for _ in _expand_count(t.get("params", {}))
            )
            print(f"{fig}: {n} tests over {[t['task'] for t in box['tasks']]}")
        return 0

    figs = args.only or list(FIGURES)
    unknown = set(figs) - set(FIGURES)
    if unknown:
        p.error(f"unknown figures {sorted(unknown)}; known: {sorted(FIGURES)}")

    out_dir = Path(args.out)
    if args.merge:
        for fig in figs:
            n = merge_figure(fig, out_dir, args.platforms)
            print(f"# {fig}: merged {n} rows", file=sys.stderr)
        return 0

    cfg = config_mod.SweepConfig.from_args(args)
    shard = config_mod.validate_sweep(cfg, p.error)
    executor = config_mod.make_executor(cfg, cache_default_path=out_dir / "cache.json")
    if args.shard_plan:
        from repro.core.box import Box

        for fig in figs:
            box = Box.from_dict(FIGURES[fig])
            for row in executor.shard_plan(box, shard):
                print(
                    f"{fig}: shard {row['shard']}  weight {row['weight']:g}  "
                    f"units {row['units']}  est_cost {row['est_cost']:.6g}  "
                    f"share {row['cost_share']:.1%}"
                )
        return 0
    all_errors = []
    total_cached = total_tests = 0
    print("figure,task,params,metric,value")
    t_start = time.time()
    for fig in figs:
        t0 = time.time()
        res = run_figure(fig, executor, out_dir, shard=shard)
        all_errors.extend({**e, "figure": fig} for e in res.errors)
        total_cached += res.stats.cached
        total_tests += res.stats.total
        for row in res.rows:
            task = row.get("task", "?")
            prefix = ";".join(
                f"{k[6:]}={row[k]}" for k in sorted(row) if k.startswith("param:")
            )
            if "platform" in row:
                prefix = f"platform={row['platform']};" + prefix
            for k, v in row.items():
                if k in ("task", "platform") or k.startswith("param:"):
                    continue
                print(f"{fig},{task},{prefix},{k},{v}")
        print(
            f"# {fig}: {len(res.rows)} rows in {time.time() - t0:.1f}s "
            f"({len(res.errors)} errors, cached={res.stats.cached}/{res.stats.total})",
            file=sys.stderr,
        )
    print(
        f"# total {time.time() - t_start:.1f}s cached={total_cached}/{total_tests}",
        file=sys.stderr,
    )
    for e in all_errors:
        print(f"ERROR {e['figure']}/{e['task']} {e['params']}: {e['error']}", file=sys.stderr)
    return 1 if all_errors else 0


def _expand_count(params: dict):
    import itertools

    lists = [v if isinstance(v, list) else [v] for v in params.values()] or [[None]]
    return itertools.product(*lists)


if __name__ == "__main__":
    raise SystemExit(main())
