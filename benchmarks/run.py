"""Benchmark orchestrator: run every paper-figure box through the framework.

Usage:
  PYTHONPATH=src python -m benchmarks.run                # all figures
  PYTHONPATH=src python -m benchmarks.run --only fig13_pushdown fig15_dbms
  PYTHONPATH=src python -m benchmarks.run --iters 5 --warmup 2
  PYTHONPATH=src python -m benchmarks.run --list

Per figure: expand the box (paper §3.3), execute, write
results/bench/<figure>.csv, and echo `figure,task,params...,metric,value`
lines to stdout — the combined CSV consumed by bench_output.txt.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks.figures import FIGURES

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def run_figure(fig: str, runner, out_dir: Path) -> tuple[list[dict], list[dict]]:
    from repro.core.box import Box

    box = Box.from_dict(FIGURES[fig])
    res = runner.run_box(box)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{fig}.csv").write_text(res.csv())
    return res.rows, res.errors


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.run")
    p.add_argument("--only", nargs="*", default=None, help="figure ids to run")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--warmup", type=int, default=1)
    p.add_argument("--out", default=str(RESULTS))
    p.add_argument("--list", action="store_true")
    args = p.parse_args(argv)

    if args.list:
        for fig, box in FIGURES.items():
            n = sum(
                1
                for t in box["tasks"]
                for _ in _expand_count(t.get("params", {}))
            )
            print(f"{fig}: {n} tests over {[t['task'] for t in box['tasks']]}")
        return 0

    figs = args.only or list(FIGURES)
    unknown = set(figs) - set(FIGURES)
    if unknown:
        p.error(f"unknown figures {sorted(unknown)}; known: {sorted(FIGURES)}")

    from repro.core.runner import Runner

    runner = Runner(platform={"name": "cpu-host"}, iters=args.iters, warmup=args.warmup)
    out_dir = Path(args.out)
    all_errors = []
    print("figure,task,params,metric,value")
    t_start = time.time()
    for fig in figs:
        t0 = time.time()
        rows, errors = run_figure(fig, runner, out_dir)
        all_errors.extend({**e, "figure": fig} for e in errors)
        for row in rows:
            task = row.get("task", "?")
            params = ";".join(
                f"{k[6:]}={row[k]}" for k in sorted(row) if k.startswith("param:")
            )
            for k, v in row.items():
                if k == "task" or k.startswith("param:"):
                    continue
                print(f"{fig},{task},{params},{k},{v}")
        print(
            f"# {fig}: {len(rows)} rows in {time.time() - t0:.1f}s "
            f"({len(errors)} errors)",
            file=sys.stderr,
        )
    print(f"# total {time.time() - t_start:.1f}s", file=sys.stderr)
    for e in all_errors:
        print(f"ERROR {e['figure']}/{e['task']} {e['params']}: {e['error']}", file=sys.stderr)
    return 1 if all_errors else 0


def _expand_count(params: dict):
    import itertools

    lists = [v if isinstance(v, list) else [v] for v in params.values()] or [[None]]
    return itertools.product(*lists)


if __name__ == "__main__":
    raise SystemExit(main())
