"""Benchmark orchestrator: run every paper-figure box through the framework.

Usage:
  python -m benchmarks.run                              # all figures
  python -m benchmarks.run --only fig13_pushdown fig15_dbms
  python -m benchmarks.run --iters 5 --warmup 2
  python -m benchmarks.run --workers 4                  # concurrent tests
  python -m benchmarks.run --platforms cpu-host dpu-sim # platform sweep
  python -m benchmarks.run --no-cache                   # force remeasure
  python -m benchmarks.run --list

Per figure: expand the box (paper §3.3), execute through the sweep
executor, write results/bench/<figure>.csv, and echo
`figure,task,params...,metric,value` lines to stdout — the combined CSV
consumed by bench_output.txt.  A persistent result cache (default
results/bench/cache.json) makes re-runs incremental: already-measured
(task, params, platform, iters) points are skipped and reported as
`cached=N` in the per-figure/total summary lines.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks.figures import FIGURES

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def run_figure(fig: str, executor, out_dir: Path):
    from repro.core.box import Box

    box = Box.from_dict(FIGURES[fig])
    res = executor.run_box(box)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{fig}.csv").write_text(res.csv())
    return res


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.run")
    p.add_argument("--only", nargs="*", default=None, help="figure ids to run")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--warmup", type=int, default=1)
    p.add_argument("--workers", type=int, default=1, help="concurrent test workers")
    p.add_argument(
        "--platforms", nargs="+", default=["cpu-host"],
        help="execution platforms to sweep (e.g. cpu-host dpu-sim)",
    )
    p.add_argument("--pool", choices=("thread", "process"), default="thread")
    p.add_argument("--no-cache", action="store_true", help="remeasure everything")
    p.add_argument("--cache-file", default=None, help="cache path (default <out>/cache.json)")
    p.add_argument("--out", default=str(RESULTS))
    p.add_argument("--list", action="store_true")
    args = p.parse_args(argv)

    if args.list:
        for fig, box in FIGURES.items():
            n = sum(
                1
                for t in box["tasks"]
                for _ in _expand_count(t.get("params", {}))
            )
            print(f"{fig}: {n} tests over {[t['task'] for t in box['tasks']]}")
        return 0

    figs = args.only or list(FIGURES)
    unknown = set(figs) - set(FIGURES)
    if unknown:
        p.error(f"unknown figures {sorted(unknown)}; known: {sorted(FIGURES)}")

    from repro.core.cache import ResultCache
    from repro.core.executor import SweepExecutor
    from repro.core.platform import get_platform

    try:
        for name in args.platforms:
            get_platform(name)
    except KeyError as e:
        p.error(str(e.args[0]))

    out_dir = Path(args.out)
    cache = None
    if not args.no_cache:
        cache = ResultCache(args.cache_file or out_dir / "cache.json")
    executor = SweepExecutor(
        platforms=args.platforms,
        workers=args.workers,
        iters=args.iters,
        warmup=args.warmup,
        cache=cache,
        pool=args.pool,
    )
    all_errors = []
    total_cached = total_tests = 0
    print("figure,task,params,metric,value")
    t_start = time.time()
    for fig in figs:
        t0 = time.time()
        res = run_figure(fig, executor, out_dir)
        all_errors.extend({**e, "figure": fig} for e in res.errors)
        total_cached += res.stats.cached
        total_tests += res.stats.total
        for row in res.rows:
            task = row.get("task", "?")
            prefix = ";".join(
                f"{k[6:]}={row[k]}" for k in sorted(row) if k.startswith("param:")
            )
            if "platform" in row:
                prefix = f"platform={row['platform']};" + prefix
            for k, v in row.items():
                if k in ("task", "platform") or k.startswith("param:"):
                    continue
                print(f"{fig},{task},{prefix},{k},{v}")
        print(
            f"# {fig}: {len(res.rows)} rows in {time.time() - t0:.1f}s "
            f"({len(res.errors)} errors, cached={res.stats.cached}/{res.stats.total})",
            file=sys.stderr,
        )
    print(
        f"# total {time.time() - t_start:.1f}s cached={total_cached}/{total_tests}",
        file=sys.stderr,
    )
    for e in all_errors:
        print(f"ERROR {e['figure']}/{e['task']} {e['params']}: {e['error']}", file=sys.stderr)
    return 1 if all_errors else 0


def _expand_count(params: dict):
    import itertools

    lists = [v if isinstance(v, list) else [v] for v in params.values()] or [[None]]
    return itertools.product(*lists)


if __name__ == "__main__":
    raise SystemExit(main())
