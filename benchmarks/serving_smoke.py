"""Serving smoke: scan-sharing equality + shed-free service below saturation.

Three checks, all on CPU (interpret mode) so CI can run them:

  1. **Scan-sharing oracle** — for every query, a micro-batch of requests
     with different predicate constants through the multi-program kernel
     must be BYTE-IDENTICAL to serial per-request execution (both pallas
     and ref paths).  Any byte of drift fails the job.
  2. **Shed-free below saturation** — measure each (query, platform)
     point's closed-loop saturation QPS, then offer a fixed-rate open-loop
     load at a fraction of it for ``--duration`` seconds; admission
     control must shed nothing and every offered request must complete.
  3. **Record** — p50/p99 latency, delivered QPS, and saturation QPS per
     (query, platform) go to BENCH_6.json for trend tracking.

Usage: python -m benchmarks.serving_smoke [--out BENCH_6.json]
       [--duration 10] [--platforms cpu-host] [--load-fraction 0.4]
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time
from pathlib import Path

import jax
import numpy as np

QUERIES = ("q1", "q6", "q12")
ROWS = 6_000  # scale 0.001: small enough for interpret-mode CI, real kernels


def check_scan_sharing() -> list[str]:
    """Byte-diff micro-batched vs serial fused-query results."""
    from repro.engine import datagen, queries
    from repro.runtime.loadgen import sample_params

    li = datagen.lineitem(jax.random.PRNGKey(3), rows=ROWS)
    od = datagen.orders(jax.random.PRNGKey(3), rows=max(ROWS // 4, 256))
    plans = queries.make_serving_plans(li, od)
    failures = []
    rng = random.Random(0)
    for qname in QUERIES:
        param_list = [sample_params(qname, rng) for _ in range(6)]
        for use_pallas in (True, False):
            batched = queries.fused_query_batch(
                plans[qname], param_list, use_pallas=use_pallas
            )
            for i, (params, got) in enumerate(zip(param_list, batched)):
                want = queries.fused_query_serial(
                    plans[qname], params, use_pallas=use_pallas
                )
                for k in want:
                    if not np.array_equal(np.asarray(want[k]), np.asarray(got[k])):
                        failures.append(
                            f"{qname}[{i}] pallas={use_pallas}: {k} differs "
                            f"(batched != serial)"
                        )
        mode = "pallas+ref"
        print(f"# {qname}: {len(param_list)}-request micro-batch byte-equal serial ({mode})")
    return failures


def serve_point(plans, qname: str, duration_s: float, load_fraction: float):
    """One (query) serving run: saturation probe, then sub-saturation load."""
    from repro.runtime.loadgen import generate_trace
    from repro.runtime.serve_query import QueryServer, measure_saturation, run_open_loop

    saturation = measure_saturation(plans, [qname], max_batch=8, seed=0)
    # Offer a comfortable fraction of the measured ceiling so the shed-free
    # assertion holds on however slow a CI machine this lands on.
    rate = max(1.0, load_fraction * saturation)
    server = QueryServer(plans, queue_depth=256, max_batch=8)
    server.warmup([qname])
    trace = generate_trace([qname], rate, duration_s, arrival="fixed", seed=0)
    report = run_open_loop(server, trace)
    lat = sorted(report.latencies_s)
    return {
        "query": qname,
        "rate_qps": rate,
        "saturation_qps": saturation,
        "offered": report.offered,
        "completed": len(report.completed),
        "shed": report.shed,
        "p50_latency_us": 1e6 * float(np.percentile(lat, 50)) if lat else None,
        "p99_latency_us": 1e6 * float(np.percentile(lat, 99)) if lat else None,
        "qps": report.qps,
        "kernel_calls": server.kernel_calls,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.serving_smoke")
    p.add_argument("--out", default="BENCH_6.json")
    p.add_argument("--duration", type=float, default=10.0)
    p.add_argument(
        "--load-fraction", type=float, default=0.4,
        help="offered fixed rate as a fraction of measured saturation",
    )
    p.add_argument(
        "--platforms", nargs="+", default=["cpu-host"],
        help="platforms to record (rates on simulated platforms are "
        "dilated by their time_scale)",
    )
    args = p.parse_args(argv)

    t0 = time.time()
    failures = check_scan_sharing()

    from repro.core.platform import get_platform
    from repro.engine import datagen, queries

    li = datagen.lineitem(jax.random.PRNGKey(3), rows=ROWS)
    od = datagen.orders(jax.random.PRNGKey(3), rows=max(ROWS // 4, 256))
    plans = queries.make_serving_plans(li, od)

    entries = []
    # Serve each query once on the host; simulated platforms reuse the
    # measurement under their time dilation (one 10s wall-clock run per
    # query keeps the job's budget bounded).
    for qname in QUERIES:
        base = serve_point(plans, qname, args.duration / len(QUERIES), args.load_fraction)
        if base["shed"] != 0:
            failures.append(
                f"{qname}: shed {base['shed']} request(s) at "
                f"{base['rate_qps']:.0f} qps below saturation "
                f"({base['saturation_qps']:.0f} qps)"
            )
        if base["completed"] != base["offered"]:
            failures.append(
                f"{qname}: only {base['completed']}/{base['offered']} "
                f"offered requests completed"
            )
        for plat in args.platforms:
            ts = float(get_platform(plat).time_scale)
            entries.append(
                {
                    **base,
                    "platform": plat,
                    "rate_qps": base["rate_qps"] / ts,
                    "saturation_qps": base["saturation_qps"] / ts,
                    "qps": base["qps"] / ts,
                    "p50_latency_us": (
                        base["p50_latency_us"] * ts if base["p50_latency_us"] else None
                    ),
                    "p99_latency_us": (
                        base["p99_latency_us"] * ts if base["p99_latency_us"] else None
                    ),
                }
            )
        print(
            f"# {qname}: saturation {base['saturation_qps']:.0f} qps, served "
            f"{base['completed']}/{base['offered']} at {base['rate_qps']:.0f} qps, "
            f"p99 {base['p99_latency_us'] and round(base['p99_latency_us'])} us, "
            f"shed {base['shed']}"
        )

    Path(args.out).write_text(
        json.dumps(
            {"bench": "serving_smoke", "failures": failures, "entries": entries},
            indent=1,
        )
        + "\n"
    )
    print(f"# wrote {args.out}: {len(entries)} entries in {time.time() - t0:.1f}s")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
