"""Transport scale: async multiplexed dispatch vs threaded pullers at 64 workers.

The async-transport layer's acceptance bar, run as a benchmark so CI pins
it per commit:

  1. **Baseline** — run the fleet box sequentially (no fleet) for the
     reference report every later phase must byte-match.
  2. **Fleet sweep** — spawn a 64-worker loopback fleet as ONE subprocess
     (``python -m repro.core.remote fleet --count 64``; a single
     comma-joined announce line names every endpoint), then drive the same
     box through it twice: once on the ``threaded`` transport (one puller
     thread per capacity slot — the pre-async baseline) and once on
     ``async`` (one dispatcher thread plus the shared selectors IO loop,
     one multiplexed connection per endpoint).  Both reports must be
     byte-identical to the sequential baseline and to each other, the
     threaded pass must have spawned >= worker-count client threads, and
     the async pass must stay within :data:`ASYNC_THREAD_BOUND`.
  3. **Steal win** — a deliberately imbalanced 2-shard split (every unit
     hash-assigned to shard 1 sleeps ~10x longer than shard 0's, via a
     param-dependent sleep table the plugin reads per call) runs twice with
     a shared result cache: without ``--steal`` the pass is bounded by the
     slow shard; with it, the drained shard 0 runner claims shard 1's
     leftovers through cache claim records and the measured wall clock must
     drop.  Merged reports byte-match the baseline both times.

Results land in a BENCH JSON (``--out``): units/s and client dispatch
thread count per transport, plus the no-steal/steal wall clocks and the
stolen-unit count.

Usage: python -m benchmarks.transport_scale [--out BENCH_8.json]
       [--workers 64]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

from repro.core import registry as reg
from repro.core.box import Box
from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor, SweepResult
from repro.core.report import merge_shard_reports, to_csv
from repro.core.shard import ShardSpec

#: Max client-side dispatch/IO threads the async transport may use for a
#: whole fleet, however many workers it has (1 dispatcher + 1 shared IO
#: loop today; the bound leaves headroom, not a thread per endpoint).
ASYNC_THREAD_BOUND = 4

#: Per-unit sleep for shard 1's units vs shard 0's in the steal phase —
#: the ~10x imbalance that makes leftovers worth claiming.
HEAVY_S = 0.25
LIGHT_S = 0.02


def _make_fleet_plugin(root: Path, name: str) -> Path:
    """64-unit deterministic task: metrics are pure functions of params, so
    reports byte-compare no matter which worker (or transport) ran what."""
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "task.json").write_text(
        json.dumps(
            {
                "name": name,
                "param_space": {
                    "a": list(range(1, 17)),
                    "b": ["w", "x", "y", "z"],
                },
                "metrics": ["avg_latency_us", "ops_per_s"],
            }
        )
    )
    (d / "run.py").write_text(
        # Injective in params (101 is coprime to every multiplier) so a
        # demux bug that swapped two responses would flip a metric cell.
        "import time\n"
        "def main(ctx, params):\n"
        "    time.sleep(0.02)\n"
        "    mult = {'w': 1, 'x': 2, 'y': 3, 'z': 5}[params['b']]\n"
        "    t = 1e-6 * (101 * params['a'] + mult)\n"
        "    return {'times_s': [t, 2 * t], 'ops_per_iter': 100.0}\n"
    )
    return d


def _make_steal_plugin(root: Path, name: str) -> Path:
    """Like the fleet plugin, but the sleep is a param-dependent table read
    per call from ``heavy.json`` — written AFTER the shard partition is
    known, so shard 1's units can be made ~10x heavier than shard 0's
    without touching the reported metrics (sleep never enters them)."""
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "task.json").write_text(
        json.dumps(
            {
                "name": name,
                "param_space": {"a": list(range(24)), "b": ["s"]},
                "metrics": ["avg_latency_us", "ops_per_s"],
            }
        )
    )
    (d / "heavy.json").write_text("[]")
    (d / "run.py").write_text(
        "import json, pathlib, time\n"
        "_HERE = pathlib.Path(__file__).resolve().parent\n"
        "def main(ctx, params):\n"
        "    heavy = set(json.loads((_HERE / 'heavy.json').read_text()))\n"
        f"    time.sleep({HEAVY_S} if params['a'] in heavy else {LIGHT_S})\n"
        "    t = 1e-6 * (101 * params['a'] + 7)\n"
        "    return {'times_s': [t, 2 * t], 'ops_per_iter': 100.0}\n"
    )
    return d


def _box(name: str, space: dict) -> Box:
    return Box.from_dict(
        {"name": f"{name}_box", "tasks": [{"task": name, "params": space}]}
    )


def _spawn_fleet(count: int, plugin: Path) -> tuple[subprocess.Popen, list[str]]:
    """One subprocess serving ``count`` loopback workers; returns it plus
    the endpoint list parsed from the single comma-joined announce line."""
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.core.remote", "fleet",
            "--count", str(count), "--capacity", "1",
            "--plugin-dir", str(plugin),
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    assert proc.stdout is not None
    deadline = time.monotonic() + 120
    while True:
        line = proc.stdout.readline()
        if line.startswith("listening on "):
            endpoints = line[len("listening on "):].strip().split(",")
            assert len(endpoints) == count, f"announced {len(endpoints)}/{count}"
            return proc, endpoints
        if proc.poll() is not None or time.monotonic() > deadline:
            raise RuntimeError(f"fleet subprocess died before announcing: {line!r}")


def phase_fleet(plugin: Path, box: Box, baseline_csv: str, workers: int) -> dict:
    """Threaded vs async over the same 64-worker loopback fleet."""
    proc, endpoints = _spawn_fleet(workers, plugin)
    try:
        passes: dict[str, dict] = {}
        csvs: dict[str, str] = {}
        for transport in ("threaded", "async"):
            ex = SweepExecutor(
                platforms=["cpu-host"], workers=workers, iters=1, warmup=0,
                remote=",".join(endpoints), transport=transport,
            )
            t0 = time.monotonic()
            res = ex.run_box(box)
            wall = time.monotonic() - t0
            assert res.stats.errors == 0, (
                f"{transport} pass had {res.stats.errors} errors"
            )
            assert res.csv() == baseline_csv, (
                f"{transport} fleet report diverged from the sequential baseline"
            )
            csvs[transport] = res.csv()
            passes[transport] = {
                "wall_s": round(wall, 3),
                "units_per_s": round(res.stats.total / wall, 1),
                "dispatch_threads": res.stats.dispatch_threads,
            }
        assert csvs["threaded"] == csvs["async"]
        assert passes["threaded"]["dispatch_threads"] >= workers, (
            f"threaded transport spawned only "
            f"{passes['threaded']['dispatch_threads']} pullers for {workers} slots"
        )
        assert passes["async"]["dispatch_threads"] <= ASYNC_THREAD_BOUND, (
            f"async transport used {passes['async']['dispatch_threads']} client "
            f"threads — bound is {ASYNC_THREAD_BOUND}"
        )
        return {
            "workers": workers,
            "units": box.total_tests(),
            "threaded": passes["threaded"],
            "async": passes["async"],
            "async_thread_bound": ASYNC_THREAD_BOUND,
            "identical": True,
        }
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def _run_shard_pair(
    box: Box, cache_path: Path, steal: bool
) -> tuple[float, list[SweepResult]]:
    """Run shards 0/2 and 1/2 concurrently against a shared cache file;
    wall clock is until BOTH finish (what a real co-scheduled pair pays)."""
    results: list[SweepResult | None] = [None, None]
    errors: list[BaseException] = []

    def run(i: int) -> None:
        try:
            # NOT max_entries=0: steal coordination lives in the shared
            # cache file, and an evict-everything flush at the end of the
            # first-finishing shard would wipe its sibling's view of what
            # has already been claimed and published.
            ex = SweepExecutor(
                platforms=["cpu-host"], iters=1, warmup=0,
                cache=ResultCache(cache_path), steal=steal,
            )
            results[i] = ex.run_box(box, shard=ShardSpec(i, 2))
        except BaseException as e:  # surface in the main thread
            errors.append(e)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(2)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise errors[0]
    assert all(r is not None for r in results)
    return wall, results  # type: ignore[return-value]


def phase_steal(plugin: Path, box: Box, tmp: Path) -> dict:
    """Measure the wall-clock win of cache-mediated stealing on an
    imbalanced 2-shard split."""
    # Learn the hash partition first, THEN make shard 1's units heavy: the
    # sleep table never enters the metrics, so skeys (and the partition)
    # don't move when it changes.
    probe = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0)
    mine, foreign = probe._expand_partition(box, probe.platforms, ShardSpec(0, 2))
    assert mine and foreign, "degenerate hash partition: one shard owns everything"
    (plugin / "heavy.json").write_text(json.dumps(sorted(u.params["a"] for u in foreign)))

    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    assert baseline.stats.errors == 0
    baseline_csv = baseline.csv()

    walls: dict[str, float] = {}
    stolen = 0
    for label, steal in (("nosteal", False), ("steal", True)):
        wall, results = _run_shard_pair(box, tmp / f"{label}-cache.json", steal)
        for i, res in enumerate(results):
            assert res.stats.errors == 0, f"{label} shard {i} had errors"
        merged = to_csv(merge_shard_reports([r.rows for r in results], box=box))
        assert merged == baseline_csv, f"{label} merged report diverged from baseline"
        walls[label] = wall
        if steal:
            stolen = sum(r.stats.stolen for r in results)
    assert stolen > 0, "steal pass claimed nothing despite the imbalance"
    assert walls["steal"] < walls["nosteal"], (
        f"stealing did not win: {walls['steal']:.2f}s vs {walls['nosteal']:.2f}s"
    )
    return {
        "units": box.total_tests(),
        "shard0_units": len(mine),
        "shard1_units": len(foreign),
        "heavy_sleep_s": HEAVY_S,
        "light_sleep_s": LIGHT_S,
        "nosteal_wall_s": round(walls["nosteal"], 3),
        "steal_wall_s": round(walls["steal"], 3),
        "speedup": round(walls["nosteal"] / walls["steal"], 2),
        "stolen": stolen,
        "identical": True,
    }


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="benchmarks.transport_scale",
        description="async multiplexed fleet transport scale + steal win",
    )
    p.add_argument("--out", default=None, help="write BENCH JSON here")
    p.add_argument("--workers", type=int, default=64)
    args = p.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="transport-scale-") as tmpdir:
        tmp = Path(tmpdir)
        fleet_plugin = _make_fleet_plugin(tmp, "scale")
        reg.load_plugin_dir(fleet_plugin)
        fleet_box = _box("scale", {"a": list(range(1, 17)), "b": ["w", "x", "y", "z"]})

        print("# phase 1/3: sequential baseline", flush=True)
        baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(
            fleet_box
        )
        assert baseline.stats.errors == 0

        print(f"# phase 2/3: {args.workers}-worker loopback fleet sweep", flush=True)
        fleet = phase_fleet(fleet_plugin, fleet_box, baseline.csv(), args.workers)
        print(
            f"#   threaded: {fleet['threaded']['units_per_s']} units/s with "
            f"{fleet['threaded']['dispatch_threads']} client threads; "
            f"async: {fleet['async']['units_per_s']} units/s with "
            f"{fleet['async']['dispatch_threads']} — byte-identical",
            flush=True,
        )

        print("# phase 3/3: 2-shard steal win", flush=True)
        steal_plugin = _make_steal_plugin(tmp, "scale_steal")
        reg.load_plugin_dir(steal_plugin)
        steal_box = _box("scale_steal", {"a": list(range(24)), "b": ["s"]})
        steal = phase_steal(steal_plugin, steal_box, tmp)
        print(
            f"#   nosteal={steal['nosteal_wall_s']}s steal={steal['steal_wall_s']}s "
            f"({steal['speedup']}x, {steal['stolen']} units stolen) — byte-identical",
            flush=True,
        )

    bench = {"bench": "transport_scale", "fleet": fleet, "steal": steal}
    text = json.dumps(bench, indent=1) + "\n"
    if args.out:
        Path(args.out).write_text(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
