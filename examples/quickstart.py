"""Quickstart: declare a measurement box, run it, read the report.

This is the paper's Fig. 2 user journey end-to-end: a JSON box naming two
tasks — a network microbenchmark and predicate pushdown — executed by the
framework (prepare → run per expanded test → report), printed as a table.

  PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import json

from repro.core import Box, Runner

# The exact shape a user would put in a .json file (paper Fig. 2).
BOX_JSON = json.dumps(
    {
        "name": "quickstart",
        "tasks": [
            {
                "task": "network",
                "params": {"collective": ["all_reduce"], "payload": ["1MB"],
                           "schedule": ["xla", "shardmap"]},
                "metrics": ["p50_latency_us", "p99_latency_us", "bandwidth_gb_s"],
            },
            {
                "task": "pushdown",
                "params": {"scale": ["0.01"], "selectivity": [0.01],
                           "plan": ["baseline", "pushdown"]},
                "metrics": ["items_per_s"],
            },
        ],
    }
)


def main() -> int:
    box = Box.from_json(BOX_JSON)
    print(f"box {box.name!r}: {box.total_tests()} tests")
    runner = Runner(platform={"name": "cpu-host"}, iters=3, warmup=1)
    result = runner.run_box(box)
    print(result.markdown())
    if result.errors:
        for e in result.errors:
            print("ERROR", e["task"], e["error"])
        return 1
    # the dpBento clean step is explicit (paper §3.3 step 6):
    runner.clean()
    print("cleaned.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
