"""Author a plugin task, declare it in a box, run it — the paper's §3.2 path.

Two plugin flavours are shown:
  1. a *class plugin* registered in-process (vendor-SDK style), and
  2. a *directory plugin*: four scripts + task.json dropped into a folder,
     loaded without touching framework code — the paper's literal mechanism.

  PYTHONPATH=src python examples/run_box.py
"""
from __future__ import annotations

import json
import tempfile
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import Box, Runner, Samples, Task, TaskContext
from repro.core.registry import _register_for_tests, load_plugin_dir
from repro.core.timing import measure


# ---- 1. class plugin: softmax throughput -----------------------------------
class SoftmaxTask(Task):
    name = "softmax_plugin"
    param_space = {"rows": [256, 1024], "cols": [128, 512]}
    default_metrics = ("ops_per_s", "avg_latency_us")

    def run(self, ctx: TaskContext, params):
        r, c = params.get("rows", 256), params.get("cols", 128)
        x = jax.random.normal(jax.random.PRNGKey(0), (r, c))
        fn = jax.jit(lambda v: jax.nn.softmax(v, axis=-1))
        times = measure(fn, x, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(times_s=times, ops_per_iter=float(r * c))


# ---- 2. directory plugin: written to disk, then loaded ----------------------
PLUGIN_TASK_JSON = {
    "name": "l2norm_dirplugin",
    "param_space": {"size": [4096, 65536]},
    "metrics": ["ops_per_s"],
}
PLUGIN_RUN_PY = textwrap.dedent(
    """
    import time
    import jax, jax.numpy as jnp

    def main(ctx, params):
        n = int(params.get("size", 4096))
        x = jnp.arange(n, dtype=jnp.float32)
        fn = jax.jit(lambda v: jnp.sqrt(jnp.sum(v * v)))
        fn(x).block_until_ready()  # warmup/compile
        times = []
        for _ in range(ctx.iters):
            t0 = time.perf_counter()
            fn(x).block_until_ready()
            times.append(time.perf_counter() - t0)
        return {"times_s": times, "ops_per_iter": float(n)}
    """
)


def main() -> int:
    _register_for_tests(SoftmaxTask())

    with tempfile.TemporaryDirectory(prefix="dpbento_plugin_") as d:
        root = Path(d) / "l2norm"
        root.mkdir()
        (root / "task.json").write_text(json.dumps(PLUGIN_TASK_JSON))
        (root / "run.py").write_text(PLUGIN_RUN_PY)
        load_plugin_dir(root)

        box = Box.from_dict(
            {
                "name": "plugin_demo",
                "tasks": [
                    {"task": "softmax_plugin", "params": {"rows": [256], "cols": [128, 512]}},
                    {"task": "l2norm_dirplugin", "params": {"size": [4096, 65536]}},
                ],
            }
        )
        runner = Runner(iters=3, warmup=1)
        res = runner.run_box(box)
        print(res.markdown())
        if res.errors:
            for e in res.errors:
                print("ERROR", e["task"], e["error"])
            return 1
    print("OK: both plugin flavours ran inside one box")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
