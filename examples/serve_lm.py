"""Serve a small LM with batched requests (continuous batching).

Spins up the SlotServer on a reduced granite-3-8b (GQA family), submits a
mixed batch of requests with different prompt lengths/budgets, and checks
every request completes with the same greedy tokens it would get alone —
batching must not change results.

  PYTHONPATH=src python examples/serve_lm.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, tiny
from repro.models.model import Model
from repro.runtime.serve_loop import Request, SlotServer


def main() -> int:
    cfg = tiny(get_arch("granite-3-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    server = SlotServer(model, n_slots=4, max_len=64)
    server.load(params)

    key = jax.random.PRNGKey(1)
    requests = []
    for uid in range(10):
        k = jax.random.fold_in(key, uid)
        plen = int(jax.random.randint(k, (), 3, 17))
        prompt = jax.random.randint(jax.random.fold_in(k, 1), (plen,), 0, cfg.vocab_size)
        requests.append(Request(uid=uid, prompt=prompt.astype(jnp.int32), max_new_tokens=8))
        server.submit(requests[-1])

    t0 = time.time()
    completions = server.run()
    dt = time.time() - t0
    done = {c.uid: c for c in completions}
    assert len(done) == len(requests), (len(done), len(requests))

    # verify against solo generation for two requests
    for req in requests[:2]:
        solo = SlotServer(model, n_slots=1, max_len=64)
        solo.load(params)
        solo.submit(req)
        ref = solo.run()[0]
        assert done[req.uid].tokens == ref.tokens, (
            f"uid {req.uid}: batched {done[req.uid].tokens} != solo {ref.tokens}"
        )

    total_new = sum(len(c.tokens) for c in completions)
    print(
        f"served {len(completions)} requests, {total_new} tokens in {dt:.1f}s "
        f"({server.decode_calls} decode steps); batched == solo for sampled requests"
    )
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
