"""Train a ~100M-param LM for a few hundred steps with the production loop.

Uses olmo-1b scaled to ~100M (8 layers x 512 d_model), synthetic data, the
sharded AdamW, checkpointing, and a mid-run injected failure to demonstrate
the restart path. Loss must decrease.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

from repro.configs.base import get_arch
from repro.data.pipeline import for_model
from repro.models.model import Model
from repro.runtime.train_loop import TrainConfig, run_with_restarts


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    args = p.parse_args(argv)

    # ~100M-param member of the olmo family (d_model 512, 8 layers)
    cfg = dataclasses.replace(
        get_arch("olmo-1b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
        d_ff=2048, vocab_size=50_304, max_seq_len=args.seq_len,
        param_dtype="float32", compute_dtype="float32",
    )
    model = Model(cfg)
    print(f"{cfg.name}-100m: {cfg.n_params()/1e6:.1f}M params")

    data = for_model(cfg, seq_len=args.seq_len, global_batch=args.batch)
    with tempfile.TemporaryDirectory(prefix="train_lm_ckpt_") as ckpt:
        tc = TrainConfig(
            steps=args.steps, ckpt_every=50, ckpt_dir=ckpt,
            lr=3e-4, warmup_steps=20,
            failure_at=args.steps // 2,  # chaos drill: die halfway, restart from ckpt
        )
        res = run_with_restarts(model, data, tc)
    first = sum(res.losses[:10]) / max(len(res.losses[:10]), 1)
    last = sum(res.losses[-10:]) / max(len(res.losses[-10:]), 1)
    print(
        f"steps={res.final_step} restarts={res.restarts} "
        f"restored_from={res.restored_from} loss {first:.3f} -> {last:.3f}"
    )
    assert res.restarts >= 1, "failure injection should have triggered a restart"
    assert last < first, f"loss did not decrease ({first:.3f} -> {last:.3f})"
    print("OK: survived failure, loss decreased.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
