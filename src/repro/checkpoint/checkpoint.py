"""Sharded, atomic, manifest-based checkpointing.

Layout (one directory per step):
    ckpt_dir/
      step_000120.tmp-<nonce>/      # staged writes
        manifest.json               # treedef, per-leaf shape/dtype/file, step
        proc00_leaf0000.npy ...     # this process's shard of each leaf
      step_000120/                  # atomic rename when complete

Fault-tolerance contract:
  * save is atomic: readers only ever see fully-written directories
    (os.replace of the staging dir is the commit point);
  * every process writes only its addressable shards; on restore each
    process reads its shards back and reassembles global arrays via
    jax.make_array_from_single_device_arrays (single-process: plain load +
    device_put with sharding);
  * `latest_step` scans for committed directories, so a crash mid-save
    resumes from the previous complete checkpoint;
  * retention: keep the newest `keep` checkpoints, best-effort delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_files(n: int, proc: int) -> list[str]:
    return [f"proc{proc:02d}_leaf{i:04d}.npy" for i in range(n)]


def _tree_paths(tree: Any) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(kp) for kp, _ in paths]


def save(ckpt_dir: str | Path, step: int, tree: Any, *, keep: int = 3) -> Path:
    """Write `tree` (arrays) for `step`. Returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    proc = jax.process_index()
    final = ckpt_dir / f"step_{step:08d}"
    stage = ckpt_dir / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
    stage.mkdir(parents=True)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    files = _leaf_files(len(leaves), proc)
    meta = []
    for leaf, fname in zip(leaves, files):
        arr = np.asarray(jax.device_get(leaf))
        np.save(stage / fname, arr, allow_pickle=False)
        meta.append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "process_count": jax.process_count(),
        "paths": _tree_paths(tree),
        "leaves": meta,
        "treedef": str(treedef),
    }
    (stage / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # Commit point. If final exists (re-save of same step), replace it.
    if final.exists():
        shutil.rmtree(final)
    os.replace(stage, final)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


def committed_steps(ckpt_dir: str | Path) -> list[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") and ".tmp-" not in p.name:
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str | Path,
    step: int | None = None,
    *,
    like: Any = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Load a checkpoint. `like` (abstract pytree) supplies the treedef;
    `shardings` (optional matching pytree of Sharding) places each leaf.
    Returns (tree, step)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    if like is None:
        raise ValueError("restore requires `like` (abstract pytree for the treedef)")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template has {len(leaves_like)}"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves_like)
    )

    out = []
    for i, (m, sh) in enumerate(zip(manifest["leaves"], shard_leaves)):
        arr = np.load(d / m["file"], allow_pickle=False)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_committed: int | None = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # only one outstanding save
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(self.ckpt_dir, step, host_tree, keep=self.keep)
            self.last_committed = step

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
