"""Architecture configs + input shape sets.

Every assigned architecture is a frozen `ArchConfig`; `ARCHS` is the
registry (`--arch <id>` everywhere). `tiny()` derives the reduced config
used by CPU smoke tests. `SHAPES` defines the four assigned input-shape
cells; which cells apply to an arch is decided by `cells_for(cfg)`.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any


@dataclass(frozen=True)
class LayerKind:
    """One position of the repeating layer pattern."""

    mixer: str  # "attn" | "mamba"
    ffn: str  # "dense" | "moe" | "none"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    source: str = ""

    # layer pattern: repeating unit; len(pattern) * n_repeats + first_k_dense == n_layers
    pattern: tuple[LayerKind, ...] = (LayerKind("attn", "dense"),)
    first_k_dense: int = 0  # leading unscanned dense-attn layers (DeepSeek/Kimi style)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # Dispatch in G independent token groups (vmapped). With the group dim
    # carved out of the batch dim (which is data-sharded), routing/scatter
    # stay shard-local instead of addressing one global [E*C, d] buffer —
    # the §Perf knob that removes the dispatch-induced gather/all-reduce.
    moe_groups: int = 1
    # Mesh axis to pin the group dim to (with_sharding_constraint); empty =
    # let the partitioner infer. Needs an ambient mesh at trace time.
    moe_group_axis: str = ""

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # misc architecture knobs
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    rope: str = "rope"  # rope | mrope | none
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    rope_theta: float = 10_000.0
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    embed_inputs: bool = True  # False: model consumes precomputed embeddings (stub frontend)
    logit_softcap: float = 0.0
    max_seq_len: int = 131_072

    # distribution / memory profile
    fsdp: bool = False  # shard params over "data" too (ZeRO-3 style)
    optimizer: str = "adamw"  # adamw | adafactor
    remat: str = "none"  # none | full | dots
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Unroll the layer scan at lowering time. The dry-run sets this so XLA's
    # cost analysis counts every layer (while-loop bodies are costed once).
    unroll_layers: bool = False
    # Chunked-vocab cross-entropy (0 = off): computes the LM loss in an
    # online-logsumexp scan over vocab chunks of this size, so the [B,S,V]
    # f32 logits tensor is never materialized — a §Perf memory knob.
    ce_vocab_chunk: int = 0
    # Explicit ZeRO-3 weight gathering (§Perf): constrain FSDP-sharded
    # params to drop their data-axis shards inside the step, so the SPMD
    # partitioner all-gathers the (small) WEIGHTS instead of all-reducing
    # partial-sum ACTIVATIONS when the contracting dim is data-sharded.
    # The constraint's autodiff transpose reduce-scatters the gradients —
    # exactly the ZeRO-3 dataflow.
    zero3_gather: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/head shard
        evenly over any mesh axis combination (pjit arguments must divide).
        Real token ids stay < vocab_size; padding columns ride in softmax."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(k.mixer != "attn" for k in self.pattern) and self.first_k_dense == 0

    @property
    def has_subquadratic_path(self) -> bool:
        """True if long-context decode is feasible (ssm / hybrid / linear attn)."""
        return any(k.mixer == "mamba" for k in self.pattern)

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - self.first_k_dense
        assert body % len(self.pattern) == 0, (self.name, body, len(self.pattern))
        return body // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d
        dense_ffn = 3 * d * f if self.act == "swiglu" else 2 * d * f
        moe_ffn = self.n_experts * 3 * d * f + d * self.n_experts  # + router
        moe_ffn += self.n_shared_experts * 3 * d * f
        mamba = (
            d * (2 * self.d_inner + 2 * self.ssm_state + self.n_ssm_heads)
            + self.d_inner * d
            + self.ssm_conv * (self.d_inner + 2 * self.ssm_state)
            + 2 * self.n_ssm_heads
            + self.d_inner
        )
        total = 0
        kinds = [LayerKind("attn", "dense")] * self.first_k_dense + list(self.pattern) * self.n_repeats
        for k in kinds:
            total += attn if k.mixer == "attn" else mamba
            total += {"dense": dense_ffn, "moe": moe_ffn, "none": 0}[k.ffn]
            total += 2 * d  # two norms (approx; non-param LN counted anyway)
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_decoder:
            enc = self.n_encoder_layers * (attn + dense_ffn + 2 * d)
            xattn = self.n_layers * (attn + d)  # cross-attn per decoder layer
            total += enc + xattn
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        per_expert = 3 * d * f
        inactive = (self.n_experts - self.experts_per_token) * per_expert
        n_moe_layers = sum(1 for k in self.pattern if k.ffn == "moe") * self.n_repeats
        return self.n_params() - n_moe_layers * inactive


# ---------------------------------------------------------------------------
# Input shape cells (assigned): seq_len x global_batch
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[str]:
    """Applicable shape cells. long_500k only for sub-quadratic archs
    (full-attention skips are recorded in DESIGN.md / EXPERIMENTS.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.has_subquadratic_path:
        cells.append("long_500k")
    return cells


# ---------------------------------------------------------------------------
ARCHS: dict[str, str] = {  # arch id -> module defining CONFIG
    "olmo-1b": "repro.configs.olmo_1b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(ARCHS[name])
    return mod.CONFIG


def all_archs() -> list[str]:
    return sorted(ARCHS)


def tiny(cfg: ArchConfig, **overrides: Any) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes: dict[str, Any] = dict(
        n_layers=len(cfg.pattern) + cfg.first_k_dense,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.is_moe:
        changes.update(n_experts=4, experts_per_token=2)
    if cfg.rope == "mrope":
        changes.update(mrope_sections=(2, 3, 3))  # sums to d_head//2 = 8
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
    if cfg.encoder_decoder:
        changes.update(n_encoder_layers=1)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
