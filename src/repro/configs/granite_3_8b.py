"""Granite-3 8B [hf:ibm-granite/granite-3.0-8b-base] — dense GQA kv=8."""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-8b-base",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    pattern=(LayerKind("attn", "dense"),),
    norm="rmsnorm",
    act="swiglu",
    optimizer="adamw",
    remat="dots",
)
