"""Grok-1 314B [hf:xai-org/grok-1; unverified] — MoE 8 experts top-2, GQA kv=8.

8 experts < 16-way model axis => expert-TP sharding (each expert's d_ff split
across 2 model shards; see models/moe.py virtual-expert layout). FSDP over
the data axis; Adafactor (Adam fp32 states would not fit 16 GB/chip).
"""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    source="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    pattern=(LayerKind("attn", "moe"),),
    n_experts=8,
    experts_per_token=2,
    norm="rmsnorm",
    act="swiglu",
    fsdp=True,
    optimizer="adafactor",
    remat="full",
)
