"""InternLM2-20B [arXiv:2403.17297; hf] — dense GQA kv=8."""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    source="arXiv:2403.17297",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    pattern=(LayerKind("attn", "dense"),),
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1e6,
    optimizer="adamw",
    remat="dots",
)
