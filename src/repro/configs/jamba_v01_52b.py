"""Jamba v0.1 52B [arXiv:2403.19887; hf] — hybrid Mamba+attention 1:7, MoE 16e top-2
every other layer.

Repeating 8-layer unit: attention at offset 4, MoE at odd offsets (period 2,
offset 1) — matches the HF config (attn_layer_period=8/offset=4,
expert_layer_period=2/offset=1). SSM blocks use the Mamba2/SSD formulation
(state 128) instead of Mamba1 (state 16): SSD is the TPU/MXU-friendly dual
[arXiv:2405.21060]; noted as a hardware adaptation in DESIGN.md.
"""
from repro.configs.base import ArchConfig, LayerKind

_M, _A = "mamba", "attn"
_D, _E = "dense", "moe"
_PATTERN = tuple(
    LayerKind(_A if i == 4 else _M, _E if i % 2 == 1 else _D) for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PATTERN,
    n_experts=16,
    experts_per_token=2,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    norm="rmsnorm",
    act="swiglu",
    rope="none",  # Jamba uses no positional encoding in attn layers
    fsdp=True,
    optimizer="adamw",
    remat="dots",
)
