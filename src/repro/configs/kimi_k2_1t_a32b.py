"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified] — MoE 384 experts top-8 + 1 shared,
first dense layer, GQA kv=8, head_dim 128.

1T params: EP 24 experts/model-shard + FSDP over data (256-way total), bf16
params + Adafactor — Adam fp32 m/v at 1T would need ~47 GB/chip vs 16 GB HBM.
"""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,  # 7168/64=112; K2 uses 128
    d_ff=2048,
    vocab_size=163840,
    pattern=(LayerKind("attn", "moe"),),
    first_k_dense=1,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    norm="rmsnorm",
    act="swiglu",
    fsdp=True,
    optimizer="adafactor",
    param_dtype="bfloat16",
    remat="full",
)
