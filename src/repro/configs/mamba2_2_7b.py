"""Mamba2-2.7B [arXiv:2405.21060; unverified] — attention-free SSD (state-space duality)."""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerKind("mamba", "none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    norm="rmsnorm",
    rope="none",
    tie_embeddings=True,
    optimizer="adamw",
    remat="none",
)
