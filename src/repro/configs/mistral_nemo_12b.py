"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407] — GQA kv=8, head_dim=128, 128k ctx."""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,  # explicit: 5120/32=160 but Nemo uses 128
    d_ff=14336,
    vocab_size=131072,
    pattern=(LayerKind("attn", "dense"),),
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1e6,
    max_seq_len=131072,
    optimizer="adamw",
    remat="dots",
)
