"""OLMo-1B [arXiv:2402.00838; hf] — dense, non-parametric LayerNorm, MHA (kv=16=H)."""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    source="arXiv:2402.00838",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    pattern=(LayerKind("attn", "dense"),),
    norm="nonparametric_ln",
    act="swiglu",
    tie_embeddings=True,
    optimizer="adamw",
    remat="none",
)
