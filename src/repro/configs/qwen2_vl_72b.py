"""Qwen2-VL 72B [arXiv:2409.12191; hf] — VLM transformer BACKBONE only.

The vision frontend (dynamic-resolution ViT) is a STUB: input_specs() provides
precomputed patch/text embeddings [B, S, d_model] plus 3D M-RoPE position ids
(temporal/height/width rotary sections 16/24/24 over half of head_dim 128).
"""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    pattern=(LayerKind("attn", "dense"),),
    norm="rmsnorm",
    act="swiglu",
    rope="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    embed_inputs=False,  # frontend stub supplies embeddings
    fsdp=True,
    optimizer="adamw",
    remat="full",
)
