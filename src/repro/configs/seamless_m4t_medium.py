"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder, multimodal.

Backbone only: the speech frontend is a STUB; input_specs() provides
precomputed frame embeddings [B, S_src, d_model] for the encoder. The decoder
embeds target tokens (vocab 256206) and cross-attends to encoder output.
12 encoder + 12 decoder layers, d_model 1024, 16 heads (kv=16 => MHA),
d_ff 4096, GELU FFN, parametric LayerNorm. RoPE substituted for the original
learned positions (adaptation noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig, LayerKind

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    pattern=(LayerKind("attn", "dense"),),
    norm="layernorm",
    act="gelu",
    encoder_decoder=True,
    n_encoder_layers=12,
    embed_inputs=False,  # encoder side consumes frame embeddings
    optimizer="adamw",
    remat="none",
)
