from repro.core.box import Box, TaskSpec
from repro.core.cache import EwmaCostStore, ResultCache, cache_key
from repro.core.cost import CostModel
from repro.core.executor import SweepExecutor, SweepResult, SweepStats
from repro.core.metrics import Samples, compute_metrics, known_metrics
from repro.core.platform import (
    Platform,
    get_platform,
    known_platforms,
    register_platform,
    remote_platform,
)
from repro.core.report import merge_shard_reports
from repro.core.runner import Runner, RunnerResult
from repro.core.scheduler import FleetScheduler, Outcome, Sink, WorkItem
from repro.core.shard import (
    ShardSpec,
    cost_partition,
    cost_shard_map,
    partition,
    resolve_auto_weights,
    shard_of,
)
from repro.core.task import Task, TaskContext, TestResult

__all__ = [
    "Box", "TaskSpec", "Samples", "compute_metrics", "known_metrics",
    "Runner", "RunnerResult", "Task", "TaskContext", "TestResult",
    "SweepExecutor", "SweepResult", "SweepStats",
    "ResultCache", "cache_key", "CostModel", "EwmaCostStore",
    "FleetScheduler", "Sink", "WorkItem", "Outcome",
    "Platform", "get_platform", "known_platforms", "register_platform",
    "remote_platform",
    "ShardSpec", "shard_of", "partition", "cost_shard_map", "cost_partition",
    "resolve_auto_weights",
    "merge_shard_reports",
]
