from repro.core.box import Box, TaskSpec
from repro.core.metrics import Samples, compute_metrics, known_metrics
from repro.core.runner import Runner, RunnerResult
from repro.core.task import Task, TaskContext, TestResult

__all__ = [
    "Box", "TaskSpec", "Samples", "compute_metrics", "known_metrics",
    "Runner", "RunnerResult", "Task", "TaskContext", "TestResult",
]
