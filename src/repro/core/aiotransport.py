"""Async multiplexed fleet transport: one event loop, one socket per worker.

The threaded :class:`repro.core.remote.RemoteTransport` spends one blocked
client thread AND one TCP connection per in-flight unit — a fleet of N
workers at capacity C costs the runner O(N x C) threads before a single
unit executes, which is exactly the host-side TCP overhead wall PnO-TCP
documents (PAPERS.md) and ROADMAP open item 2 names.  This module is the
multiplexed replacement:

  * ONE daemon IO thread runs a ``selectors`` event loop over every worker
    connection — O(endpoints) file descriptors, O(1) threads, whatever the
    fleet's total capacity;
  * one PERSISTENT non-blocking connection per endpoint carries every unit
    bound for that worker, each request frame tagged with a transport-unique
    ``"id"`` (see the request-id framing note in :mod:`repro.core.remote`);
    responses demux by id, so hundreds of units interleave in flight;
  * :meth:`AsyncFleetTransport.submit` is callback-based (the scheduler's
    async sinks complete units from the loop thread);
    :meth:`AsyncFleetTransport.request` wraps it synchronously for
    plain call sites.

Failure semantics mirror the threaded transport exactly — they are the
contract the fault soak pins:

  * **per-request deadlines**: an expired request fails with
    :class:`~repro.core.remote.WorkerUnreachable` and is NOT re-sent (the
    worker may still be grinding on it); the connection stays up, and a
    late response to an expired id is dropped on arrival;
  * **connection loss** (reset, EOF, corrupt frame): every request pending
    on that endpoint fails with ``WorkerUnreachable``; the next submit
    re-dials;
  * **connect retry**: dialing retries ``CONNECT_RETRIES`` times with the
    same jittered exponential backoff as the threaded path, without ever
    blocking the loop (non-blocking ``connect_ex`` + writability events).

Unlike ``RemoteTransport`` there is NO client-side capacity gate here: how
many units may be in flight per endpoint is the scheduler's admission
decision (the async sink's ``capacity`` / ``--max-inflight``), not the
transport's — the transport just multiplexes whatever it is given.
"""
from __future__ import annotations

import errno
import itertools
import json
import random
import selectors
import socket
import threading
import time
from collections import deque
from typing import Any, Callable

from repro.core.remote import (
    CONNECT_BACKOFF_S,
    CONNECT_RETRIES,
    CONNECT_TIMEOUT_S,
    REQUEST_TIMEOUT_S,
    WorkerUnreachable,
    parse_endpoint,
)

#: Upper bound on one recv() slurp; frames are small, responses may carry
#: sample arrays, so read generously per readiness event.
_RECV_CHUNK = 1 << 16


class _Request:
    """One in-flight (or queued) request."""

    __slots__ = ("rid", "endpoint", "data", "deadline", "callback")

    def __init__(
        self,
        rid: str,
        endpoint: str,
        data: bytes,
        deadline: float,
        callback: Callable[[dict[str, Any] | None, Exception | None], None],
    ):
        self.rid = rid
        self.endpoint = endpoint
        self.data = data
        self.deadline = deadline  # monotonic
        self.callback = callback


class _Endpoint:
    """Loop-thread-owned connection state for one worker endpoint."""

    __slots__ = (
        "endpoint", "host", "port", "sock", "state", "rbuf", "wbuf",
        "pending", "backlog", "attempts", "retry_at", "connect_deadline",
    )

    def __init__(self, endpoint: str):
        self.endpoint = endpoint
        self.host, self.port = parse_endpoint(endpoint)
        self.sock: socket.socket | None = None
        # idle -> connecting -> connected; retry-wait between dial attempts.
        self.state = "idle"
        self.rbuf = bytearray()
        self.wbuf = bytearray()
        self.pending: dict[str, _Request] = {}  # sent (or sending), awaiting reply
        self.backlog: list[_Request] = []  # submitted while not yet connected
        self.attempts = 0
        self.retry_at = 0.0
        self.connect_deadline = 0.0


class AsyncFleetTransport:
    """Multiplexing client for many worker endpoints over one event loop.

    Thread-safe: ``submit``/``request``/``drop``/``close`` may be called
    from any thread; all socket work happens on the single loop thread.
    Callbacks run ON the loop thread — keep them short (the scheduler's
    completion bookkeeping), never block in them.
    """

    def __init__(self, name: str = "aio-transport"):
        self._sel = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._sel.register(self._wake_r, selectors.EVENT_READ, ("wake", None))
        self._inbox: deque[tuple[str, Any]] = deque()
        self._inbox_lock = threading.Lock()
        self._ids = itertools.count(1)
        self._endpoints: dict[str, _Endpoint] = {}
        self._stopping = False
        self._thread = threading.Thread(target=self._loop, daemon=True, name=name)
        self._thread.start()

    # -- public API (any thread) -------------------------------------------
    def submit(
        self,
        endpoint: str,
        obj: dict[str, Any],
        timeout: float | None = None,
        callback: Callable[[dict[str, Any] | None, Exception | None], None] | None = None,
    ) -> str:
        """Send one request; ``callback(resp, exc)`` fires exactly once.

        ``resp`` is the decoded response dict on success, else ``exc`` is a
        :class:`WorkerUnreachable` (deadline, connect failure, connection
        loss).  Returns the assigned request id.
        """
        parse_endpoint(endpoint)  # validate before the loop ever sees junk
        rid = f"r{next(self._ids)}"
        data = (json.dumps({**obj, "id": rid}, default=str) + "\n").encode()
        deadline = time.monotonic() + (REQUEST_TIMEOUT_S if timeout is None else float(timeout))
        req = _Request(rid, endpoint, data, deadline, callback or (lambda r, e: None))
        self._post(("submit", req))
        return rid

    def request(
        self, endpoint: str, obj: dict[str, Any], timeout: float | None = None
    ) -> dict[str, Any]:
        """Synchronous convenience wrapper around :meth:`submit`."""
        done = threading.Event()
        box: dict[str, Any] = {}

        def cb(resp: dict[str, Any] | None, exc: Exception | None) -> None:
            box["resp"], box["exc"] = resp, exc
            done.set()

        self.submit(endpoint, obj, timeout=timeout, callback=cb)
        done.wait()  # bounded: the loop enforces the deadline
        if box["exc"] is not None:
            raise box["exc"]
        return box["resp"]

    def request_many(
        self,
        requests: "list[tuple[str, dict[str, Any]]]",
        timeout: float | None = None,
    ) -> "list[tuple[dict[str, Any] | None, Exception | None]]":
        """One concurrent wave of requests; block until every slot settles.

        ``requests`` is ``[(endpoint, obj), ...]``; the return value is a
        same-order list of ``(resp, exc)`` pairs — exactly one of the two is
        non-``None`` per slot.  All requests ride the shared loop thread, so
        a wave over N registry replicas costs one round trip, not N, and a
        dead replica burns its own deadline without delaying the others.
        Synchronous submit errors (a malformed endpoint) land in that slot's
        ``exc`` instead of aborting the wave.
        """
        if not requests:
            return []
        results: list[tuple[dict[str, Any] | None, Exception | None]] = [
            (None, None)
        ] * len(requests)
        remaining = len(requests)
        lock = threading.Lock()
        done = threading.Event()

        def settle(i: int, resp: dict[str, Any] | None, exc: Exception | None) -> None:
            nonlocal remaining
            with lock:
                results[i] = (resp, exc)
                remaining -= 1
                if remaining == 0:
                    done.set()

        for i, (endpoint, obj) in enumerate(requests):
            try:
                self.submit(
                    endpoint,
                    obj,
                    timeout=timeout,
                    callback=lambda r, e, _i=i: settle(_i, r, e),
                )
            except Exception as exc:  # malformed endpoint: settle the slot
                settle(i, None, exc)
        done.wait()  # bounded: the loop enforces every deadline
        return results

    def prewarm(self, endpoints: list[str]) -> None:
        """Start dialing every endpoint now, all concurrently, through the
        one event loop.

        Without this the first request to each endpoint pays its own dial;
        a caller that pings N workers serially at cold start pays N round
        trips of connect latency back-to-back.  Prewarming turns the
        fleet-wide cold start into ONE dial wave: every socket is opened
        non-blocking in the same loop pass and the handshakes overlap.
        Idempotent — endpoints already connected (or mid-dial) are left
        alone, and requests submitted while a dial is in flight just join
        that endpoint's backlog as usual.
        """
        for ep in endpoints:
            parse_endpoint(ep)
        self._post(("prewarm", list(endpoints)))

    def drop(self, endpoint: str) -> None:
        """Close the endpoint's connection and fail its pending requests
        (worker shut down; a later submit re-dials from scratch)."""
        self._post(("drop", endpoint))

    def close(self) -> None:
        """Stop the loop; every pending request fails as unreachable."""
        self._post(("close", None))
        self._thread.join(timeout=5.0)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def _post(self, msg: tuple[str, Any]) -> None:
        with self._inbox_lock:
            self._inbox.append(msg)
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass  # loop already torn down; close() drains regardless

    # -- event loop (loop thread only) --------------------------------------
    def _loop(self) -> None:
        try:
            while True:
                self._drain_inbox()
                if self._stopping:
                    return
                timeout = self._process_timers()
                for key, mask in self._sel.select(timeout):
                    tag, ep = key.data
                    if tag == "wake":
                        try:
                            while self._wake_r.recv(4096):
                                pass
                        except (BlockingIOError, InterruptedError):
                            pass
                        except OSError:
                            return
                    elif tag == "conn":
                        self._service(ep, mask)
        finally:
            self._teardown()

    def _drain_inbox(self) -> None:
        while True:
            with self._inbox_lock:
                if not self._inbox:
                    return
                op, arg = self._inbox.popleft()
            if op == "submit":
                self._handle_submit(arg)
            elif op == "prewarm":
                for endpoint in arg:
                    es = self._endpoints.get(endpoint)
                    if es is None:
                        es = self._endpoints[endpoint] = _Endpoint(endpoint)
                    if es.state == "idle":
                        self._start_connect(es)
            elif op == "drop":
                es = self._endpoints.get(arg)
                if es is not None:
                    self._fail_endpoint(es, "dropped by client", reconnect=False)
            elif op == "close":
                self._stopping = True

    def _handle_submit(self, req: _Request) -> None:
        es = self._endpoints.get(req.endpoint)
        if es is None:
            es = self._endpoints[req.endpoint] = _Endpoint(req.endpoint)
        if es.state == "connected":
            es.pending[req.rid] = req
            es.wbuf += req.data
            self._update_interest(es)
        else:
            es.backlog.append(req)
            if es.state == "idle":
                self._start_connect(es)
            # connecting / retry-wait: the backlog flushes on success and
            # fails with everything else after the final attempt.

    # -- connecting ----------------------------------------------------------
    def _start_connect(self, es: _Endpoint) -> None:
        try:
            info = socket.getaddrinfo(
                es.host, es.port, type=socket.SOCK_STREAM
            )[0]
        except OSError as e:
            self._connect_failed(es, e)
            return
        af, socktype, proto, _, addr = info
        sock = socket.socket(af, socktype, proto)
        sock.setblocking(False)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        err = sock.connect_ex(addr)
        if err not in (
            0,
            errno.EINPROGRESS,
            errno.EWOULDBLOCK,
            getattr(errno, "WSAEWOULDBLOCK", errno.EWOULDBLOCK),
        ):
            sock.close()
            self._connect_failed(es, OSError(err, "connect failed"))
            return
        es.sock = sock
        es.state = "connecting"
        es.connect_deadline = time.monotonic() + CONNECT_TIMEOUT_S
        self._sel.register(sock, selectors.EVENT_WRITE, ("conn", es))

    def _connect_finished(self, es: _Endpoint) -> None:
        err = es.sock.getsockopt(socket.SOL_SOCKET, socket.SO_ERROR)
        if err != 0:
            self._unregister(es)
            self._connect_failed(es, OSError(err, "connect failed"))
            return
        es.state = "connected"
        es.attempts = 0
        for req in es.backlog:
            es.pending[req.rid] = req
            es.wbuf += req.data
        es.backlog.clear()
        self._update_interest(es)

    def _connect_failed(self, es: _Endpoint, exc: Exception) -> None:
        es.attempts += 1
        if es.attempts >= max(1, CONNECT_RETRIES):
            es.attempts = 0
            self._fail_endpoint(es, f"unreachable: {exc}", reconnect=False)
            return
        es.state = "retry-wait"
        es.retry_at = (
            time.monotonic()
            + CONNECT_BACKOFF_S * (2 ** (es.attempts - 1))
            + random.uniform(0.0, CONNECT_BACKOFF_S)
        )

    # -- IO ------------------------------------------------------------------
    def _service(self, es: _Endpoint, mask: int) -> None:
        if es.state == "connecting":
            if mask & selectors.EVENT_WRITE:
                self._connect_finished(es)
            return
        if es.state != "connected":
            return
        if mask & selectors.EVENT_READ:
            self._readable(es)
        if es.state == "connected" and mask & selectors.EVENT_WRITE:
            self._writable(es)

    def _readable(self, es: _Endpoint) -> None:
        try:
            data = es.sock.recv(_RECV_CHUNK)
        except (BlockingIOError, InterruptedError):
            return
        except OSError as e:
            self._fail_endpoint(es, f"recv failed: {e}")
            return
        if not data:
            self._fail_endpoint(es, "connection closed by worker")
            return
        es.rbuf += data
        while True:
            nl = es.rbuf.find(b"\n")
            if nl < 0:
                break
            line = bytes(es.rbuf[:nl]).strip()
            del es.rbuf[: nl + 1]
            if not line:
                continue
            try:
                resp = json.loads(line)
            except json.JSONDecodeError:
                # Corrupt frame (e.g. an injected partial write): nothing on
                # this connection can be trusted to demux anymore.
                self._fail_endpoint(es, "corrupt frame from worker")
                return
            rid = resp.get("id") if isinstance(resp, dict) else None
            req = es.pending.pop(rid, None) if rid is not None else None
            if req is not None:
                self._complete(req, resp, None)
            # else: late reply to an expired/cancelled id — drop it.

    def _writable(self, es: _Endpoint) -> None:
        if es.wbuf:
            try:
                n = es.sock.send(bytes(es.wbuf))
                del es.wbuf[:n]
            except (BlockingIOError, InterruptedError):
                return
            except OSError as e:
                self._fail_endpoint(es, f"send failed: {e}")
                return
        self._update_interest(es)

    def _update_interest(self, es: _Endpoint) -> None:
        events = selectors.EVENT_READ
        if es.wbuf:
            events |= selectors.EVENT_WRITE
        try:
            self._sel.modify(es.sock, events, ("conn", es))
        except KeyError:
            self._sel.register(es.sock, events, ("conn", es))

    # -- timers --------------------------------------------------------------
    def _process_timers(self) -> float | None:
        """Fire due deadlines/retries; return the select timeout to the next."""
        now = time.monotonic()
        next_at: float | None = None
        for es in list(self._endpoints.values()):
            if es.state == "retry-wait":
                if now >= es.retry_at:
                    self._start_connect(es)
                else:
                    next_at = es.retry_at if next_at is None else min(next_at, es.retry_at)
            if es.state == "connecting":
                if now >= es.connect_deadline:
                    self._unregister(es)
                    self._connect_failed(es, TimeoutError("connect timed out"))
                else:
                    next_at = (
                        es.connect_deadline
                        if next_at is None
                        else min(next_at, es.connect_deadline)
                    )
            # Deadline sweep over pending + backlog.  Expiry is FINAL for
            # the request but not the connection: the worker may still be
            # executing (that is the hang-detection contract) — its late
            # reply is dropped by id, everything else keeps flowing.
            expired = [r for r in es.pending.values() if now >= r.deadline]
            for req in expired:
                del es.pending[req.rid]
                self._complete(
                    req, None,
                    WorkerUnreachable(
                        f"worker {es.endpoint} unreachable: deadline expired "
                        f"with the unit still in flight"
                    ),
                )
            still: list[_Request] = []
            for req in es.backlog:
                if now >= req.deadline:
                    self._complete(
                        req, None,
                        WorkerUnreachable(
                            f"worker {es.endpoint} unreachable: deadline expired "
                            f"before a connection was established"
                        ),
                    )
                else:
                    still.append(req)
            es.backlog = still
            for req in itertools.chain(es.pending.values(), es.backlog):
                next_at = req.deadline if next_at is None else min(next_at, req.deadline)
        if next_at is None:
            return None
        return max(0.0, min(next_at - time.monotonic(), 1.0))

    # -- failure/teardown ----------------------------------------------------
    def _unregister(self, es: _Endpoint) -> None:
        if es.sock is not None:
            try:
                self._sel.unregister(es.sock)
            except (KeyError, ValueError):
                pass
            try:
                es.sock.close()
            except OSError:
                pass
            es.sock = None

    def _fail_endpoint(self, es: _Endpoint, reason: str, reconnect: bool = True) -> None:
        """Connection-level failure: everything in flight on it fails."""
        self._unregister(es)
        es.state = "idle"
        es.rbuf.clear()
        es.wbuf.clear()
        failed = list(es.pending.values()) + es.backlog
        es.pending.clear()
        es.backlog.clear()
        exc = WorkerUnreachable(f"worker {es.endpoint} unreachable: {reason}")
        for req in failed:
            self._complete(req, None, exc)
        if not reconnect:
            self._endpoints.pop(es.endpoint, None)

    def _complete(
        self, req: _Request, resp: dict[str, Any] | None, exc: Exception | None
    ) -> None:
        try:
            req.callback(resp, exc)
        except Exception:  # noqa: BLE001 - a sink callback bug must not kill the loop
            import traceback

            traceback.print_exc()

    def _teardown(self) -> None:
        for es in list(self._endpoints.values()):
            self._fail_endpoint(es, "transport closed", reconnect=False)
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
        try:
            self._sel.close()
        except OSError:
            pass


# -- process-wide singleton ---------------------------------------------------
_GLOBAL: AsyncFleetTransport | None = None
_global_lock = threading.Lock()


def get_async_transport() -> AsyncFleetTransport:
    """The process-wide loop (started lazily; restarted if closed)."""
    global _GLOBAL
    with _global_lock:
        if _GLOBAL is None or not _GLOBAL.alive:
            _GLOBAL = AsyncFleetTransport()
        return _GLOBAL


__all__ = ["AsyncFleetTransport", "get_async_transport"]
