"""Measurement boxes.

A *box* is the user-facing declaration of a measurement job (paper §3.2,
Fig. 2): a JSON object naming tasks, per-task parameter lists, and metrics.
The framework expands the cross-product of each task's parameter lists into
concrete tests; metrics are NOT cross-joined (one test may report several).
"""
from __future__ import annotations

import itertools
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any


@dataclass
class TaskSpec:
    task: str
    params: dict[str, list[Any]] = field(default_factory=dict)
    metrics: tuple[str, ...] = ()

    def expand(self) -> list[dict[str, Any]]:
        """Cross-product of parameter value lists -> list of concrete tests."""
        if not self.params:
            return [{}]
        keys = sorted(self.params)
        value_lists = []
        for k in keys:
            v = self.params[k]
            vals = list(v) if isinstance(v, (list, tuple)) else [v]
            # Duplicate declared values would generate identical tests; dedupe
            # preserving order so each expanded test is unique.
            vals = list(dict.fromkeys(vals))
            value_lists.append(vals)
        return [dict(zip(keys, combo)) for combo in itertools.product(*value_lists)]


@dataclass
class Box:
    name: str
    tasks: list[TaskSpec]
    # Optional sweep declaration: named execution platforms this box should
    # run across (see repro.core.platform). Empty means "whatever the
    # executor was configured with".
    platforms: tuple[str, ...] = ()

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "Box":
        specs = []
        for t in d.get("tasks", []):
            if isinstance(t, str):
                t = {"task": t}
            specs.append(
                TaskSpec(
                    task=t["task"],
                    params={k: (v if isinstance(v, list) else [v]) for k, v in t.get("params", {}).items()},
                    metrics=tuple(t.get("metrics", ())),
                )
            )
        if not specs:
            raise ValueError(f"box {d.get('name', '?')!r} declares no tasks")
        return Box(
            name=d.get("name", "box"),
            tasks=specs,
            platforms=tuple(d.get("platforms", ())),
        )

    @staticmethod
    def from_json(text: str) -> "Box":
        return Box.from_dict(json.loads(text))

    @staticmethod
    def load(path: str | Path) -> "Box":
        return Box.from_json(Path(path).read_text())

    def total_tests(self) -> int:
        return sum(len(s.expand()) for s in self.tasks)
