"""Persistent result cache: re-runs skip already-measured points.

A sweep over (task x params x platform) is expensive and — for fixed seed
data and iteration counts — deterministic enough to reuse.  The cache maps a
content key over everything that identifies a measurement::

    sha256(task, params, platform identity, iters, warmup, metrics,
           task-source fingerprint)

to the computed metrics dict of the finished test.  Storage is one JSON
file (atomic tmp+rename writes) so the cache survives crashes, diffs
cleanly, and can be inspected/deleted by hand.  Anything that changes the
measurement — different parameter values, iteration counts, platform, the
cache format version — changes the key or invalidates the file wholesale.

Entries also record the measured wall time (``elapsed_s``) of the unit that
produced them; :class:`repro.core.cost.CostModel` feeds these back into
weighted sharding and scheduling on later runs.

Long-lived caches are bounded by an optional eviction policy: construct
with ``max_entries=`` and/or ``max_age_s=`` and ``flush()`` trims the
oldest ``saved_unix`` entries (age first, then count) before writing.
Eviction would throw the scheduling evidence away with the raw entries, so
each cache keeps an :class:`EwmaCostStore` sidecar (``costs.json`` next to
the cache file): a bounded EWMA of wall cost per (task, platform), updated
on every ``put`` and flushed with the cache, surviving both eviction and
``clear()``.  A second sidecar, :class:`EndpointHealthStore`
(``health.json``), keeps per-worker-endpoint transport health — consecutive
failures, latency EWMA, last-seen — so chronically wedged workers are
deprioritized at the start of the NEXT run too (cross-run straggler
blacklisting).

All on-disk writes go through a fresh ``mkstemp`` file in the target
directory followed by ``os.replace``, so neither a crash mid-write nor two
processes flushing the same path concurrently can leave a truncated or
interleaved JSON file behind.

The cache is also the coordination surface for **work stealing** between
shard runners (``SweepExecutor(steal=True)``): a runner whose own slice
drained claims a sibling shard's leftover unit by creating a *claim record*
— an ``O_EXCL`` exclusive-create file named by the unit's shard key under
``<cache>.claims/`` — which is a true filesystem compare-and-swap (exactly
one runner's create succeeds).  The winner executes the unit and
``publish``es the result (a read-merge-write of that single key, so
concurrent publishers never clobber each other), the owner sees the claim
and ``refresh``es the key from disk instead of waiting; if both end up
executing anyway, the duplicate dedupes through the shared cache-key
identity exactly like a lost speculation race.  ``clear()`` removes claim
records with the entries, so a fresh pass starts with a clean steal table.

Thread-safe: the executor calls ``get``/``put`` from worker threads.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import tempfile
import threading
import time
from pathlib import Path
from typing import Any

CACHE_VERSION = 1
COSTS_VERSION = 1
HEALTH_VERSION = 1

#: Smoothing factor shared by every wall-cost EWMA (sidecar + worker pings).
EWMA_ALPHA = 0.25

#: Consecutive transport failures before an endpoint is blacklisted at
#: startup (cross-run straggler/wedge evidence in the health sidecar).
BLACKLIST_AFTER = 3


def _atomic_write_text(path: Path, text: str) -> None:
    """Crash- and concurrency-safe file replace.

    The temp file is unique per writer (``mkstemp``), so two processes
    flushing the same path can never interleave bytes in a shared ``.tmp``;
    ``os.replace`` is atomic on POSIX and Windows, so readers only ever see
    a complete old or complete new file.
    """
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class EwmaCostStore:
    """Persistent EWMA wall cost per (task, platform) — the ``costs.json``
    sidecar of a :class:`ResultCache`.

    The cache records exact ``elapsed_s`` per entry, but eviction discards
    that scheduling evidence with the entries.  This store keeps a bounded
    summary instead — one exponentially-weighted moving average per
    (task, platform) — so :class:`repro.core.cost.CostModel` still has
    per-platform evidence after the raw points are gone, and ``@auto``
    shard weights have something to calibrate against on a fresh fleet.
    """

    def __init__(self, path: str | Path, alpha: float = EWMA_ALPHA):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.path = Path(path)
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._entries: dict[tuple[str, str], dict[str, float]] = {}
        self._dirty = False
        self._load()

    def _load(self) -> None:
        try:
            d = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return  # missing/corrupt -> start empty, overwrite on flush
        if d.get("version") != COSTS_VERSION:
            return
        tasks = d.get("entries")
        if not isinstance(tasks, dict):
            return
        for task, platforms in tasks.items():
            if not isinstance(platforms, dict):
                continue
            for platform, e in platforms.items():
                try:
                    ewma = float(e["ewma_s"])
                    n = int(e.get("n", 1))
                except (KeyError, TypeError, ValueError):
                    continue
                if ewma > 0 and math.isfinite(ewma):
                    self._entries[(str(task), str(platform))] = {"ewma_s": ewma, "n": max(1, n)}

    def observe(self, task: str, platform: str, elapsed_s: Any) -> None:
        """Fold one measured unit wall time into the (task, platform) EWMA."""
        try:
            x = float(elapsed_s)
        except (TypeError, ValueError):
            return
        if not task or x <= 0 or not math.isfinite(x):
            return
        key = (str(task), str(platform))
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                self._entries[key] = {"ewma_s": x, "n": 1}
            else:
                e["ewma_s"] = self.alpha * x + (1.0 - self.alpha) * e["ewma_s"]
                e["n"] += 1
            self._dirty = True

    def get(self, task: str, platform: str) -> float | None:
        with self._lock:
            e = self._entries.get((task, platform))
            return float(e["ewma_s"]) if e else None

    def snapshot(self) -> dict[tuple[str, str], dict[str, float]]:
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    def flush(self) -> None:
        with self._lock:
            if not self._dirty:
                return
            tasks: dict[str, dict[str, dict[str, float]]] = {}
            for (task, platform), e in sorted(self._entries.items()):
                tasks.setdefault(task, {})[platform] = dict(e)
            payload = {"version": COSTS_VERSION, "alpha": self.alpha, "entries": tasks}
            _atomic_write_text(self.path, json.dumps(payload, indent=1, default=str))
            self._dirty = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class EndpointHealthStore:
    """Persistent per-endpoint transport health — the ``health.json``
    sidecar next to ``costs.json``.

    Where the cost store answers "how expensive is this unit here", this
    store answers "can I trust this endpoint at all": per worker endpoint
    it keeps the consecutive transport-failure count, an EWMA of observed
    request latency, and when it last succeeded.  Only *transport*-level
    evidence feeds it (``WorkerUnreachable``: dead, hung past deadline,
    connection refused/corrupt) — a worker that cleanly reports a task
    error is a healthy endpoint and must not lose standing.

    The payoff is cross-run: a worker that was wedged last run starts this
    run with ``consecutive_failures >= BLACKLIST_AFTER`` and is
    deprioritized before it can eat another sweep's first wave.  One
    success resets the streak (recovery is cheap, and the EWMA still
    remembers the slowness).
    """

    def __init__(self, path: str | Path, alpha: float = EWMA_ALPHA):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.path = Path(path)
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._entries: dict[str, dict[str, Any]] = {}
        self._dirty = False
        self._load()

    def _load(self) -> None:
        try:
            d = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return  # missing/corrupt -> start empty, overwrite on flush
        if d.get("version") != HEALTH_VERSION:
            return
        entries = d.get("entries")
        if not isinstance(entries, dict):
            return
        for endpoint, e in entries.items():
            if not isinstance(e, dict):
                continue
            try:
                rec = {
                    "consecutive_failures": max(0, int(e.get("consecutive_failures", 0))),
                    "failures": max(0, int(e.get("failures", 0))),
                    "successes": max(0, int(e.get("successes", 0))),
                    "ewma_latency_s": (
                        float(e["ewma_latency_s"])
                        if e.get("ewma_latency_s") is not None
                        else None
                    ),
                    "last_seen_unix": float(e.get("last_seen_unix", 0.0) or 0.0),
                }
            except (TypeError, ValueError):
                continue
            lat = rec["ewma_latency_s"]
            if lat is not None and (lat <= 0 or not math.isfinite(lat)):
                rec["ewma_latency_s"] = None
            self._entries[str(endpoint)] = rec

    def _rec(self, endpoint: str) -> dict[str, Any]:
        return self._entries.setdefault(
            str(endpoint),
            {
                "consecutive_failures": 0,
                "failures": 0,
                "successes": 0,
                "ewma_latency_s": None,
                "last_seen_unix": 0.0,
            },
        )

    def observe_success(self, endpoint: str, latency_s: Any = None) -> None:
        """A request served cleanly: reset the failure streak, fold latency."""
        with self._lock:
            rec = self._rec(endpoint)
            rec["consecutive_failures"] = 0
            rec["successes"] += 1
            rec["last_seen_unix"] = time.time()
            try:
                x = float(latency_s) if latency_s is not None else None
            except (TypeError, ValueError):
                x = None
            if x is not None and x > 0 and math.isfinite(x):
                prev = rec["ewma_latency_s"]
                rec["ewma_latency_s"] = (
                    x if prev is None else self.alpha * x + (1.0 - self.alpha) * prev
                )
            self._dirty = True

    def observe_failure(self, endpoint: str) -> int:
        """A transport-level failure; returns the new consecutive count."""
        with self._lock:
            rec = self._rec(endpoint)
            rec["consecutive_failures"] += 1
            rec["failures"] += 1
            self._dirty = True
            return int(rec["consecutive_failures"])

    def get(self, endpoint: str) -> dict[str, Any] | None:
        with self._lock:
            rec = self._entries.get(str(endpoint))
            return dict(rec) if rec else None

    def blacklisted(self, endpoint: str, threshold: int = BLACKLIST_AFTER) -> bool:
        """Whether the endpoint's failure streak crosses the threshold."""
        with self._lock:
            rec = self._entries.get(str(endpoint))
            return bool(rec) and int(rec["consecutive_failures"]) >= threshold

    def snapshot(self) -> dict[str, dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    def flush(self) -> None:
        with self._lock:
            if not self._dirty:
                return
            payload = {
                "version": HEALTH_VERSION,
                "alpha": self.alpha,
                "entries": {k: self._entries[k] for k in sorted(self._entries)},
            }
            _atomic_write_text(self.path, json.dumps(payload, indent=1, default=str))
            self._dirty = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


def cache_key(
    task: str,
    params: dict[str, Any],
    platform: dict[str, Any],
    iters: int,
    warmup: int,
    metrics: tuple[str, ...],
    fingerprint: str = "",
    min_time_s: float = 0.0,
) -> str:
    ident = {
        "task": task,
        "params": params,
        "platform": platform,
        "iters": iters,
        "warmup": warmup,
        "metrics": list(metrics),
        # Source fingerprint of the task implementation: cached metrics are
        # only valid while the measuring code is unchanged (Task.source_fingerprint).
        "fingerprint": fingerprint,
    }
    if min_time_s:
        # Part of the measurement identity like iters/warmup; only folded in
        # when set so pre-existing cache entries stay addressable.
        ident["min_time_s"] = min_time_s
    blob = json.dumps(ident, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """On-disk metrics cache; ``None``-safe drop-in is simply not passing one."""

    def __init__(
        self,
        path: str | Path,
        max_entries: int | None = None,
        max_age_s: float | None = None,
        costs_path: str | Path | None = None,
        cost_sidecar: bool = True,
        health_path: str | Path | None = None,
        health_sidecar: bool = True,
    ):
        if max_entries is not None and max_entries < 0:
            raise ValueError(f"max_entries must be >= 0, got {max_entries}")
        if max_age_s is not None and max_age_s < 0:
            raise ValueError(f"max_age_s must be >= 0, got {max_age_s}")
        self.path = Path(path)
        self.max_entries = max_entries
        self.max_age_s = max_age_s
        # Cost-model persistence: EWMA per (task, platform) kept next to the
        # cache so scheduling evidence survives entry eviction.
        self.costs: EwmaCostStore | None = None
        if cost_sidecar:
            self.costs = EwmaCostStore(costs_path or self.path.with_name("costs.json"))
        # Endpoint health persistence: transport-failure streaks + latency
        # EWMAs per worker endpoint, for cross-run straggler blacklisting.
        self.health: EndpointHealthStore | None = None
        if health_sidecar:
            self.health = EndpointHealthStore(
                health_path or self.path.with_name("health.json")
            )
        self._lock = threading.Lock()
        self._entries: dict[str, dict[str, Any]] = {}
        self._dirty = False
        self.hits = 0
        self.misses = 0
        self.evicted = 0
        self._load()

    def _load(self) -> None:
        if not self.path.exists():
            return
        try:
            d = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return  # corrupt/unreadable -> start empty, overwrite on flush
        if d.get("version") != CACHE_VERSION:
            return  # format change invalidates everything
        entries = d.get("entries")
        if isinstance(entries, dict):
            self._entries = entries

    # -- lookup ------------------------------------------------------------
    def get(self, key: str) -> dict[str, float] | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            return dict(entry["metrics"])

    def put(
        self,
        key: str,
        metrics: dict[str, float],
        *,
        task: str = "",
        params: dict[str, Any] | None = None,
        platform: str = "",
        elapsed_s: float | None = None,
    ) -> None:
        entry = {
            "metrics": {k: float(v) for k, v in metrics.items()},
            "task": task,
            "params": {k: v for k, v in (params or {}).items()},
            "platform": platform,
            "saved_unix": time.time(),
        }
        if elapsed_s is not None:
            # Measured wall cost of the producing unit — scheduling evidence
            # for CostModel on later runs.
            entry["elapsed_s"] = float(elapsed_s)
        with self._lock:
            self._entries[key] = entry
            self._dirty = True
        if self.costs is not None and elapsed_s is not None:
            self.costs.observe(task, platform, elapsed_s)

    def snapshot(self) -> dict[str, dict[str, Any]]:
        """Point-in-time copy of all entries (read-only scheduling input)."""
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    # -- cross-runner coordination (work stealing) -------------------------
    def _claims_dir(self) -> Path:
        return self.path.with_name(self.path.name + ".claims")

    def try_claim(self, key: str, owner: str) -> bool:
        """Atomically claim a unit for execution; True iff WE won.

        The claim is an ``O_EXCL`` exclusive-create file — a filesystem
        compare-and-swap, so exactly one of any number of racing runners
        (threads or processes) gets True.  Claims persist for the life of
        the cache file (``clear()`` drops them): once a claimed unit's
        result is published, later runners find it by cache key and never
        look at the claim again.
        """
        d = self._claims_dir()
        try:
            d.mkdir(parents=True, exist_ok=True)
            with open(d / key, "x") as f:
                json.dump({"owner": str(owner), "claimed_unix": time.time()}, f)
            return True
        except FileExistsError:
            return False
        except OSError:
            # Unwritable claims dir (read-only cache mount): stealing is an
            # optimization — degrade to "someone else has it".
            return False

    def claim_owner(self, key: str) -> str | None:
        """Who claimed ``key``, or None if unclaimed (cheap stat + read)."""
        try:
            d = json.loads((self._claims_dir() / key).read_text())
        except (OSError, json.JSONDecodeError):
            return None
        return str(d.get("owner", "")) or None

    def claimed(self, key: str) -> bool:
        return (self._claims_dir() / key).exists()

    def refresh(self, key: str) -> dict[str, float] | None:
        """Re-read ``key`` from the ON-DISK cache (another runner may have
        published it since we loaded); folds a found entry into memory."""
        try:
            d = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return None
        if d.get("version") != CACHE_VERSION:
            return None
        entry = (d.get("entries") or {}).get(key)
        if not isinstance(entry, dict) or "metrics" not in entry:
            return None
        with self._lock:
            self._entries.setdefault(key, entry)
            self.hits += 1
        return dict(entry["metrics"])

    def publish(self, key: str) -> None:
        """Write ONE key's in-memory entry through to disk, read-merge-write.

        Unlike ``flush`` (which rewrites the whole file from this process's
        memory and would last-writer-win away entries other runners wrote),
        this merges the single key into whatever is on disk right now —
        concurrent publishers of different keys both survive.  The write
        itself is the same atomic mkstemp+replace as every other writer.
        (Two publishers racing inside the read->replace window can still
        drop one entry; that costs the owner a duplicate execution on its
        next miss, never a wrong report — same dedupe law as speculation.)
        """
        with self._lock:
            entry = self._entries.get(key)
        if entry is None:
            return
        try:
            d = json.loads(self.path.read_text())
            if d.get("version") != CACHE_VERSION or not isinstance(d.get("entries"), dict):
                d = {"version": CACHE_VERSION, "entries": {}}
        except (OSError, json.JSONDecodeError):
            d = {"version": CACHE_VERSION, "entries": {}}
        d["entries"][key] = entry
        _atomic_write_text(self.path, json.dumps(d, indent=1, default=str))

    # -- persistence -------------------------------------------------------
    def _trim(self) -> int:
        """Apply the eviction policy (caller holds the lock); returns drops."""
        dropped = 0
        if self.max_age_s is not None and self._entries:
            cutoff = time.time() - self.max_age_s
            stale = [
                k
                for k, e in self._entries.items()
                if float(e.get("saved_unix", 0.0) or 0.0) < cutoff
            ]
            for k in stale:
                del self._entries[k]
            dropped += len(stale)
        if self.max_entries is not None and len(self._entries) > self.max_entries:
            excess = len(self._entries) - self.max_entries
            oldest = sorted(
                self._entries,
                key=lambda k: (float(self._entries[k].get("saved_unix", 0.0) or 0.0), k),
            )[:excess]
            for k in oldest:
                del self._entries[k]
            dropped += excess
        if dropped:
            self._dirty = True
            self.evicted += dropped
        return dropped

    def flush(self) -> None:
        with self._lock:
            self._trim()
            if self._dirty:
                payload = {"version": CACHE_VERSION, "entries": self._entries}
                _atomic_write_text(self.path, json.dumps(payload, indent=1, default=str))
                self._dirty = False
        if self.costs is not None:
            self.costs.flush()
        if self.health is not None:
            self.health.flush()

    def clear(self) -> None:
        """Erase the cached RESULTS.  The cost sidecar deliberately
        survives: it is aggregate scheduling evidence, not results, and
        outliving eviction/clearing is its whole purpose.  Claim records go
        with the entries — a stale claim against a cleared result would
        silently disable stealing for that unit on the next pass."""
        with self._lock:
            had_entries = bool(self._entries)
            self._entries.clear()
            # Only mark dirty when there is something to erase: clearing a
            # cache that never touched disk must not create an empty file.
            if had_entries or self.path.exists():
                self._dirty = True
        d = self._claims_dir()
        if d.is_dir():
            for f in d.iterdir():
                try:
                    f.unlink()
                except OSError:
                    pass
        self.flush()

    def __len__(self) -> int:
        return len(self._entries)
