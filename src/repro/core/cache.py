"""Persistent result cache: re-runs skip already-measured points.

A sweep over (task x params x platform) is expensive and — for fixed seed
data and iteration counts — deterministic enough to reuse.  The cache maps a
content key over everything that identifies a measurement::

    sha256(task, params, platform identity, iters, warmup, metrics,
           task-source fingerprint)

to the computed metrics dict of the finished test.  Storage is one JSON
file (atomic tmp+rename writes) so the cache survives crashes, diffs
cleanly, and can be inspected/deleted by hand.  Anything that changes the
measurement — different parameter values, iteration counts, platform, the
cache format version — changes the key or invalidates the file wholesale.

Entries also record the measured wall time (``elapsed_s``) of the unit that
produced them; :class:`repro.core.cost.CostModel` feeds these back into
weighted sharding and LPT dispatch on later runs.

Long-lived caches are bounded by an optional eviction policy: construct
with ``max_entries=`` and/or ``max_age_s=`` and ``flush()`` trims the
oldest ``saved_unix`` entries (age first, then count) before writing.

Thread-safe: the executor calls ``get``/``put`` from worker threads.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from pathlib import Path
from typing import Any

CACHE_VERSION = 1


def cache_key(
    task: str,
    params: dict[str, Any],
    platform: dict[str, Any],
    iters: int,
    warmup: int,
    metrics: tuple[str, ...],
    fingerprint: str = "",
) -> str:
    ident = {
        "task": task,
        "params": params,
        "platform": platform,
        "iters": iters,
        "warmup": warmup,
        "metrics": list(metrics),
        # Source fingerprint of the task implementation: cached metrics are
        # only valid while the measuring code is unchanged (Task.source_fingerprint).
        "fingerprint": fingerprint,
    }
    blob = json.dumps(ident, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """On-disk metrics cache; ``None``-safe drop-in is simply not passing one."""

    def __init__(
        self,
        path: str | Path,
        max_entries: int | None = None,
        max_age_s: float | None = None,
    ):
        if max_entries is not None and max_entries < 0:
            raise ValueError(f"max_entries must be >= 0, got {max_entries}")
        if max_age_s is not None and max_age_s < 0:
            raise ValueError(f"max_age_s must be >= 0, got {max_age_s}")
        self.path = Path(path)
        self.max_entries = max_entries
        self.max_age_s = max_age_s
        self._lock = threading.Lock()
        self._entries: dict[str, dict[str, Any]] = {}
        self._dirty = False
        self.hits = 0
        self.misses = 0
        self.evicted = 0
        self._load()

    def _load(self) -> None:
        if not self.path.exists():
            return
        try:
            d = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return  # corrupt/unreadable -> start empty, overwrite on flush
        if d.get("version") != CACHE_VERSION:
            return  # format change invalidates everything
        entries = d.get("entries")
        if isinstance(entries, dict):
            self._entries = entries

    # -- lookup ------------------------------------------------------------
    def get(self, key: str) -> dict[str, float] | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            return dict(entry["metrics"])

    def put(
        self,
        key: str,
        metrics: dict[str, float],
        *,
        task: str = "",
        params: dict[str, Any] | None = None,
        platform: str = "",
        elapsed_s: float | None = None,
    ) -> None:
        entry = {
            "metrics": {k: float(v) for k, v in metrics.items()},
            "task": task,
            "params": {k: v for k, v in (params or {}).items()},
            "platform": platform,
            "saved_unix": time.time(),
        }
        if elapsed_s is not None:
            # Measured wall cost of the producing unit — scheduling evidence
            # for CostModel on later runs.
            entry["elapsed_s"] = float(elapsed_s)
        with self._lock:
            self._entries[key] = entry
            self._dirty = True

    def snapshot(self) -> dict[str, dict[str, Any]]:
        """Point-in-time copy of all entries (read-only scheduling input)."""
        with self._lock:
            return {k: dict(v) for k, v in self._entries.items()}

    # -- persistence -------------------------------------------------------
    def _trim(self) -> int:
        """Apply the eviction policy (caller holds the lock); returns drops."""
        dropped = 0
        if self.max_age_s is not None and self._entries:
            cutoff = time.time() - self.max_age_s
            stale = [
                k
                for k, e in self._entries.items()
                if float(e.get("saved_unix", 0.0) or 0.0) < cutoff
            ]
            for k in stale:
                del self._entries[k]
            dropped += len(stale)
        if self.max_entries is not None and len(self._entries) > self.max_entries:
            excess = len(self._entries) - self.max_entries
            oldest = sorted(
                self._entries,
                key=lambda k: (float(self._entries[k].get("saved_unix", 0.0) or 0.0), k),
            )[:excess]
            for k in oldest:
                del self._entries[k]
            dropped += excess
        if dropped:
            self._dirty = True
            self.evicted += dropped
        return dropped

    def flush(self) -> None:
        with self._lock:
            self._trim()
            if not self._dirty:
                return
            payload = {"version": CACHE_VERSION, "entries": self._entries}
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(self.path.suffix + ".tmp")
            tmp.write_text(json.dumps(payload, indent=1, default=str))
            tmp.replace(self.path)
            self._dirty = False

    def clear(self) -> None:
        with self._lock:
            had_entries = bool(self._entries)
            self._entries.clear()
            # Only mark dirty when there is something to erase: clearing a
            # cache that never touched disk must not create an empty file.
            if had_entries or self.path.exists():
                self._dirty = True
        self.flush()

    def __len__(self) -> int:
        return len(self._entries)
