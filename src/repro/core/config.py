"""Unified executor configuration: one sweep surface for every CLI.

`core/runner.py`, `benchmarks/run.py`, and `runtime/serve_query.py` all
drive the same :class:`repro.core.executor.SweepExecutor`; before this
module each re-declared the whole flag surface (25/20 ``add_argument``
calls) and the sets drifted.  Now there is exactly one definition:

  * :func:`add_sweep_args` installs the shared flags on any parser (with
    per-CLI defaults for ``--iters``/``--warmup``/``--platforms``);
  * :meth:`SweepConfig.from_args` lifts the parsed namespace into a typed
    dataclass;
  * :func:`validate_sweep` runs the CLI-side checks (platform names, shard
    spec syntax, remote fleet liveness) through the parser's ``error``;
  * :func:`make_cache` / :func:`make_executor` turn the config into the
    live objects.

Serving adds its own knob block the same way (:class:`ServeConfig` /
:func:`add_serving_args`), so ``--arrival-rate``/``--duration``/
``--queue-depth`` exist in one place too.
"""
from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path
from typing import Callable, Sequence

from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor
from repro.core.shard import ShardSpec


@dataclasses.dataclass
class SweepConfig:
    """Everything a CLI needs to build a SweepExecutor (plus shard/cache)."""

    iters: int = 5
    warmup: int = 2
    min_time_s: float = 0.0
    workers: int = 1
    platforms: list[str] | None = None
    pool: str = "thread"
    schedule: str = "dynamic"
    straggler_factor: float = 4.0
    shard: str | None = None
    weighted_shard: bool = False
    shard_plan: bool = False
    remote: str | None = None
    registry: str | None = None
    transport: str = "async"
    max_inflight: int = 0
    steal: bool = False
    cache_path: str | None = None
    no_cache: bool = False
    cache_max_entries: int | None = None
    cache_max_age_s: float | None = None

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "SweepConfig":
        return cls(
            iters=ns.iters,
            warmup=ns.warmup,
            min_time_s=ns.min_time,
            workers=ns.workers,
            platforms=list(ns.platforms) if ns.platforms else None,
            pool=ns.pool,
            schedule=ns.schedule,
            straggler_factor=ns.straggler_factor,
            shard=ns.shard,
            weighted_shard=ns.weighted_shard,
            shard_plan=getattr(ns, "shard_plan", False),
            remote=ns.remote,
            registry=getattr(ns, "registry", None),
            transport=getattr(ns, "transport", "async"),
            max_inflight=getattr(ns, "max_inflight", 0),
            steal=getattr(ns, "steal", False),
            cache_path=ns.cache_path,
            no_cache=ns.no_cache,
            cache_max_entries=ns.cache_max_entries,
            cache_max_age_s=ns.cache_max_age,
        )


def add_sweep_args(
    p: argparse.ArgumentParser,
    *,
    iters: int = 5,
    warmup: int = 2,
    platforms: Sequence[str] | None = None,
) -> None:
    """Install the shared sweep flag surface on ``p``.

    ``iters``/``warmup``/``platforms`` are the per-CLI defaults (the runner
    measures 5x after 2 warmups against box-declared platforms; the
    benchmark orchestrator 3x/1 on cpu-host).  ``--cache`` and
    ``--cache-file`` are aliases of one destination, so either spelling
    works everywhere.
    """
    g = p.add_argument_group("sweep execution")
    g.add_argument("--iters", type=int, default=iters)
    g.add_argument("--warmup", type=int, default=warmup)
    g.add_argument(
        "--min-time", type=float, default=0.0, metavar="SECONDS",
        help="keep sampling each test past --iters until this much measured "
        "wall time accumulates (microsecond-scale points stop being "
        "few-sample noise); part of the cache identity when set",
    )
    g.add_argument("--workers", type=int, default=1, help="concurrent test workers")
    g.add_argument(
        "--platforms", nargs="+",
        default=list(platforms) if platforms is not None else None,
        help="execution platforms to sweep (e.g. cpu-host dpu-sim)",
    )
    g.add_argument("--pool", choices=("thread", "process"), default="thread")
    g.add_argument(
        "--schedule", choices=("static", "dynamic"), default="dynamic",
        help="dynamic (default): pull-based fleet scheduler with straggler "
        "re-dispatch for pooled runs; static: up-front LPT plan",
    )
    g.add_argument(
        "--straggler-factor", type=float, default=4.0, metavar="X",
        help="dynamic schedule: speculatively re-dispatch a unit once it "
        "has run X times its calibrated cost estimate (default 4)",
    )
    g.add_argument(
        "--shard", default=None, metavar="I/N[@W]",
        help="run only shard I of N (e.g. 0/2); an @ weight suffix "
        "(0/2@0.25, 1/4@0.1:0.3:0.3:0.3) gives shards capacity weights and "
        "switches to cost-balanced assignment; @auto calibrates the vector "
        "from worker pings + cost evidence",
    )
    g.add_argument(
        "--weighted-shard", action="store_true",
        help="balance shards by estimated per-unit cost (cache-fed CostModel) "
        "instead of key count, even with uniform weights",
    )
    g.add_argument(
        "--shard-plan", action="store_true",
        help="print each shard's unit count and estimated cost share for "
        "--shard's N (and weights), then exit without running",
    )
    g.add_argument(
        "--remote", default=None, metavar="HOST:PORT[,HOST:PORT...]",
        help="dispatch unit execution to repro.core.remote worker(s); "
        "comma-separate a fleet — the dynamic schedule gives each worker "
        "its own sink, and @auto shard weights calibrate from their pings",
    )
    g.add_argument(
        "--registry", default=None, metavar="HOST:PORT[,HOST:PORT...]",
        help="discover the worker fleet from repro.runtime.membership "
        "registry replica(s) instead of --remote's endpoint list: sinks "
        "are the replicas' merged alive members and grow/shrink mid-sweep "
        "on membership events; with several replicas every poll queries "
        "all of them and fails over within the same tick (mutually "
        "exclusive with --remote)",
    )
    g.add_argument(
        "--transport", choices=("threaded", "async"), default="async",
        help="fleet wire strategy: async (default) multiplexes every unit "
        "over one persistent connection per worker on a single IO loop; "
        "threaded keeps one puller thread + connection per in-flight unit",
    )
    g.add_argument(
        "--max-inflight", type=int, default=0, metavar="N",
        help="async transport: cap in-flight units per worker at N instead "
        "of the worker's advertised capacity (0 = advertised)",
    )
    g.add_argument(
        "--steal", action="store_true",
        help="after draining this shard's slice, claim sibling shards' "
        "unfinished units through the shared --cache (exclusive claim "
        "records keep the merged report byte-identical); needs --shard "
        "and a shared cache file",
    )
    g.add_argument(
        "--cache", "--cache-file", dest="cache_path", default=None,
        metavar="PATH", help="persistent result cache file",
    )
    g.add_argument("--no-cache", action="store_true", help="ignore the cache and remeasure")
    g.add_argument(
        "--cache-max-entries", type=int, default=None, metavar="N",
        help="evict oldest cache entries beyond N on flush",
    )
    g.add_argument(
        "--cache-max-age", type=float, default=None, metavar="SECONDS",
        dest="cache_max_age", help="evict cache entries older than SECONDS on flush",
    )


def validate_sweep(
    cfg: SweepConfig,
    error: Callable[[str], None],
    *,
    ping_remote: bool = True,
) -> ShardSpec | None:
    """CLI-side checks shared by every entry point.

    Resolves the shard spec (calling ``error`` — typically
    ``parser.error`` — on bad syntax), verifies platform names exist, and
    optionally pings the remote fleet.  Returns the parsed ShardSpec.
    """
    if cfg.platforms:
        from repro.core.platform import get_platform

        try:
            for name in cfg.platforms:
                get_platform(name)
        except KeyError as e:
            error(str(e.args[0]))
    shard = None
    if cfg.shard:
        try:
            shard = ShardSpec.parse(cfg.shard)
        except ValueError as e:
            error(str(e))
    if cfg.shard_plan and shard is None:
        error("--shard-plan needs --shard I/N[@W] for the shard count/weights")
    if cfg.steal and shard is None:
        error("--steal coordinates between shards: it needs --shard I/N "
              "(and every shard runner pointing at one shared --cache file)")
    if cfg.steal and cfg.no_cache:
        error("--steal coordinates through the shared result cache; it "
              "cannot work with --no-cache")
    if cfg.remote and cfg.registry:
        error("--remote and --registry are mutually exclusive: an explicit "
              "endpoint list or a discovered fleet, not both")
    if cfg.remote:
        from repro.core import remote as remote_mod

        try:
            endpoints = remote_mod.parse_fleet(cfg.remote)
        except ValueError as e:
            error(str(e))
            endpoints = []
        if ping_remote and not cfg.shard_plan:
            for ep in endpoints:
                try:
                    if not remote_mod.wait_ready(ep):
                        error(f"remote worker {ep} is not answering")
                except remote_mod.RemoteExecutionError as e:
                    error(str(e))
    if cfg.registry:
        from repro.core import remote as remote_mod

        try:
            replicas = remote_mod.parse_fleet(cfg.registry)
        except ValueError as e:
            error(str(e))
            replicas = []
        if replicas and ping_remote and not cfg.shard_plan:
            # ANY answering replica is enough — the plane is replicated and
            # consumers fail over per poll; demanding all of them up front
            # would turn one down replica into a sweep that can't start.
            try:
                if remote_mod.wait_any_ready(replicas) is None:
                    error(
                        f"no membership registry replica answering "
                        f"(tried: {', '.join(replicas)})"
                    )
            except remote_mod.RemoteExecutionError as e:
                error(str(e))
    return shard


def make_cache(cfg: SweepConfig, default_path: str | Path | None = None) -> ResultCache | None:
    """The config's ResultCache, or None (``--no-cache``, or no path at all).

    ``default_path`` is the CLI's fallback location (the benchmark
    orchestrator caches next to its results by default; the runner only
    caches when asked).
    """
    if cfg.no_cache:
        return None
    path = cfg.cache_path or default_path
    if path is None:
        return None
    return ResultCache(
        path,
        max_entries=cfg.cache_max_entries,
        max_age_s=cfg.cache_max_age_s,
    )


def make_executor(
    cfg: SweepConfig,
    *,
    cache: ResultCache | None = None,
    cache_default_path: str | Path | None = None,
) -> SweepExecutor:
    """Build the SweepExecutor this config describes.

    Pass ``cache`` to reuse an already-constructed cache, or let the
    config (plus ``cache_default_path``) decide.
    """
    if cache is None:
        cache = make_cache(cfg, cache_default_path)
    return SweepExecutor(
        platforms=cfg.platforms,
        workers=cfg.workers,
        iters=cfg.iters,
        warmup=cfg.warmup,
        min_time_s=cfg.min_time_s,
        cache=cache,
        pool=cfg.pool,
        remote=cfg.remote,
        fleet_registry=cfg.registry,
        weighted_shard=cfg.weighted_shard,
        schedule=cfg.schedule,
        straggler_factor=cfg.straggler_factor,
        transport=cfg.transport,
        max_inflight=cfg.max_inflight,
        steal=cfg.steal,
    )


# ---------------------------------------------------------------------------
# Serving knobs — the query-serving front end's own block, defined once.
@dataclasses.dataclass
class ServeConfig:
    """Knobs of the open-loop query-serving loop (runtime/serve_query.py)."""

    arrival_rate: float = 50.0  # offered load, requests/second
    duration_s: float = 2.0  # open-loop run length, seconds
    queue_depth: int | None = 64  # admission bound; None = never shed
    max_batch: int = 8  # scan-sharing coalescing width
    arrival: str = "poisson"  # "poisson" | "fixed"
    batching: bool = True  # False = serial per-request execution
    queries: list[str] = dataclasses.field(default_factory=lambda: ["q6"])
    scale: str = "0.001"  # dataset scale factor (tasks/dbms scales)
    seed: int = 0

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "ServeConfig":
        return cls(
            arrival_rate=ns.arrival_rate,
            duration_s=ns.duration,
            queue_depth=ns.queue_depth if ns.queue_depth > 0 else None,
            max_batch=ns.max_batch,
            arrival=ns.arrival,
            batching=not ns.no_batching,
            queries=list(ns.query),
            scale=ns.scale,
            seed=ns.seed,
        )


def add_serving_args(p: argparse.ArgumentParser) -> None:
    """Install the serving knob block (shared by serve CLI and smoke)."""
    g = p.add_argument_group("serving")
    g.add_argument(
        "--arrival-rate", type=float, default=50.0, metavar="QPS",
        help="offered load in requests/second (open loop)",
    )
    g.add_argument(
        "--duration", type=float, default=2.0, metavar="SECONDS",
        help="open-loop run length",
    )
    g.add_argument(
        "--queue-depth", type=int, default=64, metavar="N",
        help="admission-control queue bound; 0 = unbounded (never shed)",
    )
    g.add_argument(
        "--max-batch", type=int, default=8, metavar="B",
        help="scan-sharing width: max requests coalesced into one kernel pass",
    )
    g.add_argument(
        "--arrival", choices=("poisson", "fixed"), default="poisson",
        help="arrival process of the open-loop load generator",
    )
    g.add_argument(
        "--no-batching", action="store_true",
        help="serve strictly one request per kernel pass (no scan sharing)",
    )
    g.add_argument(
        "--query", nargs="+", default=["q6"], choices=("q1", "q6", "q12"),
        help="fused queries to serve (requests round-robin across them)",
    )
    g.add_argument(
        "--scale", default="0.001", choices=("0.001", "0.01", "0.1"),
        help="TPC-H scale factor of the served tables",
    )
    g.add_argument("--seed", type=int, default=0, help="load-generator seed")


__all__ = [
    "ServeConfig",
    "SweepConfig",
    "add_serving_args",
    "add_sweep_args",
    "make_cache",
    "make_executor",
    "validate_sweep",
]
