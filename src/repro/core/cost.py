"""Cost model: estimate per-unit wall cost for scheduling decisions.

Sharding and dispatch both need to know *how long a unit will take* before
running it: partitioning a sweep by unit count systematically overloads the
slow side of a heterogeneous fleet (the BlueField-2 characterizations put
DPU Arm cores at a fraction of host-core throughput), and submitting a pool
in grid order leaves the longest unit running alone at the tail.

:class:`CostModel` turns whatever evidence exists into a relative wall-cost
estimate, in strictly decreasing order of trust:

  1. **Measured** — the exact unit was run before and its ``elapsed_s`` was
     recorded into the :class:`~repro.core.cache.ResultCache` entry on
     ``put`` (every executor path records it).  Re-runs therefore schedule
     on real numbers.
  2. **Task+platform mean** — the mean measured cost of the same task on the
     same platform (other parameter points), when the exact point is new.
  2b. **Persisted EWMA** — the cache's ``costs.json`` sidecar
     (:class:`repro.core.cache.EwmaCostStore`) keeps an EWMA per
     (task, platform) that survives entry eviction; consulted when the live
     entries hold no mean for the pair.
  3. **Task mean × platform scale** — the task's mean across all platforms,
     scaled by the target platform's :meth:`~repro.core.platform.Platform.
     cost_scale` heuristic (``time_scale`` for simulated wimpy cores).
  4. **Platform heuristic** — no history at all: ``cost_scale`` alone, so a
     ``dpu-sim`` unit still counts ~3.5x a host unit.
  5. **Uniform** — 1.0; every consumer degrades to today's count-balanced
     behaviour.

Estimates are *relative* weights, not predictions: only ratios matter to the
weighted partition (:func:`repro.core.shard.cost_shard_map`) and to the
longest-processing-time-first dispatch in :class:`repro.core.executor.
SweepExecutor`.  The model snapshots the cache once at construction, so one
scheduling decision is internally consistent even while the cache fills up.

Determinism note: runners that must agree on a weighted partition (one per
shard) must see the same cost evidence — share the cache file, pre-seeded by
a prior run.  Without any cache the model is a pure function of (task,
platform) and agrees everywhere by construction.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Iterable, Mapping

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.cache import ResultCache
    from repro.core.platform import Platform

DEFAULT_COST = 1.0

#: estimate() provenance labels, most to least trusted.
SOURCES = ("measured", "task-platform-mean", "ewma", "task-mean", "heuristic", "uniform")


class CostModel:
    """Per-unit wall-cost estimator fed by cache-recorded measurements."""

    def __init__(self, cache: "ResultCache | None" = None, default_cost: float = DEFAULT_COST):
        self.default_cost = float(default_cost)
        self._exact: dict[str, float] = {}
        self._task_platform: dict[tuple[str, str], list[float]] = {}
        self._task: dict[str, list[float]] = {}
        self._ewma: dict[tuple[str, str], float] = {}
        if cache is not None:
            self._ingest(cache.snapshot())
            costs = getattr(cache, "costs", None)
            if costs is not None:
                for key, e in costs.snapshot().items():
                    try:
                        v = float(e.get("ewma_s", 0.0) or 0.0)
                    except (TypeError, ValueError):
                        continue
                    if v > 0:
                        self._ewma[key] = v

    def _ingest(self, entries: Mapping[str, Mapping[str, Any]]) -> None:
        for key, entry in entries.items():
            try:
                elapsed = float(entry.get("elapsed_s", 0.0) or 0.0)
            except (TypeError, ValueError):
                continue
            if elapsed <= 0.0:
                continue
            self._exact[key] = elapsed
            task = str(entry.get("task", "") or "")
            platform = str(entry.get("platform", "") or "")
            if task:
                self._task.setdefault(task, []).append(elapsed)
                if platform:
                    self._task_platform.setdefault((task, platform), []).append(elapsed)

    @property
    def measured_points(self) -> int:
        """How many exact measurements back this model."""
        return len(self._exact)

    @property
    def mean_elapsed_s(self) -> float | None:
        """Mean measured unit wall time — a "typical unit costs X seconds"
        scale for auto-weight fallbacks; sidecar EWMAs stand in when every
        raw entry was evicted.  ``None`` with no evidence at all."""
        vals = list(self._exact.values()) or list(self._ewma.values())
        if not vals:
            return None
        return sum(vals) / len(vals)

    def estimate(
        self,
        key: str | None = None,
        task: str = "",
        platform: "Platform | None" = None,
    ) -> float:
        """Relative wall-cost estimate for one unit (see tier list above)."""
        return self.explain(key, task=task, platform=platform)[0]

    def explain(
        self,
        key: str | None = None,
        task: str = "",
        platform: "Platform | None" = None,
    ) -> tuple[float, str]:
        """``(cost, source)`` — the estimate plus which tier produced it."""
        if key is not None:
            exact = self._exact.get(key)
            if exact is not None:
                return exact, "measured"
        scale = platform.cost_scale() if platform is not None else 1.0
        if task and platform is not None:
            tp = self._task_platform.get((task, platform.name))
            if tp:
                return sum(tp) / len(tp), "task-platform-mean"
            ew = self._ewma.get((task, platform.name))
            if ew is not None:
                return ew, "ewma"
        if task:
            t = self._task.get(task)
            if t:
                return (sum(t) / len(t)) * scale, "task-mean"
        if scale != 1.0:
            return self.default_cost * scale, "heuristic"
        return self.default_cost, "uniform"

    def estimate_many(self, units: Iterable[Any], lookup: str = "ckey") -> dict[str, float]:
        """Shard-key -> cost for executor units (``skey``/``ckey`` carriers).

        ``lookup`` names the attribute used for the exact-measurement tier:
        ``"ckey"`` (default) weighs the endpoint-specific measurement —
        right for local decisions like LPT dispatch; partitioning across
        runners must pass ``"skey"`` so every runner, whatever its
        ``--remote`` setting, resolves the same evidence and computes the
        same partition.  Duplicate shard keys (overlapping task specs) keep
        one entry — they share a cache identity, hence an estimate; the
        partition layer accounts for multiplicity itself.
        """
        out: dict[str, float] = {}
        for u in units:
            skey = getattr(u, "skey", None) or getattr(u, "ckey", None)
            if skey is None or skey in out:
                continue
            out[skey] = self.estimate(
                getattr(u, lookup, None) or skey,
                task=getattr(u, "task_name", ""),
                platform=getattr(u, "platform", None),
            )
        return out


__all__ = ["CostModel", "DEFAULT_COST", "SOURCES"]
