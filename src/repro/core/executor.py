"""Sweep execution subsystem: concurrent, multi-platform, cached.

The seed ``Runner`` walked a box strictly sequentially on one implicit
platform.  This module is the generalisation every scaling direction builds
on (ROADMAP: sharding, batching, async, caching, multi-backend):

  * **Concurrency** — expanded tests dispatch onto a thread pool (default)
    or a spawn-based process pool (``pool="process"``); ``workers=1`` keeps
    the exact sequential seed path.  Report rows are assembled in submission
    order, so the output is identical regardless of worker count.
  * **Prepare barriers** — ``Task.prepare`` runs exactly once per
    (platform, task) no matter how many workers race into the task; losers
    block on an event until the winner's prepare finishes (or fails, which
    fails their tests too).  This keeps the shared ``TaskContext`` contract
    of the paper's lifecycle intact under concurrency.
  * **Platform sweeps** — one invocation can run the same grid across many
    named :mod:`repro.core.platform` backends; rows then carry a
    ``platform`` column and feed ``report.speedup_table``.
  * **Result caching** — with a :class:`repro.core.cache.ResultCache`,
    already-measured (task, params, platform, iters, task-source) points
    short-circuit into cached metrics; ``SweepStats.cached`` reports how many.
  * **Sharding** — ``run_box(box, shard=ShardSpec(i, n))`` executes only the
    i-th consistent-hash slice of the expanded grid (see
    :mod:`repro.core.shard`); shard reports reassemble with
    ``report.merge_shard_reports``.  Cache keys are shard-independent, so
    shards dedupe against each other through a shared cache.
  * **Cost-aware scheduling** — a :class:`repro.core.cost.CostModel` (fed by
    wall times the cache records on every ``put``, persisted across
    eviction by the ``costs.json`` EWMA sidecar) drives shard specs with
    ``weights`` (or ``weighted_shard=True``): the grid partitions by
    *estimated cost* instead of key count.  ``--shard i/n@auto`` resolves
    the weight vector from fleet pings (worker capacity + measured EWMA
    throughput) plus local cost evidence instead of operator guesses.
    ``shard_plan(box, spec)`` previews the per-shard unit counts and cost
    shares without running anything.
  * **Dynamic scheduling** (default for pooled runs) — a pull-based
    :class:`repro.core.scheduler.FleetScheduler`: one cost-descending work
    queue, drained by sink workers (local thread/process slots plus one
    sink per remote endpoint at its advertised capacity) as they free up;
    stragglers past ``straggler_factor x`` their calibrated estimate are
    speculatively re-dispatched to idle sinks, first completion wins.
    ``schedule="static"`` keeps the up-front plan: LPT submission order
    (``_dispatch_order``) into a fixed thread/process pool.  Either way,
    report rows are assembled in canonical grid order, so output is
    byte-identical to sequential execution.
  * **Remote dispatch** — a ``kind="remote"`` platform (or an executor-wide
    ``remote="host:port"`` endpoint; comma-separate several for a fleet)
    ships units to :mod:`repro.core.remote` workers instead of running
    them locally.
  * **Elastic fleets** — ``fleet_registry="host:port"`` discovers the
    worker fleet from a :mod:`repro.runtime.membership` registry instead
    of an endpoint list: sinks are the registry's alive members, a
    :class:`repro.runtime.elastic.FleetWatcher` grows/shrinks the sink set
    mid-sweep on membership events, per-unit deadlines derived from the
    cost sidecar bound hung-worker detection, and the ``health.json``
    sidecar blacklists chronically failing endpoints across runs.  Merged
    reports stay byte-identical to sequential runs throughout — rows
    assemble in canonical grid order whatever the fleet did.

Process-pool note: tasks registered only via ``_register_for_tests`` are
invisible to spawned children; plugin directories ARE threaded into the
child bootstrap, so ``load_plugin_dir`` tasks work under ``pool="process"``.
"""
from __future__ import annotations

import json
import threading
import time
import traceback
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core import cache as cache_mod
from repro.core import registry, report
from repro.core.box import Box
from repro.core.cost import CostModel
from repro.core.metrics import compute_metrics
from repro.core.platform import Platform, resolve
from repro.core.scheduler import (
    DEFAULT_STRAGGLER_FACTOR,
    FleetScheduler,
    Sink,
    WorkItem,
)
from repro.core.shard import ShardSpec, cost_shard_map, resolve_auto_weights, shard_of
from repro.core.task import TaskContext, TestResult


class _ChildFailure(RuntimeError):
    """A process-pool child (or worker) serialized a failure back.

    Carries the child-side traceback so error reports show where the task
    actually died, not where the parent re-raised.
    """

    def __init__(self, message: str, child_traceback: str = ""):
        super().__init__(message)
        self.child_traceback = child_traceback


class RemoteFleetEmpty(RuntimeError):
    """A registry-discovered fleet has no alive workers to run on."""


@dataclass
class SweepStats:
    total: int = 0
    executed: int = 0
    cached: int = 0
    errors: int = 0
    # Units that got a speculative straggler copy under dynamic scheduling.
    speculated: int = 0
    # Units re-enqueued because their sink was marked dead mid-flight.
    redispatched: int = 0
    # Fleet endpoints excluded at startup by the health sidecar's
    # consecutive-failure streak (cross-run straggler blacklisting).
    blacklisted: int = 0
    # Sibling shards' leftover units this runner claimed and executed
    # through the shared cache (--steal; see ResultCache.try_claim).
    stolen: int = 0
    # Client-side dispatch/puller threads the scheduler created for this
    # sweep (monotonic count): O(sum of sink capacities) on the threaded
    # transport, O(1) dispatcher (+ the shared async IO loop) on async.
    dispatch_threads: int = 0
    # Consecutive membership polls at sweep end where NO registry replica
    # answered — non-zero means the sweep finished under a dark control
    # plane (results are still complete; joins/leaves were deferred).
    registry_poll_failures: int = 0


@dataclass
class SweepResult:
    box: str
    platforms: list[str]
    rows: list[dict[str, Any]] = field(default_factory=list)
    results: list[TestResult] = field(default_factory=list)
    errors: list[dict[str, str]] = field(default_factory=list)
    stats: SweepStats = field(default_factory=SweepStats)

    def csv(self) -> str:
        return report.to_csv(self.rows)

    def markdown(self) -> str:
        return report.to_markdown(self.rows)


@dataclass
class _Unit:
    """One concrete test: a point of the (platform x task x params) grid.

    ``skey`` is the shard-assignment key (always the endpoint-free cache
    key, so runners pointing different shards at different workers still
    cover the grid between them); ``ckey`` is the result-cache key (which
    DOES see the ``--remote`` endpoint: a remote host's measurement is not
    the local platform's measurement).  They coincide for local runs, so
    shard assignment and cache identity agree by construction.
    """

    index: int
    platform: Platform
    task_name: str
    params: dict[str, Any]
    metrics: tuple[str, ...]
    ckey: str | None = None
    skey: str | None = None


class SweepExecutor:
    def __init__(
        self,
        platforms: Sequence[Platform | str | dict[str, Any]] | None = None,
        workers: int = 1,
        iters: int = 5,
        warmup: int = 2,
        fail_fast: bool = False,
        cache: cache_mod.ResultCache | None = None,
        pool: str = "thread",
        remote: str | None = None,
        weighted_shard: bool = False,
        schedule: str = "dynamic",
        straggler_factor: float = DEFAULT_STRAGGLER_FACTOR,
        min_time_s: float = 0.0,
        fleet_registry: str | None = None,
        transport: str = "async",
        max_inflight: int = 0,
        steal: bool = False,
    ):
        if pool not in ("thread", "process"):
            raise ValueError(f"pool must be 'thread' or 'process', got {pool!r}")
        if schedule not in ("static", "dynamic"):
            raise ValueError(f"schedule must be 'static' or 'dynamic', got {schedule!r}")
        if straggler_factor <= 0:
            raise ValueError(f"straggler_factor must be > 0, got {straggler_factor}")
        if transport not in ("threaded", "async"):
            raise ValueError(f"transport must be 'threaded' or 'async', got {transport!r}")
        if max_inflight < 0:
            raise ValueError(f"max_inflight must be >= 0, got {max_inflight}")
        self._platforms_explicit = platforms is not None
        self.platforms = [resolve(p) for p in (platforms or ["default"])]
        if len({p.name for p in self.platforms}) != len(self.platforms):
            raise ValueError(f"duplicate platform names in {[p.name for p in self.platforms]}")
        self.workers = max(1, int(workers))
        self.iters = iters
        self.warmup = warmup
        # Floor on measured wall time per test (core.timing.measure): tasks
        # that honor it keep sampling past `iters` until it accumulates.
        self.min_time_s = float(min_time_s)
        self.fail_fast = fail_fast
        self.cache = cache
        self.pool = pool
        # Endpoint(s) of repro.core.remote workers; when set, EVERY unit is
        # dispatched there (per-platform remotes use kind="remote" instead).
        # A comma-separated fleet gives the dynamic scheduler one sink per
        # worker; static dispatch targets the first endpoint.
        self.remote = remote
        # Membership registry endpoint (repro.runtime.membership): the fleet
        # is DISCOVERED from live registrations instead of enumerated by
        # hand, and under dynamic scheduling a FleetWatcher grows/shrinks
        # the sink set mid-sweep on membership events.  Mutually exclusive
        # with an explicit `remote` fleet.
        if fleet_registry is not None and remote is not None:
            raise ValueError("pass either remote= or fleet_registry=, not both")
        self.fleet_registry = fleet_registry
        # Balance shard assignment by estimated cost even without explicit
        # shard weights (ShardSpec.weights implies it regardless).
        self.weighted_shard = weighted_shard
        # "dynamic" (default): pull-based FleetScheduler for pooled runs;
        # "static": the original up-front LPT plan into a fixed pool.
        self.schedule = schedule
        self.straggler_factor = float(straggler_factor)
        # Fleet-sink wire strategy.  "async" (default): callback sinks over
        # the shared repro.core.aiotransport event loop — one dispatcher
        # thread and one persistent multiplexed connection per endpoint.
        # "threaded": the original one-puller-thread-per-capacity-slot path
        # (kept as a fallback and as the benchmark baseline).
        self.transport = transport
        # Per-endpoint in-flight admission override for async sinks; 0 uses
        # each worker's advertised capacity.  Values above capacity queue
        # server-side — note the deadline caveat: a unit's clock starts at
        # dispatch, so deep overcommit can expire units that never ran.
        self.max_inflight = int(max_inflight)
        # Cache-mediated work stealing: after draining its own shard slice,
        # this runner claims sibling shards' unfinished units via exclusive
        # claim records in the shared ResultCache (no-op without a cache or
        # without sharding; results publish under the unit's cache key, so
        # the owning shard's report picks them up as hits — byte-identical
        # merge preserved because first completed claim wins).
        self.steal = bool(steal)
        # endpoint -> {"capacity", "throughput"} advertised via registry
        # heartbeats; consulted before ever pinging a worker (zero startup
        # pings for registry fleets), kept fresh by the FleetWatcher tap.
        self._advertised: dict[str, dict[str, Any]] = {}
        # Contexts persist across boxes so prepare is shared; cleaned explicitly.
        self._contexts: dict[tuple[str, str], TaskContext] = {}
        self._prep: dict[tuple[str, str], dict[str, Any]] = {}
        self._lock = threading.Lock()
        # Per-(platform, task) serialization points: prepare barriers and
        # context-log appends contend only within one task, not globally.
        self._task_locks: dict[tuple[str, str], threading.Lock] = {}

    # -- shared state ------------------------------------------------------
    def _context(self, platform: Platform, task_name: str) -> TaskContext:
        key = (platform.name, task_name)
        with self._lock:
            ctx = self._contexts.get(key)
            if ctx is None:
                ctx = TaskContext(
                    platform=platform.describe(),
                    iters=self.iters,
                    warmup=self.warmup,
                    min_time_s=self.min_time_s,
                )
                self._contexts[key] = ctx
        return ctx

    def _task_lock(self, platform_name: str, task_name: str) -> threading.Lock:
        key = (platform_name, task_name)
        with self._lock:
            return self._task_locks.setdefault(key, threading.Lock())

    def _ensure_prepared(self, task, platform: Platform, ctx: TaskContext) -> None:
        """Run prepare exactly once per (platform, task).

        Serialization is per-(platform, task): units of the same task block
        on the winner's prepare (holding that key's lock), while units of
        OTHER tasks prepare and run concurrently — no global barrier.
        """
        key = (platform.name, task.name)
        with self._task_lock(*key):
            with self._lock:
                state = self._prep.get(key)
            if state is None:
                state = {"error": None}
                try:
                    task.prepare(ctx)
                except BaseException as e:
                    state["error"] = e
                    with self._lock:
                        self._prep[key] = state
                    raise
                with self._lock:
                    self._prep[key] = state
                return
        if state["error"] is not None:
            raise RuntimeError(
                f"prepare failed for task {task.name!r} on {platform.name!r}: "
                f"{state['error']}"
            ) from state["error"]

    # -- unit execution ----------------------------------------------------
    def _fleet_identity(self) -> str | None:
        """The STABLE name of the executor-wide fleet for cache identity.

        An explicit ``remote`` fleet is identified by its endpoint list; a
        registry-discovered fleet by the registry's own replica list —
        worker endpoints there are ephemeral (workers join/leave, ports
        churn), so folding them into cache keys would orphan every entry on
        the next membership change.  The replica list is sorted so the
        identity is independent of listing order AND of which replica
        happens to answer a given poll.  ``None`` means purely local
        execution.
        """
        if self.remote is not None:
            return self.remote
        if self.fleet_registry is not None:
            from repro.core.remote import parse_fleet

            return "registry://" + ",".join(sorted(parse_fleet(self.fleet_registry)))
        return None

    def _remote_endpoints(self) -> list[str]:
        """The executor-wide worker fleet: the parsed ``remote`` list, or
        the registry replicas' CURRENT merged alive members (empty when
        neither is set — and also when no replica answers, which static
        paths treat as "no fleet" while dynamic paths keep watching for
        joins)."""
        from repro.core import remote as remote_mod

        if self.remote is not None:
            return remote_mod.parse_fleet(self.remote)
        if self.fleet_registry is not None:
            members, answered = remote_mod.fleet_view(self.fleet_registry)
            if not answered:
                return []
            for m in members:
                self._advertise(m)
            return [m["endpoint"] for m in members if m.get("status") == "alive"]
        return []

    def _advertise(self, row: dict[str, Any]) -> None:
        """Record a registry fleet row's heartbeat-carried capacity and
        throughput so discovery never needs to ping the worker itself."""
        ep = row.get("endpoint")
        cap = row.get("capacity")
        if not ep or not cap:
            return
        try:
            self._advertised[str(ep)] = {
                "capacity": max(1, int(cap)),
                "throughput": row.get("throughput"),
            }
        except (TypeError, ValueError):
            pass

    def _remote_endpoint(self, unit: _Unit) -> str | None:
        """Worker endpoint for this unit, or None for local execution.

        With a multi-endpoint fleet this is the *static* answer (the first
        endpoint); dynamic scheduling overrides per sink instead.
        """
        endpoints = self._remote_endpoints()
        if endpoints:
            return endpoints[0]
        return unit.platform.endpoint()

    def _unit_deadline(self, unit: _Unit) -> float:
        """Layered per-unit deadline (seconds) from measured cost evidence.

        The ``costs.json`` sidecar's (task, platform) EWMA is in real
        seconds whenever it exists; a hung worker is then detected within
        ``UNIT_DEADLINE_FACTOR x`` the unit's expected cost (floored for
        noise) instead of the 600 s request ceiling.  No evidence — first
        ever run of the task — keeps the ceiling: better one slow detection
        than killing a legitimately long first measurement.
        """
        from repro.core.remote import unit_deadline_s

        est = None
        if self.cache is not None and self.cache.costs is not None:
            est = self.cache.costs.get(unit.task_name, unit.platform.name)
        return unit_deadline_s(est)

    def _run_unit_remote(
        self, unit: _Unit, endpoint: str, deadline_s: float | None = None
    ) -> tuple[TestResult, float | None]:
        """Ship one unit to a worker; prepare/run/transform happen there.

        Returns the result plus the WORKER-measured wall cost of the unit
        (queue/transport wait excluded — that is scheduling noise, not
        evidence of what the unit costs).
        """
        from repro.core import remote as remote_mod

        resp = remote_mod.get_transport(endpoint).run_unit(
            _unit_payload(unit, self, want_samples=True),
            timeout=self._unit_deadline(unit) if deadline_s is None else deadline_s,
        )
        vals = {k: float(v) for k, v in resp["metrics"].items()}
        ctx = self._context(unit.platform, unit.task_name)
        with self._task_lock(unit.platform.name, unit.task_name):
            ctx.log.append(
                {"task": unit.task_name, "params": dict(unit.params), "metrics": dict(vals)}
            )
        elapsed = resp.get("elapsed_s")
        return (
            TestResult(unit.task_name, dict(unit.params), vals, platform=unit.platform.name),
            float(elapsed) if elapsed is not None else None,
        )

    def _cache_store(
        self,
        ckey: str,
        vals: dict[str, float],
        *,
        task: str,
        params: dict[str, Any],
        platform: str,
        elapsed_s: float | None,
    ) -> None:
        """``cache.put`` plus, when stealing, an immediate single-key publish.

        Claim/refresh coordination between shard runners happens through the
        cache file on DISK, but a plain put only reaches it at the end-of-run
        flush.  A steal-enabled run therefore writes each completed unit
        through immediately — otherwise siblings claim and re-execute work
        its owner already finished (correct, but zero wall-clock win).
        """
        self.cache.put(
            ckey, vals, task=task, params=params, platform=platform, elapsed_s=elapsed_s
        )
        if self.steal:
            self.cache.publish(ckey)

    def _run_unit(self, unit: _Unit, endpoint: str | None = None) -> tuple[TestResult, bool]:
        """Execute (or cache-hit) one unit; returns (result, was_cached).

        ``endpoint`` forces dispatch to one specific worker (a dynamic
        sink's home); ``None`` resolves statically from the executor/
        platform configuration.
        """
        if self.cache is not None and unit.ckey is not None:
            hit = self.cache.get(unit.ckey)
            if hit is None and self.steal and unit.skey is not None and self.cache.claimed(unit.skey):
                # A sibling runner claimed this unit for stealing: its result
                # may already be published on disk.  If not, execute anyway —
                # first completed claim wins, and byte-identical metrics make
                # the duplicate execution harmless (same dedupe law as
                # speculation).
                hit = self.cache.refresh(unit.ckey)
            if hit is not None:
                return (
                    TestResult(
                        unit.task_name, dict(unit.params), hit, platform=unit.platform.name
                    ),
                    True,
                )
        if endpoint is None:
            endpoint = self._remote_endpoint(unit)
        if endpoint is not None:
            result, elapsed = self._run_unit_remote(unit, endpoint)
            if self.cache is not None and self.cache.health is not None:
                # Static-path success evidence; failures propagate to the
                # caller before this line and are observed by dynamic sinks.
                self.cache.health.observe_success(endpoint, elapsed)
            if self.cache is not None and unit.ckey is not None:
                self._cache_store(
                    unit.ckey,
                    result.metrics,
                    task=unit.task_name,
                    params=unit.params,
                    platform=unit.platform.name,
                    elapsed_s=elapsed,
                )
            return result, False
        task = registry.get(unit.task_name)
        ctx = self._context(unit.platform, unit.task_name)
        self._ensure_prepared(task, unit.platform, ctx)
        # Cost evidence measures only the repeatable per-unit work: one-time
        # prepare and lock wait would inflate every racer's recorded cost.
        t0 = time.perf_counter()
        samples = task.run(ctx, dict(unit.params))
        samples = unit.platform.transform_samples(samples)
        vals = compute_metrics(samples, unit.metrics)
        elapsed = time.perf_counter() - t0
        with self._task_lock(unit.platform.name, unit.task_name):
            ctx.log.append(
                {"task": task.name, "params": dict(unit.params), "metrics": dict(vals)}
            )
        if self.cache is not None and unit.ckey is not None:
            self._cache_store(
                unit.ckey,
                vals,
                task=task.name,
                params=unit.params,
                platform=unit.platform.name,
                elapsed_s=elapsed,
            )
        return TestResult(task.name, dict(unit.params), vals, platform=unit.platform.name), False

    # -- box execution -----------------------------------------------------
    def _expand_candidates(self, box: Box, platforms: list[Platform]) -> list[_Unit]:
        """Expand the FULL (platform x task x params) grid, keys attached."""
        units: list[_Unit] = []
        # Validate the whole box before anything executes.
        fingerprints: dict[str, str] = {}
        for spec in box.tasks:
            task = registry.get(spec.task)
            task.validate_params(spec.params)
            fingerprints.setdefault(task.name, task.source_fingerprint())
        idx = 0
        for platform in platforms:
            for spec in box.tasks:
                task = registry.get(spec.task)
                metrics = tuple(spec.metrics) or tuple(task.default_metrics)
                for params in spec.expand():
                    # Shard assignment must NOT see the --remote endpoint:
                    # runners pointing different shards at different workers
                    # still have to cover the grid between them.  The cache
                    # key MUST see it: a remote host's measurement is not the
                    # local platform's measurement.
                    skey = cache_mod.cache_key(
                        task.name,
                        params,
                        platform.cache_identity(),
                        self.iters,
                        self.warmup,
                        metrics,
                        fingerprint=fingerprints[task.name],
                        min_time_s=self.min_time_s,
                    )
                    ckey = skey
                    fleet = self._fleet_identity()
                    if fleet is not None:
                        # The stable fleet name, never an individual worker
                        # endpoint: under elastic membership the same unit
                        # may execute on whichever worker pulls it, and its
                        # measurement identity is "this fleet", not "this
                        # ephemeral port".
                        ckey = cache_mod.cache_key(
                            task.name,
                            params,
                            {**platform.cache_identity(), "remote": fleet},
                            self.iters,
                            self.warmup,
                            metrics,
                            fingerprint=fingerprints[task.name],
                            min_time_s=self.min_time_s,
                        )
                    units.append(
                        _Unit(idx, platform, task.name, params, metrics, ckey, skey)
                    )
                    idx += 1
        return units

    def _prewarm_fleet(self, endpoints: list[str], timeout: float = 30.0) -> None:
        """Dial the whole fleet and learn every capacity in ONE wave.

        Without this, fleet cold start is serial: each ``_fleet_sink``
        calls :meth:`_endpoint_capacity`, whose fallback ping opens a
        connection and blocks for the round trip — N workers cost N
        back-to-back dials before the first unit moves.  On the async
        transport this method instead (1) prewarms every endpoint socket
        concurrently through the one event loop and (2) issues all the
        capacity pings as concurrent async requests, recording answers in
        the advertised map so the per-sink lookups below are pure dict
        hits.  Endpoints that fail to answer are simply not advertised —
        they keep the old per-sink fallback path and its failure
        semantics.  No-op on the threaded transport and for endpoints
        that already advertised (registry fleets heartbeat capacity).
        """
        if self.transport != "async":
            return
        todo = [ep for ep in endpoints if ep not in self._advertised]
        if not todo:
            return
        from repro.core.aiotransport import get_async_transport

        aio = get_async_transport()
        aio.prewarm(list(endpoints))
        lock = threading.Lock()
        done = threading.Event()
        answers: dict[str, dict[str, Any]] = {}
        remaining = len(todo)

        def on_pong(resp, exc, _ep):
            nonlocal remaining
            with lock:
                if exc is None and resp is not None and resp.get("ok"):
                    answers[_ep] = resp
                remaining -= 1
                if remaining == 0:
                    done.set()

        for ep in todo:
            aio.submit(
                ep, {"op": "ping"}, timeout=timeout,
                callback=lambda r, e, _ep=ep: on_pong(r, e, _ep),
            )
        done.wait(timeout + 5.0)  # bounded: the loop enforces each deadline
        for ep, resp in answers.items():
            self._advertise(
                {
                    "endpoint": ep,
                    "capacity": resp.get("capacity"),
                    "throughput": resp.get("throughput"),
                }
            )

    def _endpoint_capacity(self, endpoint: str, fallback: int = 1) -> int:
        """A worker's advertised concurrency, else ``fallback``.

        Heartbeat-advertised capacity (registry fleets) answers without any
        network round trip; only workers outside a registry get pinged.
        """
        from repro.core import remote as remote_mod

        adv = self._advertised.get(endpoint)
        if adv is not None:
            return adv["capacity"]
        info = remote_mod.get_transport(endpoint).info()
        if info is not None:
            try:
                return max(1, int(info.get("capacity", fallback) or fallback))
            except (TypeError, ValueError):
                pass
        return max(1, int(fallback))

    def _auto_weights(self, count: int) -> tuple[float, ...]:
        """Resolve ``@auto`` shard weights from fleet pings + cost evidence.

        Fleet endpoint i is shard i's home worker: its ping-advertised
        capacity and measured EWMA unit time size the shard.  Shards beyond
        the fleet (or the whole vector, with no fleet) are sized from local
        evidence: this executor's ``workers`` slots at the local CostModel's
        mean unit time.

        Determinism caveat: local evidence is per-runner.  Runners sharding
        the same box must resolve identical vectors or the grid loses
        coverage, so with a partial fleet (fewer endpoints than shards)
        every runner must use the same ``--workers`` and a shared cache;
        with a full fleet the inputs are the workers' own pings, which
        agree as long as the fleet is quiescent between resolutions (the
        lattice quantization in :func:`resolve_auto_weights` absorbs small
        EWMA jitter).  With no fleet at all the evidence is identical per
        shard, so resolution is uniform regardless of runner settings.
        """
        from repro.core import remote as remote_mod

        model = CostModel(self.cache)
        endpoints = self._remote_endpoints()
        self._prewarm_fleet(endpoints[:count])
        evidence: list[dict[str, Any]] = []
        for i in range(count):
            if i < len(endpoints):
                # Heartbeat-advertised evidence first (registry fleets carry
                # capacity AND measured throughput in every beat); ping only
                # hand-listed workers that never advertised.
                info = self._advertised.get(endpoints[i])
                if info is None:
                    info = remote_mod.get_transport(endpoints[i]).info() or {}
                throughput = info.get("throughput") or {}
                evidence.append(
                    {"capacity": info.get("capacity", 1), "ewma_s": throughput.get("ewma_s")}
                )
            else:
                evidence.append({"capacity": self.workers, "ewma_s": model.mean_elapsed_s})
        return resolve_auto_weights(count, evidence, default_unit_s=model.mean_elapsed_s)

    def _resolve_shard(self, shard: ShardSpec | None) -> ShardSpec | None:
        """Concretize an ``@auto`` spec; anything else passes through."""
        if shard is None or not shard.is_auto:
            return shard
        return shard.resolved(self._auto_weights(shard.count))

    def _shard_owner_map(
        self, units: list[_Unit], shard: ShardSpec
    ) -> dict[str, int] | None:
        """skey -> owning shard for cost-aware specs, None for legacy hash.

        Legacy (unweighted, count-balanced) sharding stays a pure per-key
        hash — fully resize-stable and independent of any cost evidence.
        Weighted specs (or ``weighted_shard=True``) balance ESTIMATED COST:
        runners that must agree on such a partition need the same cost view,
        i.e. a shared (pre-seeded) cache or none at all.
        """
        if shard.weights is None and not self.weighted_shard:
            return None
        model = CostModel(self.cache)
        # Evidence lookups go by skey (endpoint-free): runners pointing
        # their shards at different --remote workers must still resolve the
        # same costs, or their partitions diverge and drop grid coverage.
        costs = model.estimate_many(units, lookup="skey")
        return cost_shard_map(
            [u.skey for u in units], shard.count, weights=shard.weights, costs=costs
        )

    def _expand_units(
        self, box: Box, platforms: list[Platform], shard: ShardSpec | None = None
    ) -> list[_Unit]:
        return self._expand_partition(box, platforms, shard)[0]

    def _expand_partition(
        self, box: Box, platforms: list[Platform], shard: ShardSpec | None = None
    ) -> tuple[list[_Unit], list[_Unit]]:
        """(mine, foreign): this shard's slice plus every other shard's.

        ``foreign`` is the steal candidate pool — units some sibling runner
        owns, reachable here only through the shared cache's claim records.
        Unsharded runs own everything, so ``foreign`` is empty.
        """
        units = self._expand_candidates(box, platforms)
        if shard is None:
            return units, []
        shard = self._resolve_shard(shard)
        owner = self._shard_owner_map(units, shard)
        if owner is None:
            mine = [u for u in units if shard_of(u.skey, shard.count) == shard.index]
            foreign = [u for u in units if shard_of(u.skey, shard.count) != shard.index]
        else:
            mine = [u for u in units if owner[u.skey] == shard.index]
            foreign = [u for u in units if owner[u.skey] != shard.index]
        # Reindex: ``index`` is the position in THIS run's canonical row
        # assembly, which for a shard is its kept subsequence of the grid.
        for i, u in enumerate(mine):
            u.index = i
        return mine, foreign

    def shard_plan(self, box: Box, shard: ShardSpec) -> list[dict[str, Any]]:
        """Dry-run preview: per-shard unit count and estimated cost share.

        Uses the exact same partition path as execution (cost-aware when the
        spec carries weights or ``weighted_shard`` is set, ``@auto`` weights
        resolved from fleet pings, legacy hash otherwise), so the plan IS
        what ``run_box`` would do.
        """
        shard = self._resolve_shard(shard)
        platforms = self._box_platforms(box)
        units = self._expand_candidates(box, platforms)
        model = CostModel(self.cache)
        costs = model.estimate_many(units, lookup="skey")
        owner = self._shard_owner_map(units, shard)
        if owner is None:
            owner = {u.skey: shard_of(u.skey, shard.count) for u in units}
        n_units = [0] * shard.count
        loads = [0.0] * shard.count
        for u in units:
            i = owner[u.skey]
            n_units[i] += 1
            loads[i] += costs.get(u.skey, 1.0)
        total = sum(loads) or 1.0
        weights = shard.weights or (1.0,) * shard.count
        return [
            {
                "shard": str(ShardSpec(i, shard.count, shard.weights)),
                "weight": weights[i],
                "units": n_units[i],
                "est_cost": loads[i],
                "cost_share": loads[i] / total,
                "measured_points": model.measured_points,
            }
            for i in range(shard.count)
        ]

    def _box_platforms(self, box: Box) -> list[Platform]:
        """Box-declared platforms win unless the executor was given some."""
        if box.platforms and not self._platforms_explicit:
            return [resolve(p) for p in box.platforms]
        return self.platforms

    def run_box(self, box: Box, shard: ShardSpec | None = None) -> SweepResult:
        platforms = self._box_platforms(box)
        units, foreign = self._expand_partition(box, platforms, shard)
        out = SweepResult(box=box.name, platforms=[p.name for p in platforms])
        out.stats.total = len(units)
        ordered: list[TestResult | None] = [None] * len(units)

        def record_error(unit: _Unit, exc: BaseException) -> None:
            # Child failures already carry "Type: message" plus the
            # child-side traceback; don't re-wrap them in the parent's.
            if isinstance(exc, _ChildFailure):
                err, tb = str(exc), exc.child_traceback
            else:
                err = f"{type(exc).__name__}: {exc}"
                # The dynamic path records errors after the worker thread
                # unwound, so format from the exception's own traceback —
                # format_exc() would see no active exception there.
                if exc.__traceback__ is not None:
                    tb = "".join(traceback.format_exception(type(exc), exc, exc.__traceback__))
                else:
                    tb = traceback.format_exc()
            out.stats.errors += 1
            out.errors.append(
                {
                    "task": unit.task_name,
                    "params": json.dumps(unit.params, default=str),
                    "platform": unit.platform.name,
                    "error": err,
                    "traceback": tb,
                }
            )

        # Remote units are network-bound and must not re-execute locally in
        # a spawned child, so remote dispatch always goes through the
        # in-process (sequential/thread/dynamic-sink) paths.
        any_remote = self._fleet_identity() is not None or any(
            u.platform.kind == "remote" for u in units
        )
        # Dynamic (pull-based) scheduling is the default for pooled runs:
        # more than one local worker slot, a multi-worker remote fleet, or
        # ANY registry-discovered fleet (elastic membership needs the pull
        # scheduler to react to joins/leaves at all).  Single-worker local
        # runs keep the exact sequential seed path.
        dynamic = (
            self.schedule == "dynamic"
            and len(units) > 1
            and (
                self.workers > 1
                or len(self._remote_endpoints()) > 1
                or self.fleet_registry is not None
            )
        )
        try:
            if dynamic:
                self._run_dynamic(units, ordered, out, record_error)
            elif self.workers == 1 or len(units) <= 1:
                for unit in units:
                    try:
                        result, was_cached = self._run_unit(unit)
                    except Exception as e:  # noqa: BLE001 - report, keep going
                        if self.fail_fast:
                            raise
                        record_error(unit, e)
                        continue
                    ordered[unit.index] = result
                    out.stats.cached += was_cached
            elif self.pool == "thread" or any_remote:
                with ThreadPoolExecutor(max_workers=self.workers) as pool:
                    pairs = [
                        (unit, pool.submit(self._run_unit, unit))
                        for unit in self._dispatch_order(units)
                    ]
                    for unit, fut in pairs:
                        try:
                            result, was_cached = fut.result()
                        except Exception as e:  # noqa: BLE001
                            if self.fail_fast:
                                raise
                            record_error(unit, e)
                            continue
                        ordered[unit.index] = result
                        out.stats.cached += was_cached
            else:
                self._run_process_pool(units, ordered, out, record_error)
            if self.steal and shard is not None and foreign:
                self._steal_leftovers(foreign, shard, out)
        finally:
            # Persist whatever was measured even when fail_fast aborts the
            # sweep mid-way — the re-run then resumes from the cache.
            if self.cache is not None:
                self.cache.flush()

        out.results = [r for r in ordered if r is not None]
        out.stats.executed = len(out.results) - out.stats.cached

        # Report per (platform, task) in declaration order — identical row
        # order for any worker count.
        multi = len(platforms) > 1
        for platform in platforms:
            reported: set[str] = set()
            for spec in box.tasks:
                if spec.task in reported:
                    continue
                reported.add(spec.task)
                task = registry.get(spec.task)
                task_results = [
                    r
                    for r in out.results
                    if r.task == task.name and r.platform == platform.name
                ]
                ctx = self._context(platform, task.name)
                rows = task.report(ctx, task_results)
                if multi:
                    rows = [{**row, "platform": platform.name} for row in rows]
                out.rows.extend(rows)
        return out

    # -- cache-mediated work stealing --------------------------------------
    def _steal_leftovers(self, foreign: list[_Unit], shard: ShardSpec, out: SweepResult) -> None:
        """Drained early: claim and run sibling shards' unfinished units.

        Coordination is entirely through the shared :class:`ResultCache`
        (see its work-stealing note): an O_EXCL claim record keyed by the
        unit's endpoint-free ``skey`` elects exactly one stealer, the result
        publishes to disk under ``ckey``, and the owning shard picks it up
        as a cache hit.  Stolen results never enter THIS runner's report
        rows — merged output stays byte-identical to an unsharded run.
        Everything here is best-effort: a failed steal just leaves the unit
        for its owner.
        """
        import os

        if self.cache is None:
            return
        owner_id = f"shard-{shard.index}-{shard.count}-pid{os.getpid()}"
        model = CostModel(self.cache)
        costs = model.estimate_many(foreign, lookup="skey")
        # Heaviest first, cost ties from the BACK of the sibling's queue:
        # owners drain their slice front-to-back in grid order, so tail-end
        # steals (the classic stealing-deque rule) converge toward the
        # owner instead of duplicating the unit it is executing right now.
        for u in sorted(reversed(foreign), key=lambda x: -costs.get(x.skey or "", 1.0)):
            if u.skey is None or u.ckey is None:
                continue
            if self.cache.get(u.ckey) is not None:
                continue  # already measured (shared dedupe)
            if self.cache.refresh(u.ckey) is not None:
                continue  # its owner (or another stealer) published it
            if not self.cache.try_claim(u.skey, owner_id):
                continue  # lost the claim race
            try:
                self._run_unit(u)
            except Exception:  # noqa: BLE001 - owner still runs it
                continue
            self.cache.publish(u.ckey)
            out.stats.stolen += 1

    # -- dynamic (pull-based) scheduling -----------------------------------
    def _run_unit_process(self, unit: _Unit, proc_pool: ProcessPoolExecutor) -> tuple[TestResult, bool]:
        """A dynamic local sink's unit path under ``pool="process"``."""
        if self.cache is not None and unit.ckey is not None:
            hit = self.cache.get(unit.ckey)
            if hit is not None:
                return (
                    TestResult(
                        unit.task_name, dict(unit.params), hit, platform=unit.platform.name
                    ),
                    True,
                )
        res = proc_pool.submit(_subprocess_run_unit, _unit_payload(unit, self)).result()
        if not res["ok"]:
            raise _ChildFailure(res["error"], res.get("traceback", ""))
        vals = res["metrics"]
        if self.cache is not None and unit.ckey is not None:
            self._cache_store(
                unit.ckey,
                vals,
                task=unit.task_name,
                params=unit.params,
                platform=unit.platform.name,
                elapsed_s=res.get("elapsed_s"),
            )
        return TestResult(unit.task_name, dict(unit.params), vals, platform=unit.platform.name), False

    def _fleet_sink(self, ep: str) -> Sink:
        """A health-observing pull sink for one fleet worker endpoint.

        Transport-level failures (``WorkerUnreachable``: dead, hung past
        deadline, corrupt wire) feed the health sidecar's failure streak;
        clean task errors do NOT — the endpoint answered, it is healthy.

        On the default ``transport="async"`` the sink is callback-based:
        units go out as id-tagged frames on the shared
        :mod:`repro.core.aiotransport` loop's one persistent connection to
        this worker, and completion (the same cache-put/health/ctx-log
        bookkeeping as the threaded path) runs on the loop thread.  The
        sink's capacity is the per-endpoint in-flight admission bound —
        ``max_inflight`` when set, else the worker's advertised capacity.
        """
        from repro.core.remote import RemoteExecutionError, WorkerUnreachable

        health = self.cache.health if self.cache is not None else None

        def run(u, _ep=ep):
            try:
                return self._run_unit(u, endpoint=_ep)
            except WorkerUnreachable:
                if health is not None:
                    health.observe_failure(_ep)
                raise

        capacity = self._endpoint_capacity(ep)
        if self.transport != "async":
            return Sink(name=ep, capacity=capacity, run=run)

        def submit(u, done, _ep=ep):
            if self.cache is not None and u.ckey is not None:
                hit = self.cache.get(u.ckey)
                if hit is not None:
                    done(
                        result=TestResult(
                            u.task_name, dict(u.params), hit, platform=u.platform.name
                        ),
                        was_cached=True,
                    )
                    return
            from repro.core.aiotransport import get_async_transport

            def on_done(resp, exc, _u=u):
                try:
                    if exc is not None:
                        if isinstance(exc, WorkerUnreachable) and health is not None:
                            health.observe_failure(_ep)
                        done(error=exc)
                        return
                    if not resp.get("ok"):
                        done(
                            error=RemoteExecutionError(
                                f"worker {_ep} failed: {resp.get('error', 'unknown error')}"
                            )
                        )
                        return
                    vals = {k: float(v) for k, v in resp["metrics"].items()}
                    ctx = self._context(_u.platform, _u.task_name)
                    with self._task_lock(_u.platform.name, _u.task_name):
                        ctx.log.append(
                            {
                                "task": _u.task_name,
                                "params": dict(_u.params),
                                "metrics": dict(vals),
                            }
                        )
                    elapsed = resp.get("elapsed_s")
                    elapsed = float(elapsed) if elapsed is not None else None
                    if health is not None:
                        health.observe_success(_ep, elapsed)
                    if self.cache is not None and _u.ckey is not None:
                        self._cache_store(
                            _u.ckey,
                            vals,
                            task=_u.task_name,
                            params=_u.params,
                            platform=_u.platform.name,
                            elapsed_s=elapsed,
                        )
                    done(
                        result=TestResult(
                            _u.task_name, dict(_u.params), vals, platform=_u.platform.name
                        )
                    )
                except Exception as e:  # noqa: BLE001 - bookkeeping bug -> unit error
                    done(error=e)

            get_async_transport().submit(
                _ep,
                {"op": "run", "payload": _unit_payload(u, self, want_samples=True)},
                timeout=self._unit_deadline(u),
                callback=on_done,
            )

        return Sink(
            name=ep,
            capacity=self.max_inflight or capacity,
            run=run,
            submit=submit,
        )

    def _dynamic_sinks(
        self, units: list[_Unit], stats: SweepStats | None = None
    ) -> tuple[list[Sink], list[WorkItem], ProcessPoolExecutor | None]:
        """Build the pull sinks and eligibility-tagged work items.

        With an executor-wide fleet, every unit may run on any fleet sink
        (the fleet identity — not the individual endpoint — is the cache
        identity, so first-completion-wins speculation dedupes cleanly);
        those units carry DYNAMIC eligibility (``sinks=None``), so sinks a
        FleetWatcher adds mid-sweep pick them up too.  Otherwise each unit
        binds to the one sink that matches its measurement target: its
        remote platform's endpoint, or the local thread/process slots.

        Chronically bad endpoints — health-sidecar failure streak at or
        past ``BLACKLIST_AFTER`` — are excluded up front, but only while a
        healthy alternative exists: an all-blacklisted fleet runs in full
        (degraded beats impossible) and a success there resets the streaks.
        """
        from repro.core import remote as remote_mod

        model = CostModel(self.cache)
        costs = model.estimate_many(units)
        sinks: list[Sink] = []
        items: list[WorkItem] = []
        endpoints = self._remote_endpoints()
        if not endpoints and self.fleet_registry is not None:
            # Elastic fleet with nobody home yet: give workers one grace
            # window to register before declaring the fleet empty.  The
            # required wait's failure message carries the partial view
            # (who registered, who is missing, which replicas answered).
            try:
                remote_mod.wait_members(
                    self.fleet_registry, count=1, timeout=30.0, required=True
                )
            except remote_mod.RemoteExecutionError as e:
                raise RemoteFleetEmpty(
                    f"registry {self.fleet_registry} has no alive workers: {e}"
                ) from e
            endpoints = self._remote_endpoints()
            if not endpoints:
                raise RemoteFleetEmpty(
                    f"registry {self.fleet_registry} has no alive workers"
                )
        if endpoints:
            health = self.cache.health if self.cache is not None else None
            if health is not None:
                healthy = [ep for ep in endpoints if not health.blacklisted(ep)]
                if healthy and len(healthy) < len(endpoints):
                    if stats is not None:
                        stats.blacklisted = len(endpoints) - len(healthy)
                    endpoints = healthy
            # One concurrent dial+ping wave before the per-sink capacity
            # lookups: fleet-wide cold start stops being serial round trips.
            self._prewarm_fleet(endpoints)
            sinks = [self._fleet_sink(ep) for ep in endpoints]
            items = [WorkItem(u, costs.get(u.skey or "", 1.0), None) for u in units]
            return sinks, items, None
        proc_pool: ProcessPoolExecutor | None = None
        sink_of_endpoint: dict[str, int] = {}
        local_id: int | None = None
        for u in units:
            ep = u.platform.endpoint()
            if ep is not None:
                sid = sink_of_endpoint.get(ep)
                if sid is None:
                    fallback = int(u.platform.flags.get("capacity", 1) or 1)
                    sinks.append(
                        Sink(
                            name=ep,
                            capacity=self._endpoint_capacity(ep, fallback=fallback),
                            run=lambda x, _ep=ep: self._run_unit(x, endpoint=_ep),
                        )
                    )
                    sid = sink_of_endpoint[ep] = len(sinks) - 1
            else:
                if local_id is None:
                    if self.pool == "process":
                        import multiprocessing

                        proc_pool = ProcessPoolExecutor(
                            max_workers=self.workers,
                            mp_context=multiprocessing.get_context("spawn"),
                        )
                        pool_ref = proc_pool
                        run = lambda x: self._run_unit_process(x, pool_ref)  # noqa: E731
                    else:
                        run = self._run_unit
                    sinks.append(Sink(name="local", capacity=self.workers, run=run))
                    local_id = len(sinks) - 1
                sid = local_id
            items.append(WorkItem(u, costs.get(u.skey or "", 1.0), (sid,)))
        return sinks, items, proc_pool

    def _run_dynamic(self, units, ordered, out, record_error) -> None:
        sinks, items, proc_pool = self._dynamic_sinks(units, out.stats)
        watcher = None
        try:
            scheduler = FleetScheduler(
                sinks,
                straggler_factor=self.straggler_factor,
                fail_fast=self.fail_fast,
            )
            if self.fleet_registry is not None:
                # Elastic membership: follow the registry while the sweep
                # runs — newly registered workers become sinks mid-sweep,
                # suspect/vanished ones are marked dead and their units
                # re-enqueued within the heartbeat detection bound.
                from repro.runtime.elastic import FleetWatcher

                def observe(members: list[dict]) -> None:
                    # Keep the advertised capacity/throughput map fresh from
                    # heartbeat payloads: a worker joining mid-sweep becomes
                    # a sink without a single startup ping.
                    for m in members:
                        self._advertise(m)

                watcher = FleetWatcher(
                    self.fleet_registry,
                    scheduler,
                    make_sink=self._fleet_sink,
                    observe=observe,
                )
                watcher.start()
            outcomes = scheduler.run(items)
            # Client-thread economics of this sweep: the scheduler's own
            # dispatch/puller threads, plus the one shared async IO loop
            # when any sink multiplexed through it.
            out.stats.dispatch_threads = scheduler.threads_started + int(
                any(s.submit is not None for s in scheduler.sinks)
            )
        finally:
            if watcher is not None:
                watcher.stop()
                out.stats.registry_poll_failures = watcher.poll_failures
            if proc_pool is not None:
                # Don't wait: a wedged child (the reason its unit was
                # speculated) must not block the sweep's return.
                proc_pool.shutdown(wait=False, cancel_futures=True)
        for oc in outcomes:
            unit = oc.item.unit
            out.stats.speculated += bool(oc.speculated)
            out.stats.redispatched += bool(oc.redispatched)
            if oc.error is not None:
                if self.fail_fast:
                    raise oc.error
                record_error(unit, oc.error)
            elif oc.result is not None:
                ordered[unit.index] = oc.result
                out.stats.cached += oc.was_cached
                if (
                    oc.speculated
                    and not oc.was_cached
                    and self.cache is not None
                    and unit.ckey is not None
                ):
                    # Both attempts of a speculated unit share one cache key;
                    # a losing attempt finishing AFTER the winner would have
                    # overwritten the entry with its own measurement.
                    # Re-assert the winner so the cache agrees with the
                    # emitted row.
                    self._cache_store(
                        unit.ckey,
                        oc.result.metrics,
                        task=unit.task_name,
                        params=unit.params,
                        platform=unit.platform.name,
                        elapsed_s=oc.elapsed_s,
                    )

    def _dispatch_order(self, units: list[_Unit]) -> list[_Unit]:
        """Pool submission order: longest-processing-time-first.

        Heaviest estimated units start first so the slowest one never ends
        up running alone after every other worker drained (the classic LPT
        makespan win).  With no cost evidence estimates are uniform and the
        stable sort degrades to grid order.  Report rows are assembled by
        ``unit.index`` regardless, so output is order-independent.
        """
        model = CostModel(self.cache)
        costs = model.estimate_many(units)
        return sorted(units, key=lambda u: -costs.get(u.skey or "", 1.0))

    def _run_process_pool(self, units, ordered, out, record_error) -> None:
        import multiprocessing

        # Parent owns the cache; children only ever see cache misses.
        misses: list[_Unit] = []
        for unit in units:
            hit = self.cache.get(unit.ckey) if (self.cache and unit.ckey) else None
            if hit is not None:
                ordered[unit.index] = TestResult(
                    unit.task_name, dict(unit.params), hit, platform=unit.platform.name
                )
                out.stats.cached += 1
            else:
                misses.append(unit)
        if not misses:
            return
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=self.workers, mp_context=ctx) as pool:
            pairs = [
                (unit, pool.submit(_subprocess_run_unit, _unit_payload(unit, self)))
                for unit in self._dispatch_order(misses)
            ]
            for unit, fut in pairs:
                try:
                    res = fut.result()
                except Exception as e:  # noqa: BLE001 - pool/pickling failure
                    if self.fail_fast:
                        raise
                    record_error(unit, e)
                    continue
                if not res["ok"]:
                    if self.fail_fast:
                        raise RuntimeError(res["error"])
                    out.stats.errors += 1
                    out.errors.append(
                        {
                            "task": unit.task_name,
                            "params": json.dumps(unit.params, default=str),
                            "platform": unit.platform.name,
                            "error": res["error"],
                            "traceback": res["traceback"],
                        }
                    )
                    continue
                vals = res["metrics"]
                ordered[unit.index] = TestResult(
                    unit.task_name, dict(unit.params), vals, platform=unit.platform.name
                )
                if self.cache is not None and unit.ckey is not None:
                    self._cache_store(
                        unit.ckey,
                        vals,
                        task=unit.task_name,
                        params=unit.params,
                        platform=unit.platform.name,
                        elapsed_s=res.get("elapsed_s"),
                    )

    # -- cleanup -----------------------------------------------------------
    def clean(self, task_name: str | None = None) -> None:
        """Explicit cleanup (paper step 6) — restores pre-benchmark state."""
        if task_name is not None:
            names = [task_name]
        else:
            names = sorted({t for (_, t) in self._prep})
        for name in names:
            task = registry.get(name)
            # Clean every context that actually exists for this task — boxes
            # may have swept platforms the executor wasn't constructed with.
            with self._lock:
                keys = sorted(
                    {k for k in (*self._contexts, *self._prep) if k[1] == name}
                )
            if not keys:
                # Nothing prepared: still hand the task a fresh context so an
                # explicit clean of on-disk state works (seed behaviour).
                keys = [(p.name, name) for p in self.platforms]
            for key in keys:
                with self._lock:
                    ctx = self._contexts.pop(key, None)
                    self._prep.pop(key, None)
                if ctx is None:
                    ctx = TaskContext(
                        platform={"name": key[0]}, iters=self.iters, warmup=self.warmup
                    )
                task.clean(ctx)


# -- process-pool worker (module level: must be picklable by spawn) ----------
_CHILD_CONTEXTS: dict[tuple[str, str], TaskContext] = {}
# Guards the context get-or-create ONLY (task.run stays outside): a spawn
# child is single-threaded, but the `fleet` CLI runs N WorkerServers in one
# process, all dispatching concurrently into this function with N separate
# per-server lock tables — without this, racers double-prepare a context.
_CHILD_LOCK = threading.Lock()


def _unit_payload(unit: _Unit, ex: SweepExecutor, want_samples: bool = False) -> dict[str, Any]:
    import dataclasses

    platform = dataclasses.asdict(unit.platform)
    # The worker executes locally: strip the dispatch endpoint so a remote
    # platform measures as its base identity on the worker host.
    if platform.get("kind") == "remote":
        platform = {
            **platform,
            "kind": "host",
            "flags": {k: v for k, v in platform["flags"].items() if k != "endpoint"},
        }
    return {
        "task": unit.task_name,
        "params": unit.params,
        "metrics": list(unit.metrics),
        "platform": platform,
        "iters": ex.iters,
        "warmup": ex.warmup,
        "min_time_s": ex.min_time_s,
        # Spawned children / remote workers start from a fresh interpreter:
        # hand over the plugin dirs loaded in this process so directory
        # plugin tasks resolve there too.
        "plugin_dirs": registry.plugin_dirs(),
        # Raw samples are only worth serializing back over a transport that
        # wants to stream them; the process pool reads metrics alone.
        "want_samples": want_samples,
    }


def _subprocess_run_unit(payload: dict[str, Any]) -> dict[str, Any]:
    import dataclasses

    try:
        registry.load_plugin_dirs(payload.get("plugin_dirs", ()))
        platform = Platform(**payload["platform"])
        task = registry.get(payload["task"])
        key = (platform.name, task.name)
        with _CHILD_LOCK:
            ctx = _CHILD_CONTEXTS.get(key)
            if ctx is None:
                ctx = TaskContext(
                    platform=platform.describe(),
                    iters=payload["iters"],
                    warmup=payload["warmup"],
                    min_time_s=float(payload.get("min_time_s", 0.0)),
                )
                task.prepare(ctx)
                _CHILD_CONTEXTS[key] = ctx
            else:
                # Long-lived workers reuse the prepared context across client
                # runs; the measurement knobs are per-request (and part of the
                # client's cache identity), so refresh them every time.
                # Same-key requests are serialized by the worker's
                # per-(platform, task) locks, so this mutation cannot race a
                # running unit.
                ctx.iters = payload["iters"]
                ctx.warmup = payload["warmup"]
                ctx.min_time_s = float(payload.get("min_time_s", 0.0))
        # Cost evidence measures only the repeatable per-unit work, matching
        # the in-process path (one-time bootstrap/prepare stays out).
        t0 = time.perf_counter()
        samples = task.run(ctx, dict(payload["params"]))
        samples = platform.transform_samples(samples)
        vals = compute_metrics(samples, tuple(payload["metrics"]))
        # Wall cost of the unit on THIS host — scheduling evidence for the
        # parent's cache (CostModel) on later runs.
        out = {"ok": True, "metrics": vals, "elapsed_s": time.perf_counter() - t0}
        if payload.get("want_samples"):
            # Raw samples ride along so transports can stream the measurement
            # itself, not just the aggregates (repro.core.remote.samples_from_wire).
            out["samples"] = dataclasses.asdict(samples)
        return out
    except Exception as e:  # noqa: BLE001 - serialize the failure for the parent
        return {"ok": False, "error": f"{type(e).__name__}: {e}", "traceback": traceback.format_exc()}


__all__ = [
    "SweepExecutor",
    "SweepResult",
    "SweepStats",
]
