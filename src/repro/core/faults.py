"""Fault injection for worker fleets (tests and the CI soak — never prod).

The elastic-fleet layer's whole claim is "a worker can die, hang, stall, or
corrupt the wire mid-sweep and the merged report is still byte-identical to
a fault-free run".  This module makes those failures reproducible: a worker
started with ``--allow-faults`` honors an armed ``{"op": "fault"}`` request
and misbehaves on its NEXT run request(s) —

  ``kill``     ``os._exit`` mid-unit: no response, no deregister — the
               client sees the connection drop, the registry sees beats
               stop.  The crashed-process case.
  ``hang``     accept the unit, never reply (heartbeats keep flowing from
               their own thread): the wedged-core case the BlueField
               studies report.  Only per-unit deadlines / straggler
               re-dispatch catch this one.
  ``slow``     sleep ``seconds`` then execute normally: the transient
               straggler that must NOT be counted as dead.
  ``partial``  write truncated garbage JSON and drop the connection: the
               corrupted-wire case.

:class:`FaultPlan` draws a seeded random schedule of those modes, so a soak
run is chaotic but exactly reproducible from its seed, and
:class:`FaultyFleet` keeps a registered ``LocalWorker`` fleet at target
strength by respawning killed members — the "replacement capacity joins
mid-sweep" half of elasticity.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Sequence

from repro.core.remote import (
    LocalWorker,
    RemoteExecutionError,
    get_transport,
    wait_members,
    wait_ready,
)

#: Modes a --allow-faults worker understands (order = doc order above).
FAULT_MODES = ("kill", "hang", "slow", "partial")

#: Control-plane modes, applied by the HARNESS to registry replicas it owns
#: (:class:`RegistryReplicas`) — never shipped over the wire, so a worker's
#: ``_arm_fault`` keeps rejecting them:
#:
#:   ``registry-kill``       drop the replica's whole worker table and
#:                           restart it empty on the same port — it must
#:                           re-converge from peer sync + re-admission.
#:   ``registry-partition``  stop serving but PARK the table; healing
#:                           re-serves the now-stale state, which the
#:                           last-beat-wins merge must reconcile away.
REGISTRY_FAULT_MODES = ("registry-kill", "registry-partition")


@dataclass(frozen=True)
class FaultSpec:
    """One armed misbehaviour: ``mode`` applied to the next ``units`` run
    requests, sleeping ``seconds`` where the mode takes a duration."""

    mode: str
    seconds: float = 0.5
    units: int = 1

    def __post_init__(self) -> None:
        if self.mode not in FAULT_MODES + REGISTRY_FAULT_MODES:
            raise ValueError(
                f"unknown fault mode {self.mode!r}; known: "
                f"{FAULT_MODES + REGISTRY_FAULT_MODES}"
            )
        if self.seconds < 0:
            raise ValueError(f"fault seconds must be >= 0, got {self.seconds}")
        if self.units < 1:
            raise ValueError(f"fault units must be >= 1, got {self.units}")


def inject(endpoint: str, spec: FaultSpec, timeout: float = 10.0) -> dict[str, Any]:
    """Arm ``spec`` on a ``--allow-faults`` worker; raises if it refuses."""
    resp = get_transport(endpoint).request(
        {"op": "fault", "mode": spec.mode, "seconds": spec.seconds, "units": spec.units},
        timeout=timeout,
        connect_retries=1,
    )
    if not resp.get("ok"):
        raise RemoteExecutionError(f"worker {endpoint} refused fault: {resp.get('error')}")
    return resp


@dataclass
class FaultEvent:
    """One injected fault as the soak log records it."""

    t_s: float
    endpoint: str
    spec: FaultSpec


class FaultPlan:
    """Seeded random fault schedule: same seed -> same chaos.

    ``draw()`` yields the next (mode, seconds) pair from the seeded stream;
    mode weights favour the recoverable modes so a soak keeps making
    progress while still exercising every path.
    """

    #: (mode, weight): kill is rarer because each one costs a respawn.
    WEIGHTS = (("slow", 4), ("hang", 3), ("partial", 2), ("kill", 1))

    def __init__(
        self,
        seed: int,
        max_sleep_s: float = 1.0,
        weights: Sequence[tuple[str, int]] | None = None,
    ):
        self._rng = random.Random(seed)
        self.max_sleep_s = float(max_sleep_s)
        self.weights = tuple(weights) if weights is not None else self.WEIGHTS

    def draw(self) -> FaultSpec:
        modes = [m for m, w in self.weights for _ in range(w)]
        mode = self._rng.choice(modes)
        return FaultSpec(mode=mode, seconds=round(self._rng.uniform(0.1, self.max_sleep_s), 3))


class FaultyFleet:
    """A registered ``LocalWorker`` fleet that survives its own faults.

    Spawns ``size`` loopback workers (all ``--allow-faults``, all registered
    against ``register``), then — while :meth:`run` is active — injects
    faults from a seeded :class:`FaultPlan` at ``period_s`` intervals and
    respawns any worker its own ``kill`` took down, so fleet strength
    recovers and the sweep sees both *leave* and *join* membership events.

    Use as a context manager::

        with FaultyFleet(4, register=reg.endpoint, plugin_dirs=[...],
                         seed=7) as fleet:
            fleet.start(period_s=1.0)
            ... run the sweep ...
            events = fleet.stop()
    """

    def __init__(
        self,
        size: int,
        register: str,
        plugin_dirs: Sequence[Any] = (),
        seed: int = 0,
        heartbeat_interval_s: float = 0.5,
        max_sleep_s: float = 1.0,
        capacity: int = 1,
    ):
        if size < 1:
            raise ValueError(f"fleet size must be >= 1, got {size}")
        self.size = size
        self.register = register
        self.plugin_dirs = [str(d) for d in plugin_dirs]
        self.heartbeat_interval_s = heartbeat_interval_s
        self.capacity = capacity
        self.plan = FaultPlan(seed, max_sleep_s=max_sleep_s)
        self.workers: list[LocalWorker] = []
        self.events: list[FaultEvent] = []
        self.respawns = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0 = 0.0

    def _spawn(self) -> LocalWorker:
        w = LocalWorker(
            plugin_dirs=self.plugin_dirs,
            capacity=self.capacity,
            register=self.register,
            heartbeat_interval_s=self.heartbeat_interval_s,
            allow_faults=True,
        )
        w.__enter__()
        wait_ready(w.endpoint, timeout=60.0)
        return w

    def __enter__(self) -> "FaultyFleet":
        try:
            for _ in range(self.size):
                self.workers.append(self._spawn())
            wait_members(self.register, count=self.size, timeout=60.0)
        except BaseException:
            self.__exit__(None, None, None)
            raise
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
        for w in self.workers:
            w.__exit__(None, None, None)
        self.workers.clear()

    @property
    def endpoints(self) -> list[str]:
        return [w.endpoint for w in self.workers if w.endpoint]

    # -- chaos loop ----------------------------------------------------------
    def start(self, period_s: float = 1.0) -> None:
        """Begin injecting one fault per ``period_s`` at random targets."""
        if self._thread is not None:
            return
        self._t0 = time.monotonic()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, args=(period_s,), daemon=True, name="fault-injector"
        )
        self._thread.start()

    def stop(self) -> list[FaultEvent]:
        """Stop injecting, respawn any dead member, return the event log."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self._respawn_dead()
        return list(self.events)

    def _respawn_dead(self) -> None:
        for i, w in enumerate(self.workers):
            if not w.alive:
                w.__exit__(None, None, None)
                self.workers[i] = self._spawn()
                self.respawns += 1

    def _loop(self, period_s: float) -> None:
        rng = self.plan._rng  # share the seeded stream for target choice too
        while not self._stop.wait(period_s):
            self._respawn_dead()
            live = [w for w in self.workers if w.alive and w.endpoint]
            if not live:
                continue
            target = rng.choice(live)
            spec = self.plan.draw()
            try:
                inject(target.endpoint, spec)
            except RemoteExecutionError:
                continue  # target died between choice and arm; next tick respawns
            self.events.append(
                FaultEvent(t_s=time.monotonic() - self._t0, endpoint=target.endpoint, spec=spec)
            )


class RegistryReplicas:
    """An in-process replicated membership plane the harness can abuse.

    Binds ``count`` mutually-peered registry replicas on ephemeral loopback
    ports (``warmup=False`` — a brand-new plane has no tracked sinks to
    protect, so gating its first answers would only slow cold start) and
    keeps the PORTS stable across kill/partition cycles, so workers beating
    at the comma-joined ``register`` list and sweeps polling the same
    ``--registry`` value reconnect to a healed replica without any
    re-configuration — exactly how a restarted registry host behaves.

    ``kill(i)``       discard replica i's worker table and stop serving;
                      :meth:`restart` brings it back EMPTY (and warming up:
                      it refuses ``fleet`` until a peer sync lands or a
                      full suspect window passes, so a poller can never
                      adopt its empty view as truth).
    ``partition(i)``  stop serving but keep the table; :meth:`heal`
                      re-serves the stale state for the merge to reconcile.
    """

    def __init__(
        self,
        count: int = 3,
        heartbeat_interval_s: float = 0.5,
        sync_interval_s: float | None = None,
        host: str = "127.0.0.1",
    ):
        if count < 1:
            raise ValueError(f"replica count must be >= 1, got {count}")
        self.count = count
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.sync_interval_s = sync_interval_s
        self.host = host
        self.servers: list[Any] = []
        self.endpoints: list[str] = []
        self.ports: list[int] = []
        self._parked: dict[int, Any] = {}  # partitioned registries, state kept

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "RegistryReplicas":
        from repro.runtime.membership import MembershipServer, ReplicatedRegistry

        self._mk_server = MembershipServer
        self._mk_registry = ReplicatedRegistry
        try:
            # Bind all replicas first so every peer list is complete.
            for _ in range(self.count):
                srv = MembershipServer(
                    self.host, 0,
                    registry=ReplicatedRegistry(
                        heartbeat_interval_s=self.heartbeat_interval_s,
                        sync_interval_s=self.sync_interval_s,
                        warmup=False,
                    ),
                )
                self.servers.append(srv)
                self.endpoints.append(srv.endpoint)
                self.ports.append(srv.server_address[1])
            for i, srv in enumerate(self.servers):
                srv.registry.peers = [ep for j, ep in enumerate(self.endpoints) if j != i]
                srv.serve_in_thread()
        except BaseException:
            self.__exit__(None, None, None)
            raise
        return self

    def __exit__(self, *exc) -> None:
        for srv in self.servers:
            if srv is not None:
                srv.shutdown()
                srv.server_close()
        self.servers.clear()
        self._parked.clear()

    @property
    def register(self) -> str:
        """The comma-joined replica list — ``--register``/``--registry`` value."""
        return ",".join(self.endpoints)

    def up(self) -> list[int]:
        """Indices of replicas currently serving."""
        return [i for i, srv in enumerate(self.servers) if srv is not None]

    # -- faults --------------------------------------------------------------
    def _stop_server(self, i: int) -> Any:
        srv = self.servers[i]
        if srv is None:
            raise ValueError(f"replica {i} is already down")
        srv.shutdown()
        srv.server_close()
        self.servers[i] = None
        return srv

    def _serve(self, i: int, reg: Any) -> None:
        srv = self._mk_server(self.host, self.ports[i], registry=reg)
        self.servers[i] = srv
        srv.serve_in_thread()

    def kill(self, i: int) -> None:
        """registry-kill: drop replica i's state and stop serving."""
        self._stop_server(i)
        self._parked.pop(i, None)

    def restart(self, i: int) -> None:
        """Bring a killed replica back EMPTY on its original port, warming
        up: it must converge from peer sync / worker re-admission before it
        answers ``fleet``."""
        if self.servers[i] is not None:
            raise ValueError(f"replica {i} is still up")
        reg = self._mk_registry(
            peers=[ep for j, ep in enumerate(self.endpoints) if j != i],
            heartbeat_interval_s=self.heartbeat_interval_s,
            sync_interval_s=self.sync_interval_s,
        )
        self._parked.pop(i, None)
        self._serve(i, reg)

    def partition(self, i: int) -> None:
        """registry-partition: stop serving replica i but PARK its table."""
        srv = self._stop_server(i)
        self._parked[i] = srv.registry

    def heal(self, i: int) -> None:
        """Re-serve a partitioned replica with its (now stale) parked state;
        the next sync round's last-beat-wins merge reconciles it."""
        reg = self._parked.pop(i, None)
        if reg is None:
            raise ValueError(f"replica {i} is not partitioned (kill/restart instead?)")
        self._serve(i, reg)

    def repair(self, i: int) -> None:
        """Whatever is wrong with replica i, undo it."""
        if self.servers[i] is not None:
            return
        if i in self._parked:
            self.heal(i)
        else:
            self.restart(i)


class RegistryChaos:
    """Seeded control-plane chaos over a :class:`RegistryReplicas` plane.

    Draws ``registry-partition``/``registry-kill`` faults from the same
    seeded :class:`FaultPlan` machinery the worker soak uses (same seed ->
    same chaos), applies each to a random UP replica, and repairs it after
    the drawn duration — while always leaving at least ``min_up`` replicas
    serving, so the plane degrades but never (unless asked) goes fully
    dark.  ``stop()`` repairs everything outstanding.
    """

    #: Partitions outnumber kills: they exercise the stale-merge path, and
    #: each kill costs the plane a full warmup+resync cycle.
    WEIGHTS = (("registry-partition", 2), ("registry-kill", 1))

    def __init__(
        self,
        replicas: RegistryReplicas,
        seed: int = 0,
        max_sleep_s: float = 1.5,
        min_up: int = 1,
    ):
        self.replicas = replicas
        self.plan = FaultPlan(seed, max_sleep_s=max_sleep_s, weights=self.WEIGHTS)
        self.min_up = max(0, int(min_up))
        self.events: list[FaultEvent] = []
        self._due: dict[int, float] = {}  # replica index -> monotonic repair time
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0 = 0.0

    def start(self, period_s: float = 1.0) -> None:
        if self._thread is not None:
            return
        self._t0 = time.monotonic()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, args=(period_s,), daemon=True, name="registry-chaos"
        )
        self._thread.start()

    def stop(self) -> list[FaultEvent]:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        for i in list(self._due):
            self.replicas.repair(i)
            del self._due[i]
        return list(self.events)

    def _loop(self, period_s: float) -> None:
        rng = self.plan._rng  # one seeded stream: modes, targets, durations
        while not self._stop.wait(period_s):
            now = time.monotonic()
            for i, due_at in list(self._due.items()):
                if now >= due_at:
                    self.replicas.repair(i)
                    del self._due[i]
            up = self.replicas.up()
            if len(up) <= self.min_up:
                continue
            target = rng.choice(up)
            spec = self.plan.draw()
            if spec.mode == "registry-kill":
                self.replicas.kill(target)
            else:
                self.replicas.partition(target)
            self._due[target] = time.monotonic() + spec.seconds
            self.events.append(
                FaultEvent(
                    t_s=time.monotonic() - self._t0,
                    endpoint=self.replicas.endpoints[target],
                    spec=spec,
                )
            )


__all__ = [
    "FAULT_MODES",
    "REGISTRY_FAULT_MODES",
    "FaultEvent",
    "FaultPlan",
    "FaultSpec",
    "FaultyFleet",
    "RegistryChaos",
    "RegistryReplicas",
    "inject",
]
