"""Metric definitions and aggregation.

dpBento tasks declare *metrics of interest*; one test may yield several
metrics (the paper explicitly does not cross-join parameters with metrics).
A metric is computed from a list of raw samples (usually per-iteration wall
times in seconds) plus optional work counters (ops, bytes, tuples).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable


def _percentile(sorted_xs: list[float], q: float) -> float:
    if not sorted_xs:
        return math.nan
    if len(sorted_xs) == 1:
        return sorted_xs[0]
    pos = q / 100.0 * (len(sorted_xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(sorted_xs) - 1)
    frac = pos - lo
    return sorted_xs[lo] * (1 - frac) + sorted_xs[hi] * frac


@dataclass
class Samples:
    """Raw measurement output of one test run."""

    times_s: list[float] = field(default_factory=list)
    # Work done per iteration, used to derive rates.
    ops_per_iter: float = 0.0
    bytes_per_iter: float = 0.0
    items_per_iter: float = 0.0  # tuples / requests / tokens
    extra: dict[str, float] = field(default_factory=dict)


# metric name -> fn(Samples) -> float
_METRICS: dict[str, Callable[[Samples], float]] = {}


def metric(name: str):
    def deco(fn: Callable[[Samples], float]):
        _METRICS[name] = fn
        return fn

    return deco


@metric("avg_latency_us")
def _avg_latency(s: Samples) -> float:
    return 1e6 * sum(s.times_s) / len(s.times_s) if s.times_s else math.nan


@metric("p50_latency_us")
def _p50(s: Samples) -> float:
    return 1e6 * _percentile(sorted(s.times_s), 50)


@metric("p99_latency_us")
def _p99(s: Samples) -> float:
    return 1e6 * _percentile(sorted(s.times_s), 99)


@metric("min_latency_us")
def _min(s: Samples) -> float:
    return 1e6 * min(s.times_s) if s.times_s else math.nan


@metric("ops_per_s")
def _ops(s: Samples) -> float:
    t = min(s.times_s) if s.times_s else math.nan
    return s.ops_per_iter / t if t else math.nan


@metric("bandwidth_gb_s")
def _bw(s: Samples) -> float:
    t = min(s.times_s) if s.times_s else math.nan
    return s.bytes_per_iter / t / 1e9 if t else math.nan


@metric("items_per_s")
def _items(s: Samples) -> float:
    t = min(s.times_s) if s.times_s else math.nan
    return s.items_per_iter / t if t else math.nan


def known_metrics() -> tuple[str, ...]:
    return tuple(_METRICS)


def compute_metrics(samples: Samples, names: tuple[str, ...] | list[str]) -> dict[str, float]:
    out: dict[str, float] = {}
    for name in names:
        if name in _METRICS:
            out[name] = float(_METRICS[name](samples))
        elif name in samples.extra:
            out[name] = float(samples.extra[name])
        else:
            raise KeyError(
                f"unknown metric {name!r}; known: {sorted(_METRICS)} + extra {sorted(samples.extra)}"
            )
    # Extras a task reported unconditionally ride along (e.g. derived roofline terms).
    for k, v in samples.extra.items():
        out.setdefault(k, float(v))
    return out
