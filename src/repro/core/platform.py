"""Execution platform registry.

dpBento's point (paper §3.3) is sweeping the SAME test grid across several
execution targets — host CPU, DPU cores, DPU accelerators — and comparing.
A :class:`Platform` names one such target and carries everything the
framework needs to run tests "on" it:

  * ``flags`` — capability hints handed to tasks via ``TaskContext.platform``
    (tasks may branch on them, e.g. pick an accelerated kernel);
  * ``time_scale`` — for *simulated* targets only: a deterministic dilation
    applied to measured wall times, modeling a wimpier core complex (the
    BlueField-2 characterizations report ~3-4x slower general compute on the
    DPU Arm cores than the host).  Real hardware targets keep 1.0.

Built-ins:

  ``default``   — alias for native host execution (seed behaviour);
  ``cpu-host``  — native host execution, explicit name;
  ``dpu-sim``   — simulated DPU: same tasks, deterministic time dilation +
                  accelerator capability flags, so multi-platform sweeps and
                  speedup tables exercise the full path without hardware.

The launch layer can override/extend these via
``repro.launch.profiles.EXECUTION_PROFILES`` (lazily merged on first
lookup) so a future real-DPU profile can pin sharding defaults without the
core layer importing jax.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.core.metrics import Samples


@dataclass(frozen=True)
class Platform:
    name: str
    kind: str = "host"  # host | sim | remote
    time_scale: float = 1.0  # sim targets: dilate measured times
    flags: dict[str, Any] = field(default_factory=dict)
    # kind == "remote": flags["endpoint"] names the worker (host:port) this
    # platform's units are dispatched to (see repro.core.remote).

    def describe(self) -> dict[str, Any]:
        """The dict that lands in ``TaskContext.platform``."""
        return {"name": self.name, "kind": self.kind, **self.flags}

    def transform_samples(self, samples: Samples) -> Samples:
        """Apply the platform's measurement model to raw samples."""
        if self.time_scale == 1.0:
            return samples
        return dataclasses.replace(
            samples, times_s=[t * self.time_scale for t in samples.times_s]
        )

    def cost_scale(self) -> float:
        """Relative per-unit wall-cost heuristic for scheduling.

        :class:`repro.core.cost.CostModel` falls back to this when no
        measured wall times exist yet: simulated targets dilate cost by
        their ``time_scale`` (a dpu-sim unit costs ~3.5x a host unit), and
        any platform may pin an explicit ``cost_scale`` flag (e.g. a real
        BlueField profile calibrated once and reused).  Dimensionless —
        only ratios between platforms matter.
        """
        if "cost_scale" in self.flags:
            return float(self.flags["cost_scale"])
        if self.kind == "sim" and self.time_scale > 0:
            return self.time_scale
        return 1.0

    def endpoint(self) -> str | None:
        """Worker endpoint for ``kind == "remote"`` platforms, else None.

        A remote platform without an ``endpoint`` flag is a configuration
        error — there is nowhere to dispatch its units.  An optional
        ``capacity`` flag hints the sink's concurrency when the worker's
        ping cannot be reached (a live ping always wins).
        """
        if self.kind != "remote":
            return None
        ep = self.flags.get("endpoint")
        if not ep:
            raise ValueError(f"remote platform {self.name!r} has no 'endpoint' flag")
        return str(ep)

    def cache_identity(self) -> dict[str, Any]:
        """What makes this platform's measurements distinct (cache keying).

        Flags are included: tasks may branch on them, so measurements taken
        under different flags are different measurements.
        """
        return {
            "name": self.name,
            "kind": self.kind,
            "time_scale": self.time_scale,
            "flags": self.flags,
        }


_PLATFORMS: dict[str, Platform] = {}
_wired = False


def register_platform(platform: Platform) -> Platform:
    _PLATFORMS[platform.name] = platform
    return platform


register_platform(Platform(name="default"))
register_platform(Platform(name="cpu-host"))
register_platform(
    Platform(
        name="dpu-sim",
        kind="sim",
        time_scale=3.5,
        flags={"wimpy_cores": True, "accelerators": ["compression", "crypto"]},
    )
)


def _load_wiring() -> None:
    """Merge launch-layer execution profiles (best effort, once)."""
    global _wired
    if _wired:
        return
    _wired = True
    try:
        from repro.launch import profiles
    except Exception:  # noqa: BLE001 - launch layer (jax) may be unavailable
        return
    for name, spec in getattr(profiles, "EXECUTION_PROFILES", {}).items():
        base = _PLATFORMS.get(name, Platform(name=name))
        scalar = {k: spec[k] for k in ("kind", "time_scale") if k in spec}
        flags = {**base.flags, **spec.get("flags", {})}
        _PLATFORMS[name] = dataclasses.replace(base, flags=flags, **scalar)


def get_platform(name: str) -> Platform:
    _load_wiring()
    try:
        return _PLATFORMS[name]
    except KeyError:
        raise KeyError(
            f"unknown platform {name!r}; known: {sorted(_PLATFORMS)}"
        ) from None


def known_platforms() -> list[str]:
    _load_wiring()
    return sorted(_PLATFORMS)


def resolve(spec: "Platform | str | Mapping[str, Any] | None") -> Platform:
    """Coerce user input (name, legacy dict, Platform) into a Platform.

    Legacy dicts (``{"name": ..., **flags}``) keep working: a registered
    name resolves to its platform with the extra keys merged into flags.
    The dataclass scalars ``kind`` and ``time_scale`` are honoured as
    fields (not flags), so a box can declare e.g.
    ``{"name": "bf2", "kind": "remote", "endpoint": "10.0.0.2:7177"}``.
    """
    if spec is None:
        return get_platform("default")
    if isinstance(spec, Platform):
        return spec
    if isinstance(spec, str):
        return get_platform(spec)
    d = dict(spec)
    name = d.pop("name", "default")
    scalars = {k: d.pop(k) for k in ("kind", "time_scale") if k in d}
    _load_wiring()
    base = _PLATFORMS.get(name, Platform(name=name))
    if d:
        base = dataclasses.replace(base, flags={**base.flags, **d})
    if scalars:
        base = dataclasses.replace(base, **scalars)
    return base


def remote_platform(
    endpoint: str, base: "Platform | str" = "cpu-host", name: str | None = None
) -> Platform:
    """A remote variant of ``base``: same capability flags, units dispatched
    to the worker at ``endpoint``.  The endpoint lands in flags, hence in
    ``cache_identity()`` — a remote measurement never aliases a local one.
    """
    b = resolve(base)
    return dataclasses.replace(
        b,
        name=name or f"{b.name}@{endpoint}",
        kind="remote",
        flags={**b.flags, "endpoint": endpoint},
    )
