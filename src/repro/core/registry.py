"""Task registry + plugin loading.

Built-in tasks register via the `@register` decorator. Plugins come in two
forms (paper §3.2):

1. *Class plugins*: any module that defines `Task` subclasses and calls
   `register`; `load_builtin_tasks()` imports the built-in + plugin packages.
2. *Directory plugins* (the paper's literal mechanism): a directory holding
   `task.json` (name, param_space, metrics) and up to four scripts
   `prepare.py / run.py / report.py / clean.py`, each defining
   `main(ctx, params) -> dict | None`. `load_plugin_dir()` wraps them into a
   Task without the author touching framework code.
"""
from __future__ import annotations

import hashlib
import importlib
import json
import runpy
from pathlib import Path
from typing import Any, Iterable

from repro.core.metrics import Samples
from repro.core.task import Task, TaskContext

_REGISTRY: dict[str, Task] = {}
# Plugin directories loaded into THIS process, in load order.  Spawned
# process-pool children and remote workers start from a fresh interpreter
# that only sees importable built-ins; the executor threads this list into
# their bootstrap payload so boxes referencing plugin tasks work there too.
_PLUGIN_DIRS: list[str] = []


def register(task_cls: type[Task]) -> type[Task]:
    inst = task_cls()
    if not inst.name:
        raise ValueError(f"{task_cls.__name__} has no name")
    _REGISTRY[inst.name] = inst
    return task_cls


def get(name: str) -> Task:
    if name not in _REGISTRY:
        load_builtin_tasks()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown task {name!r}; known: {sorted(_REGISTRY)}") from None


def known_tasks() -> list[str]:
    load_builtin_tasks()
    return sorted(_REGISTRY)


_BUILTIN_MODULES = (
    "repro.tasks.compute",
    "repro.tasks.memory",
    "repro.tasks.storage",
    "repro.tasks.network",
    "repro.tasks.pushdown",
    "repro.tasks.index_offload",
    "repro.tasks.dbms",
    "repro.tasks.serving",
    "repro.tasks.plugins.pallas_accel",
    "repro.tasks.plugins.quantize",
)

_loaded = False


def load_builtin_tasks() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


class DirectoryPluginTask(Task):
    """Wraps a plugin directory's four scripts into the task abstraction."""

    def __init__(self, root: Path, spec: dict[str, Any]):
        self.root = Path(root)
        self.name = spec["name"]
        self.param_space = {k: list(v) for k, v in spec.get("param_space", {}).items()}
        self.default_metrics = tuple(spec.get("metrics", ("avg_latency_us",)))

    def _script(self, phase: str):
        p = self.root / f"{phase}.py"
        if not p.exists():
            return None
        ns = runpy.run_path(str(p))
        fn = ns.get("main")
        if fn is None:
            raise ValueError(f"plugin script {p} must define main(ctx, params)")
        return fn

    def prepare(self, ctx: TaskContext) -> None:
        fn = self._script("prepare")
        if fn:
            fn(ctx, {})

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        fn = self._script("run")
        if fn is None:
            raise ValueError(f"plugin {self.name} has no run.py")
        out = fn(ctx, params)
        if isinstance(out, Samples):
            return out
        if isinstance(out, dict):
            return Samples(
                times_s=list(out.get("times_s", [])),
                ops_per_iter=float(out.get("ops_per_iter", 0.0)),
                bytes_per_iter=float(out.get("bytes_per_iter", 0.0)),
                items_per_iter=float(out.get("items_per_iter", 0.0)),
                extra={k: float(v) for k, v in out.get("extra", {}).items()},
            )
        raise TypeError(f"plugin {self.name} run.py returned {type(out)}")

    def clean(self, ctx: TaskContext) -> None:
        fn = self._script("clean")
        if fn:
            fn(ctx, {})
        super().clean(ctx)

    def source_fingerprint(self) -> str:
        """Hash task.json + every phase script; editing any of them must
        invalidate cached results (scripts are re-read on every run)."""
        h = hashlib.sha256()
        for name in ("task.json", "prepare.py", "run.py", "report.py", "clean.py"):
            p = self.root / name
            if p.is_file():
                h.update(name.encode())
                h.update(p.read_bytes())
        return h.hexdigest()[:16]


def load_plugin_dir(root: str | Path) -> Task:
    root = Path(root)
    spec = json.loads((root / "task.json").read_text())
    task = DirectoryPluginTask(root, spec)
    _REGISTRY[task.name] = task
    canon = str(root.resolve())
    if canon not in _PLUGIN_DIRS:
        _PLUGIN_DIRS.append(canon)
    return task


def load_plugin_tree(root: str | Path) -> list[Task]:
    """Register every subdirectory of `root` containing a task.json."""
    out = []
    for p in sorted(Path(root).iterdir()):
        if (p / "task.json").exists():
            out.append(load_plugin_dir(p))
    return out


def plugin_dirs() -> list[str]:
    """Plugin directories loaded so far (for child/worker bootstrap)."""
    return list(_PLUGIN_DIRS)


def load_plugin_dirs(roots: Iterable[str]) -> None:
    """Bootstrap helper: load plugin dirs handed over by a parent.

    Already-loaded dirs are skipped (this runs per unit in process-pool
    children and per request in remote workers; scripts are re-read at run
    time regardless).  Missing paths are skipped too — a remote worker on
    another host may carry its own copies (``--plugin-dir``) instead of
    sharing the parent's filesystem; a task that stays unknown still fails
    with a clear error.
    """
    for root in roots:
        canon = str(Path(root).resolve())
        if canon not in _PLUGIN_DIRS and Path(canon).is_dir():
            load_plugin_dir(canon)


def _register_for_tests(task: Task) -> None:
    _REGISTRY[task.name] = task


def iter_tasks(names: Iterable[str]) -> list[Task]:
    return [get(n) for n in names]
