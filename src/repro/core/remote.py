"""Remote platform transport: dispatch sweep units to a worker endpoint.

This is the transport the ROADMAP's "remote executor backend" called for:
a ``kind="remote"`` :class:`~repro.core.platform.Platform` (or an
executor-wide ``remote=`` endpoint) serializes each expanded unit as a JSON
payload, ships it to a worker, and streams the measured ``Samples`` +
computed metrics back.  The worker is this same module run as::

    python -m repro.core.remote worker --host 127.0.0.1 --port 0 \
        [--capacity N] [--plugin-dir DIR ...] [--register HOST:PORT]

It binds a TCP socket (port 0 = ephemeral; the chosen endpoint is announced
as ``listening on HOST:PORT`` on stdout) and executes requests through the
exact code path the process pool uses (``executor._subprocess_run_unit``),
so local, process-pool, and remote execution are behaviourally identical.

Deployment is a config change, not a code change: a loopback subprocess
(:class:`LocalWorker`, used by tests/CI), a second host, or a BlueField DPU
reached over SSH all look like ``host:port`` once the worker runs there.
With ``--register`` the worker stops being a hand-typed endpoint entirely:
it announces itself to a :mod:`repro.runtime.membership` registry and
proves liveness with a heartbeat every :data:`HEARTBEAT_INTERVAL_S`
seconds, so runners discover the fleet (``--registry``) and a silent
worker is *suspected after ~3 missed beats* — seconds, not the request
timeout.

Failure handling is layered (fast to slow):

  1. **Heartbeats** — a crashed/partitioned worker misses beats and is
     re-dispatched around within ``SUSPECT_BEATS x HEARTBEAT_INTERVAL_S``.
  2. **Per-unit deadlines** — callers pass ``timeout=`` derived from the
     scheduler's cost evidence (:func:`unit_deadline_s`), so a *hung*
     worker (accepts, never replies — it still heartbeats) is detected in
     a small multiple of the unit's expected cost.
  3. **Connect retry with jittered backoff** — transient dial failures
     (worker restarting, SYN drop) retry :data:`CONNECT_RETRIES` times
     before the endpoint is reported unreachable.
  4. **Request ceiling** — :data:`REQUEST_TIMEOUT_S` remains the absolute
     backstop when no cost evidence exists.

Transport-level failures raise :class:`WorkerUnreachable` (a
:class:`RemoteExecutionError`) so schedulers can tell "the endpoint is
bad" (feed the health sidecar, re-dispatch) from "the task failed there"
(a worker-reported error — the endpoint itself is healthy).

Wire format: newline-delimited JSON, request/response, many requests per
connection.  Ops: ``{"op": "ping"}`` -> liveness + capacity/throughput;
``{"op": "run", "payload": {...}}`` -> ``{"ok": true, "metrics": {...}}``
or ``{"ok": false, "error": ..., "traceback": ...}``; the membership pair
``register`` / ``heartbeat`` (plus ``deregister`` / ``fleet``) served by a
registry; ``{"op": "fault", ...}`` arms test-only fault injection on
workers started with ``--allow-faults`` (see :mod:`repro.core.faults`).

**Request-id framing (multiplexing):** a request may carry an ``"id"``
field (any JSON string).  Id-tagged requests are dispatched concurrently —
each on its own handler thread, still bounded by the worker's capacity
slots — and the response frame echoes the id (``{"id": ..., "ok": ...}``),
serialized onto the connection under a per-connection write lock.
Responses therefore return in COMPLETION order, not request order, and one
connection can interleave hundreds of in-flight units; clients demux by id
(:mod:`repro.core.aiotransport` drives this from a single ``selectors``
event loop).  Requests WITHOUT an id keep the legacy contract: in-order,
one at a time per connection — :class:`RemoteTransport`, registry clients,
and pre-existing workers interoperate unchanged.  All sockets (both
accepted and dialed) set ``TCP_NODELAY``: frames are small newline-JSON
messages, and Nagle + delayed-ACK otherwise adds ~40 ms stalls per round
trip that dominate short units.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import re
import socket
import socketserver
import subprocess
import sys
import threading
import time
import traceback
from pathlib import Path
from typing import Any, Sequence

from repro.core import registry
from repro.core.cache import EWMA_ALPHA
from repro.core.metrics import Samples

CONNECT_TIMEOUT_S = 10.0
REQUEST_TIMEOUT_S = 600.0  # absolute ceiling: one unit may measure for minutes

#: Worker liveness beat period; suspicion bound = SUSPECT_BEATS x this
#: (see repro.runtime.membership).
HEARTBEAT_INTERVAL_S = 2.0
#: Dial attempts on transient connect errors before giving up.
CONNECT_RETRIES = 3
#: Base of the jittered exponential backoff between dial attempts.
CONNECT_BACKOFF_S = 0.2
#: Per-unit deadline = this multiple of the unit's expected wall cost...
UNIT_DEADLINE_FACTOR = 10.0
#: ...but never tighter than this floor (measurement noise headroom).
MIN_UNIT_DEADLINE_S = 5.0
#: Deadline for registry control-plane ops (fleet polls, beats, syncs):
#: these are tiny table lookups — anything slower is a dead/partitioned
#: replica, and waiting the full request ceiling on it would stall the
#: beat wave / poll tick that the other replicas are ready to answer.
REGISTRY_OP_TIMEOUT_S = 5.0


class RemoteExecutionError(RuntimeError):
    """A worker reported failure (or the transport could not reach one)."""


class WorkerUnreachable(RemoteExecutionError):
    """Transport-level failure: dead/hung/unreachable endpoint (not a task
    error) — evidence against the *endpoint* for health tracking."""


def parse_endpoint(endpoint: str) -> tuple[str, int]:
    """``"host:port"`` / ``"tcp://host:port"`` / ``"[v6]:port"`` -> (host, port)."""
    ep = str(endpoint).removeprefix("tcp://")
    m = re.fullmatch(r"\[([^\]]+)\]:(\d+)", ep)
    if m:
        host, port_s = m.group(1), m.group(2)
    else:
        host, _, port_s = ep.rpartition(":")
        if ":" in host:
            raise ValueError(
                f"bad endpoint {endpoint!r}: bracket IPv6 literals as [addr]:port"
            )
        if not port_s.isdigit():
            raise ValueError(f"bad endpoint {endpoint!r}; expected host:port")
    port = int(port_s)
    if not 1 <= port <= 65535:
        raise ValueError(f"bad endpoint {endpoint!r}: port must be in [1, 65535], got {port}")
    return host or "127.0.0.1", port


def routable_host(bind_host: str) -> str:
    """A connectable address for announcements/registration payloads.

    Binding to the wildcard (``0.0.0.0`` / ``::`` / ``""``) is how a worker
    serves every interface, but advertising it verbatim hands clients an
    unconnectable address.  Resolve the host's outbound interface instead
    (a connect-less UDP socket — no packet is sent), falling back to the
    hostname's address, then loopback.
    """
    if bind_host not in ("0.0.0.0", "::", ""):
        return bind_host
    try:
        probe = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        try:
            probe.connect(("10.255.255.255", 1))
            return probe.getsockname()[0]
        finally:
            probe.close()
    except OSError:
        pass
    try:
        return socket.gethostbyname(socket.gethostname())
    except OSError:
        return "127.0.0.1"


def unit_deadline_s(expected_s: float | None) -> float:
    """Layered per-unit deadline from cost evidence (seconds), bounded by
    the floor (noise headroom) and the absolute request ceiling."""
    if expected_s is None or expected_s <= 0:
        return REQUEST_TIMEOUT_S
    return min(REQUEST_TIMEOUT_S, max(MIN_UNIT_DEADLINE_S, UNIT_DEADLINE_FACTOR * expected_s))


def parse_fleet(remote: "str | Sequence[str] | None") -> list[str]:
    """``--remote`` value -> list of worker endpoints.

    A single endpoint stays a one-element fleet; a comma-separated string
    (``hostA:7177,hostB:7177``) or a sequence names several workers — the
    dynamic scheduler gives each its own pull sink, and ``@auto`` shard
    weights calibrate from their pings (fleet endpoint i is shard i's home
    worker).  Every endpoint is validated up front.
    """
    if not remote:
        return []
    if isinstance(remote, str):
        parts = [p.strip() for p in remote.split(",")]
    else:
        parts = [str(p).strip() for p in remote]
    endpoints = [p for p in parts if p]
    for ep in endpoints:
        parse_endpoint(ep)
    return endpoints


def samples_from_wire(d: dict[str, Any]) -> Samples:
    """Reconstruct the worker-measured Samples from its wire dict."""
    return Samples(
        times_s=[float(t) for t in d.get("times_s", [])],
        ops_per_iter=float(d.get("ops_per_iter", 0.0)),
        bytes_per_iter=float(d.get("bytes_per_iter", 0.0)),
        items_per_iter=float(d.get("items_per_iter", 0.0)),
        extra={k: float(v) for k, v in d.get("extra", {}).items()},
    )


# -- worker (server) ---------------------------------------------------------
class JsonLineHandler(socketserver.StreamRequestHandler):
    """Newline-JSON request/response loop shared by worker and registry.

    ``dispatch`` is wrapped: an unexpected exception serializes back as an
    error response instead of killing the connection thread silently —
    which would leave the client blocked on a reply that never comes until
    the full request timeout expired.

    Requests carrying an ``"id"`` field are *multiplexed*: each dispatches
    on its own thread and its response (id echoed back) is written under a
    per-connection write lock whenever it completes — out of order is
    expected, the id is the demux key.  Id-less requests keep the legacy
    serial in-order path.
    """

    def setup(self) -> None:
        super().setup()
        try:
            # Small newline-JSON frames: Nagle + delayed-ACK would add
            # ~40 ms per round trip, dominating short units.
            self.connection.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._wlock = threading.Lock()
        self._conn_dead = False

    def _dispatch(self, req: dict[str, Any]) -> dict[str, Any]:
        try:
            return self.server.dispatch(req)  # type: ignore[attr-defined]
        except Exception as e:  # noqa: BLE001 - serialize, keep serving
            return {
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(),
            }

    def _write_response(self, resp: Any, rid: Any = None) -> bool:
        """Serialize one response frame; False = connection is done for."""
        raw = resp.pop("_raw_bytes", None) if isinstance(resp, dict) else None
        if isinstance(resp, dict) and rid is not None:
            resp = {**resp, "id": rid}
        with self._wlock:
            if self._conn_dead:
                return False
            try:
                if raw is not None:
                    # Injected wire fault: emit the broken bytes verbatim
                    # and drop the connection (repro.core.faults "partial").
                    self.wfile.write(raw if isinstance(raw, bytes) else str(raw).encode())
                    self.wfile.flush()
                    self._conn_dead = True
                    try:
                        self.connection.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass
                    return False
                self.wfile.write((json.dumps(resp, default=str) + "\n").encode())
                self.wfile.flush()
                return True
            except (OSError, ValueError):
                # Client went away mid-write; late multiplexed responses
                # simply have nowhere to go.
                self._conn_dead = True
                return False

    def _respond_threaded(self, req: dict[str, Any], rid: Any) -> None:
        self._write_response(self._dispatch(req), rid)

    def handle(self) -> None:
        for line in self.rfile:
            line = line.strip()
            if not line:
                continue
            try:
                req = json.loads(line)
            except json.JSONDecodeError as e:
                if not self._write_response({"ok": False, "error": f"bad request JSON: {e}"}):
                    return
                continue
            rid = req.get("id") if isinstance(req, dict) else None
            if rid is not None:
                # Multiplexed request: dispatch concurrently, reply whenever
                # done.  Execution concurrency is still bounded by the
                # server's capacity slots inside dispatch().
                threading.Thread(
                    target=self._respond_threaded, args=(req, rid), daemon=True,
                    name="mux-dispatch",
                ).start()
                continue
            if not self._write_response(self._dispatch(req)):
                return
        # EOF from client: mark dead so straggler multiplexed responses
        # don't write into a torn-down connection.
        with self._wlock:
            self._conn_dead = True


class WorkerServer(socketserver.ThreadingTCPServer):
    """Executes unit payloads for remote runners.

    Concurrency model: up to ``capacity`` units execute at once (a
    multi-core DPU sets ``--capacity`` to its spare cores; the default 1
    keeps the original fully-serialized behaviour), and units of the SAME
    (platform, task) always serialize against each other — that per-key
    lock is the prepare barrier for the shared contexts
    ``_subprocess_run_unit`` keys per (platform, task).  Disjoint tasks run
    concurrently; identical tasks queue.

    Membership: construct with ``register="host:port"`` (CLI
    ``--register``) and the worker announces itself to that
    :mod:`repro.runtime.membership` registry, heartbeats every
    ``heartbeat_interval_s``, and deregisters on clean shutdown — fleet
    membership becomes dynamic instead of a hand-typed endpoint list.

    Fault injection (tests/CI soak only): with ``allow_faults=True`` the
    ``fault`` op arms kill/hang/slow/partial-write behaviour against the
    next run requests (:mod:`repro.core.faults`).  Disabled by default; a
    production worker ignores the op with an error response.
    """

    allow_reuse_address = True
    daemon_threads = True

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        plugin_dirs: Any = (),
        capacity: int = 1,
        advertise_host: str | None = None,
        register: str | None = None,
        heartbeat_interval_s: float = HEARTBEAT_INTERVAL_S,
        allow_faults: bool = False,
    ):
        super().__init__((host, port), JsonLineHandler)
        self.capacity = max(1, int(capacity))
        self.advertise_host = advertise_host
        self._slots = threading.BoundedSemaphore(self.capacity)
        self._task_locks: dict[tuple[str, str], threading.Lock] = {}
        self._locks_guard = threading.Lock()
        # Measured throughput, advertised on ping: EWMA of this worker's own
        # unit wall times (overall + per task).  Auto-weight calibration
        # (``--shard i/n@auto``) sizes shards from capacity / ewma_s.
        self._stats_lock = threading.Lock()
        self._units_done = 0
        self._ewma_s: float | None = None
        self._task_ewma_s: dict[str, float] = {}
        # Armed faults: list of {"mode", "seconds", "units"} consumed by run
        # requests in FIFO order (guarded by _stats_lock's sibling below).
        self.allow_faults = bool(allow_faults)
        self._fault_lock = threading.Lock()
        self._faults: list[dict[str, Any]] = []
        # Membership: registration target + the heartbeat thread handle.
        self.register_endpoint = register
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self._hb_stop = threading.Event()
        self._hb_thread: threading.Thread | None = None
        registry.load_plugin_dirs(str(d) for d in plugin_dirs)

    @property
    def endpoint(self) -> str:
        """The *advertised* endpoint: always connectable, never a wildcard.

        ``--host 0.0.0.0`` binds every interface but would announce (and
        register) an unconnectable ``0.0.0.0:PORT``; resolve a routable
        address instead.  ``advertise_host`` overrides for NAT/multi-homed
        hosts.
        """
        host, port = self.server_address[:2]
        adv = self.advertise_host or routable_host(str(host))
        return f"{adv}:{port}"

    def _task_lock(self, payload: dict[str, Any]) -> threading.Lock:
        platform = payload.get("platform") or {}
        key = (str(platform.get("name", "?")), str(payload.get("task", "?")))
        with self._locks_guard:
            return self._task_locks.setdefault(key, threading.Lock())

    def _observe(self, task: str, elapsed_s: Any) -> None:
        """Fold one finished unit's wall time into the advertised EWMAs."""
        try:
            x = float(elapsed_s)
        except (TypeError, ValueError):
            return
        if x <= 0:
            return
        with self._stats_lock:
            self._units_done += 1
            self._ewma_s = (
                x if self._ewma_s is None
                else EWMA_ALPHA * x + (1.0 - EWMA_ALPHA) * self._ewma_s
            )
            prev = self._task_ewma_s.get(task)
            self._task_ewma_s[task] = (
                x if prev is None else EWMA_ALPHA * x + (1.0 - EWMA_ALPHA) * prev
            )

    def throughput(self) -> dict[str, Any]:
        """The measured-throughput payload advertised on ping."""
        with self._stats_lock:
            return {
                "units": self._units_done,
                "ewma_s": self._ewma_s,
                "per_task": dict(self._task_ewma_s),
            }

    # -- membership ----------------------------------------------------------
    def start_heartbeat(self) -> threading.Thread | None:
        """Register with every configured registry replica and beat until
        shutdown.

        ``register`` may name several replicas (``a:7170,b:7170,c:7170``);
        each beat wave fans out to ALL of them through the async mux client,
        so one dead replica burns its own deadline on the loop thread without
        delaying the beats the live replicas are owed.  Per replica, a failed
        beat drops back to the register op with jittered exponential backoff
        — capped well inside the suspect window, so a replica that restarts
        empty re-admits this worker before its time-based warmup gate opens
        and a poller could see a stale view.  The daemon thread itself never
        dies to a transport error: a full registry outage just means every
        replica sits in backoff until one answers again.
        """
        if not self.register_endpoint or self._hb_thread is not None:
            return self._hb_thread
        replicas = parse_fleet(self.register_endpoint)

        def loop() -> None:
            # Import here, not at module top: aiotransport imports remote.
            from repro.core.aiotransport import get_async_transport

            aio = get_async_transport()
            interval = self.heartbeat_interval_s
            # A beat must settle (or fail) well before the suspect bound;
            # backoff after failures never exceeds (SUSPECT_BEATS-1) beats =
            # 2 intervals + jitter, so recovery beats land inside a restarted
            # replica's warmup window (suspect_beats x interval).
            beat_timeout = max(2.0, 2.0 * interval)
            backoff_cap = 2.0 * interval
            lock = threading.Lock()
            state = {
                ep: {"registered": False, "failures": 0, "next_at": 0.0, "inflight": False}
                for ep in replicas
            }

            def settle(ep: str, resp: dict[str, Any] | None, exc: Exception | None) -> None:
                ok = exc is None and isinstance(resp, dict) and bool(resp.get("ok"))
                with lock:
                    st = state[ep]
                    st["inflight"] = False
                    if ok:
                        st["registered"] = True
                        st["failures"] = 0
                        st["next_at"] = 0.0
                    else:
                        st["registered"] = False  # re-register once it answers
                        st["failures"] = int(st["failures"]) + 1
                        backoff = min(
                            backoff_cap,
                            interval * (2.0 ** min(int(st["failures"]) - 1, 3)),
                        )
                        st["next_at"] = (
                            time.monotonic() + backoff + random.uniform(0.0, interval / 2.0)
                        )

            while not self._hb_stop.is_set():
                try:
                    now = time.monotonic()
                    for ep in replicas:
                        with lock:
                            st = state[ep]
                            if st["inflight"] or now < float(st["next_at"]):
                                continue
                            st["inflight"] = True
                            if not st["registered"]:
                                req: dict[str, Any] = {
                                    "op": "register",
                                    "endpoint": self.endpoint,
                                    "capacity": self.capacity,
                                    "meta": {"pid": os.getpid()},
                                }
                            else:
                                # Beats carry capacity AND measured throughput,
                                # so runners size sinks / auto-weights straight
                                # from the registry view — zero startup pings
                                # per member.
                                req = {
                                    "op": "heartbeat",
                                    "endpoint": self.endpoint,
                                    "capacity": self.capacity,
                                    "throughput": self.throughput(),
                                }
                        try:
                            aio.submit(
                                ep, req, timeout=beat_timeout,
                                callback=lambda r, e, _ep=ep: settle(_ep, r, e),
                            )
                        except Exception as exc:
                            settle(ep, None, exc)
                except Exception:
                    pass  # the beat daemon must outlive any one bad wave
                self._hb_stop.wait(self.heartbeat_interval_s)

        self._hb_thread = threading.Thread(target=loop, daemon=True, name="worker-heartbeat")
        self._hb_thread.start()
        return self._hb_thread

    def stop_heartbeat(self, deregister_worker: bool = True) -> None:
        self._hb_stop.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=2.0)
            self._hb_thread = None
        if deregister_worker and self.register_endpoint:
            for ep in parse_fleet(self.register_endpoint):
                try:
                    deregister(ep, self.endpoint)
                except RemoteExecutionError:
                    pass  # replica gone; its failure detector reaps us anyway

    def server_close(self) -> None:  # type: ignore[override]
        self.stop_heartbeat()
        super().server_close()

    # -- fault injection (tests/CI soak) --------------------------------------
    def _arm_fault(self, req: dict[str, Any]) -> dict[str, Any]:
        from repro.core.faults import FAULT_MODES

        if not self.allow_faults:
            return {"ok": False, "error": "fault injection disabled (start with --allow-faults)"}
        mode = str(req.get("mode", ""))
        if mode not in FAULT_MODES:
            return {"ok": False, "error": f"unknown fault mode {mode!r}; known: {FAULT_MODES}"}
        spec = {
            "mode": mode,
            "seconds": float(req.get("seconds", 0.5) or 0.0),
            "units": max(1, int(req.get("units", 1) or 1)),
        }
        with self._fault_lock:
            self._faults.append(spec)
        return {"ok": True, "op": "fault", "armed": spec}

    def _take_fault(self) -> dict[str, Any] | None:
        with self._fault_lock:
            if not self._faults:
                return None
            spec = self._faults[0]
            spec["units"] -= 1
            if spec["units"] <= 0:
                self._faults.pop(0)
            return spec

    def dispatch(self, req: dict[str, Any]) -> dict[str, Any]:
        from repro.core import executor as executor_mod

        op = req.get("op")
        if op == "ping":
            return {
                "ok": True, "op": "ping", "pid": os.getpid(),
                "capacity": self.capacity, "throughput": self.throughput(),
                "endpoint": self.endpoint,
            }
        if op == "fault":
            return self._arm_fault(req)
        if op == "run":
            fault = self._take_fault()
            if fault is not None:
                mode = fault["mode"]
                if mode == "kill":
                    # Simulated crash mid-unit: no response, no cleanup — the
                    # client sees the connection die, the registry sees beats
                    # stop.  (Only reachable with --allow-faults.)
                    os._exit(23)
                if mode == "hang":
                    # Accepts but never replies: the pathological wedged
                    # worker.  Heartbeats (separate thread) keep flowing, so
                    # only per-unit deadlines / straggler re-dispatch catch it.
                    time.sleep(fault["seconds"] or REQUEST_TIMEOUT_S)
                    return {"ok": False, "error": "fault: hang elapsed"}
                if mode == "partial":
                    # Truncated garbage on the wire, then connection drop.
                    return {"_raw_bytes": b'{"ok": true, "metrics": {"trunc'}
                if mode == "slow":
                    time.sleep(fault["seconds"])
            # Payload plugin dirs load inside _subprocess_run_unit's try, so
            # a broken plugin serializes back as an error response instead of
            # killing the connection.
            payload = req.get("payload") or {}
            # Task lock OUTSIDE the capacity slot: same-task waiters queue
            # on their lock without occupying a slot, so disjoint tasks
            # really do run concurrently up to capacity.  No deadlock: a
            # slot holder is always executing, never waiting on a lock.
            with self._task_lock(payload), self._slots:
                resp = executor_mod._subprocess_run_unit(payload)
            if resp.get("ok"):
                self._observe(str(payload.get("task", "?")), resp.get("elapsed_s"))
            return resp
        return {"ok": False, "error": f"unknown op {op!r}"}

    def serve_in_thread(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        self.start_heartbeat()
        return t


# -- transport (client) ------------------------------------------------------
class _Conn:
    """One TCP connection to a worker (socket + buffered reader)."""

    def __init__(self, host: str, port: int):
        self.sock = socket.create_connection((host, port), timeout=CONNECT_TIMEOUT_S)
        self.sock.settimeout(REQUEST_TIMEOUT_S)
        try:
            # Request frames are tiny; without this, Nagle + delayed-ACK
            # stalls every short unit's round trip by ~40 ms.
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self.rfile = self.sock.makefile("rb")

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class RemoteTransport:
    """Client for one worker endpoint.  Thread-safe connection pool.

    Concurrent callers (the executor's thread pool) each check out their
    own connection — the worker serves one request thread per connection,
    so a ``--capacity N`` worker really executes N units at once.  Idle
    connections are pooled and reused; a dead pooled connection (worker
    restarted between sweeps) retries once on a fresh one.

    Deadlines: every request takes an optional ``timeout`` (seconds) that
    bounds the wait for the response — the per-unit deadline layer.  A
    timed-out request raises :class:`WorkerUnreachable` immediately (no
    blind re-send: the worker may still be executing the unit), while
    transient *connect* errors retry with jittered exponential backoff.
    """

    def __init__(self, endpoint: str):
        self.endpoint = endpoint
        self.host, self.port = parse_endpoint(endpoint)
        self._lock = threading.Lock()
        self._idle: list[_Conn] = []
        self._closed = False
        # In-flight requests are bounded by the worker's advertised capacity
        # (learned from ping on first use): excess callers queue CLIENT-side,
        # so worker-side queue wait never ticks against the socket timeout
        # and a unit is never re-sent while the worker still executes it.
        self._gate_lock = threading.Lock()
        self._gate: threading.BoundedSemaphore | None = None

    def _dial(self, retries: int = CONNECT_RETRIES) -> _Conn:
        """Dial with jittered exponential backoff on transient errors."""
        last: OSError | None = None
        for attempt in range(max(1, retries)):
            try:
                return _Conn(self.host, self.port)
            except OSError as e:
                last = e
                if attempt + 1 >= max(1, retries):
                    break
                time.sleep(
                    CONNECT_BACKOFF_S * (2**attempt)
                    + random.uniform(0.0, CONNECT_BACKOFF_S)
                )
        raise WorkerUnreachable(f"worker {self.endpoint} unreachable: {last}") from last

    def _checkout(self, fresh: bool = False, retries: int = CONNECT_RETRIES) -> _Conn:
        """Pop an idle connection, or dial.  ``fresh`` always dials — the
        retry path must not pick up ANOTHER stale pooled connection after a
        worker restart invalidated the whole pool."""
        if not fresh:
            with self._lock:
                if self._idle:
                    return self._idle.pop()
        return self._dial(retries=retries)

    def _checkin(self, conn: _Conn) -> None:
        with self._lock:
            if not self._closed:
                self._idle.append(conn)
                return
        conn.close()

    def close(self) -> None:
        with self._lock:
            idle, self._idle = self._idle, []
            self._closed = True
        for conn in idle:
            conn.close()

    def _probe_capacity(self) -> int | None:
        """Ping on a dedicated connection; None when unreachable."""
        try:
            conn = _Conn(self.host, self.port)
        except OSError:
            return None
        try:
            conn.sock.sendall(b'{"op": "ping"}\n')
            line = conn.rfile.readline()
            if not line:
                return None
            cap = int(json.loads(line).get("capacity", 1) or 1)
            self._checkin(conn)
            conn = None
            return max(1, cap)
        except (OSError, json.JSONDecodeError, TypeError, ValueError):
            return None
        finally:
            if conn is not None:
                conn.close()

    def _capacity_gate(self) -> "threading.BoundedSemaphore":
        with self._gate_lock:
            if self._gate is not None:
                return self._gate
        cap = self._probe_capacity()
        with self._gate_lock:
            # Only cache a gate learned from a live worker: probing a not-
            # yet-started worker (wait_ready) must not pin capacity to 1.
            if self._gate is None and cap is not None:
                self._gate = threading.BoundedSemaphore(cap)
            return self._gate or threading.BoundedSemaphore(1)

    def request(
        self,
        obj: dict[str, Any],
        timeout: float | None = None,
        connect_retries: int = CONNECT_RETRIES,
    ) -> dict[str, Any]:
        data = (json.dumps(obj, default=str) + "\n").encode()
        deadline = REQUEST_TIMEOUT_S if timeout is None else float(timeout)
        with self._capacity_gate():
            # One retry: a stale pooled connection (worker restart between
            # sweeps) fails on first use; the retry always dials fresh.
            for attempt in (0, 1):
                conn = None
                try:
                    conn = self._checkout(fresh=attempt > 0, retries=connect_retries)
                    conn.sock.settimeout(deadline)
                    conn.sock.sendall(data)
                    line = conn.rfile.readline()
                    if not line:
                        raise ConnectionError("worker closed connection")
                    resp = json.loads(line)
                    conn.sock.settimeout(REQUEST_TIMEOUT_S)
                    self._checkin(conn)
                    return resp
                except (OSError, json.JSONDecodeError) as e:
                    if conn is not None:
                        conn.close()
                    # A deadline expiry is FINAL for this request: the
                    # worker may still be grinding (or hung) on the unit;
                    # re-sending would double-execute it and double the
                    # detection latency.  The caller re-dispatches instead.
                    if isinstance(e, socket.timeout) or attempt:
                        raise WorkerUnreachable(
                            f"worker {self.endpoint} unreachable: {e}"
                        ) from e
        raise AssertionError("unreachable")

    def ping(self) -> bool:
        try:
            return bool(self.request({"op": "ping"}).get("ok"))
        except RemoteExecutionError:
            return False

    def info(self) -> dict[str, Any] | None:
        """Full ping payload (capacity, measured throughput) from a live
        worker; ``None`` when the worker is unreachable or answered with an
        error payload."""
        try:
            resp = self.request({"op": "ping"})
        except RemoteExecutionError:
            return None
        return resp if resp.get("ok") else None

    def run_unit(
        self, payload: dict[str, Any], timeout: float | None = None
    ) -> dict[str, Any]:
        resp = self.request({"op": "run", "payload": payload}, timeout=timeout)
        if not resp.get("ok"):
            raise RemoteExecutionError(
                f"worker {self.endpoint} failed: {resp.get('error', 'unknown error')}"
            )
        return resp


_TRANSPORTS: dict[str, RemoteTransport] = {}
_transports_lock = threading.Lock()


def get_transport(endpoint: str) -> RemoteTransport:
    """Process-wide transport pool: one client per endpoint."""
    with _transports_lock:
        t = _TRANSPORTS.get(endpoint)
        if t is None:
            t = _TRANSPORTS[endpoint] = RemoteTransport(endpoint)
        return t


# -- membership client ops (register/heartbeat pair + fleet discovery) -------
def register(
    registry_endpoint: str,
    worker_endpoint: str,
    capacity: int = 1,
    meta: dict[str, Any] | None = None,
    timeout: float = 10.0,
) -> dict[str, Any]:
    """Announce a worker to a membership registry; returns the registry ack
    (which carries the expected ``heartbeat_interval_s``)."""
    resp = get_transport(registry_endpoint).request(
        {
            "op": "register",
            "endpoint": worker_endpoint,
            "capacity": int(capacity),
            "meta": dict(meta or {}),
        },
        timeout=timeout,
        connect_retries=1,
    )
    if not resp.get("ok"):
        raise RemoteExecutionError(
            f"registry {registry_endpoint} rejected register: {resp.get('error')}"
        )
    return resp


def heartbeat(
    registry_endpoint: str,
    worker_endpoint: str,
    capacity: int | None = None,
    throughput: dict[str, Any] | None = None,
    timeout: float = 10.0,
) -> dict[str, Any]:
    """One liveness beat.  Unknown endpoints are re-admitted (registry
    restarts heal on the next beat wave).  ``capacity``/``throughput`` ride
    along so the registry's fleet view advertises what a ping would —
    discovery then needs zero startup round trips per member."""
    req: dict[str, Any] = {"op": "heartbeat", "endpoint": worker_endpoint}
    if capacity is not None:
        req["capacity"] = int(capacity)
    if throughput is not None:
        req["throughput"] = dict(throughput)
    resp = get_transport(registry_endpoint).request(req, timeout=timeout, connect_retries=1)
    if not resp.get("ok"):
        raise RemoteExecutionError(
            f"registry {registry_endpoint} rejected heartbeat: {resp.get('error')}"
        )
    return resp


def deregister(
    registry_endpoint: str, worker_endpoint: str, timeout: float = 10.0
) -> dict[str, Any]:
    """Graceful leave (clean shutdown beats waiting out the failure detector)."""
    return get_transport(registry_endpoint).request(
        {"op": "deregister", "endpoint": worker_endpoint},
        timeout=timeout,
        connect_retries=1,
    )


def fleet_members(registry_endpoint: str, timeout: float = 10.0) -> list[dict[str, Any]]:
    """The registry's current fleet view (alive + suspect, dead pruned)."""
    resp = get_transport(registry_endpoint).request(
        {"op": "fleet"}, timeout=timeout, connect_retries=1
    )
    if not resp.get("ok"):
        raise RemoteExecutionError(
            f"registry {registry_endpoint} rejected fleet query: {resp.get('error')}"
        )
    return list(resp.get("workers", []))


def _fresher_row(a: dict[str, Any], b: dict[str, Any]) -> dict[str, Any]:
    """Last-beat-wins between two replicas' rows for the SAME worker: the
    smaller ``age_s`` (most recently heard beat) is authoritative; on an
    exact tie the larger beat count breaks it (a replica that missed beats
    mid-partition reports the same age after re-admission but fewer beats)."""
    try:
        age_a, age_b = float(a.get("age_s", 0.0)), float(b.get("age_s", 0.0))
    except (TypeError, ValueError):
        return a
    if age_a != age_b:
        return a if age_a < age_b else b
    return a if int(a.get("beats", 0) or 0) >= int(b.get("beats", 0) or 0) else b


def merge_member_rows(views: Sequence[Sequence[dict[str, Any]]]) -> list[dict[str, Any]]:
    """Merge several replicas' fleet views into one quorum view.

    Per worker endpoint the freshest row wins (:func:`_fresher_row`), so a
    replica that was partitioned and still carries stale ``suspect`` rows
    cannot override a peer that heard the worker beat this interval.  Output
    is sorted by endpoint — byte-stable regardless of which replicas
    answered or in what order."""
    merged: dict[str, dict[str, Any]] = {}
    for view in views:
        for row in view:
            ep = str(row.get("endpoint", ""))
            if not ep:
                continue
            cur = merged.get(ep)
            merged[ep] = row if cur is None else _fresher_row(cur, row)
    return [merged[ep] for ep in sorted(merged)]


def fleet_view(
    registry_endpoints: "str | Sequence[str]",
    timeout: float = REGISTRY_OP_TIMEOUT_S,
) -> tuple[list[dict[str, Any]], list[str]]:
    """Query EVERY registry replica in one concurrent wave and merge.

    Returns ``(merged_members, answered_replicas)``.  Failover is free: the
    wave rides the async mux client, so losing replica 1 costs nothing —
    replica 2's answer was already in flight in the same tick.  A replica
    that answers with an error payload (e.g. restarted and still warming up)
    counts as unanswered; zero answered replicas yields ``([], [])`` and the
    CALLER decides whether a dark control plane means "empty fleet" or
    "keep the last view" (the watcher keeps it — no flapping)."""
    replicas = parse_fleet(registry_endpoints)
    if not replicas:
        return [], []
    from repro.core.aiotransport import get_async_transport

    results = get_async_transport().request_many(
        [(ep, {"op": "fleet"}) for ep in replicas], timeout=timeout
    )
    views: list[list[dict[str, Any]]] = []
    answered: list[str] = []
    for ep, (resp, _exc) in zip(replicas, results):
        if isinstance(resp, dict) and resp.get("ok"):
            views.append(list(resp.get("workers", [])))
            answered.append(ep)
    return merge_member_rows(views), answered


def wait_members(
    registry_endpoint: "str | Sequence[str]",
    count: int = 1,
    timeout: float = 30.0,
    required: bool = False,
) -> list[dict[str, Any]]:
    """Poll the registry replicas until >= ``count`` workers are alive.

    On timeout the default returns whatever the final merged view holds
    (possibly short); ``required=True`` instead raises with the partial
    view spelled out — who IS alive, who is registered-but-not-alive and in
    what state, and which replicas answered — so a fleet cold-start failure
    is diagnosable from the message alone."""
    replicas = parse_fleet(registry_endpoint)
    deadline = time.monotonic() + timeout
    members: list[dict[str, Any]] = []
    answered: list[str] = []
    while True:
        members, answered = fleet_view(replicas)
        alive = [m for m in members if m.get("status") == "alive"]
        if len(alive) >= count:
            return alive
        if time.monotonic() >= deadline:
            break
        time.sleep(0.1)
    if not required:
        return [m for m in members if m.get("status") == "alive"]
    alive = [m for m in members if m.get("status") == "alive"]
    others = [m for m in members if m.get("status") != "alive"]
    silent = [ep for ep in replicas if ep not in answered]
    parts = [
        f"needed {count} alive worker(s), saw {len(alive)} after {timeout:g}s",
        "alive: " + (", ".join(str(m.get("endpoint")) for m in alive) or "none"),
    ]
    if others:
        parts.append(
            "registered but not alive: "
            + ", ".join(f"{m.get('endpoint')} ({m.get('status')})" for m in others)
        )
    parts.append(f"replicas answered: {len(answered)}/{len(replicas)}")
    if silent:
        parts.append("silent replicas: " + ", ".join(silent))
    raise RemoteExecutionError("; ".join(parts))


def wait_any_ready(
    registry_endpoints: "str | Sequence[str]", timeout: float = 30.0
) -> str | None:
    """Poll the replica list until ANY replica answers ping ok; returns that
    replica's endpoint, or ``None`` if the whole plane stayed dark."""
    replicas = parse_fleet(registry_endpoints)
    if not replicas:
        return None
    deadline = time.monotonic() + timeout
    while True:
        for ep in replicas:
            try:
                resp = get_transport(ep).request(
                    {"op": "ping"}, timeout=REGISTRY_OP_TIMEOUT_S, connect_retries=1
                )
            except RemoteExecutionError:
                continue
            if resp.get("ok"):
                return ep
        if time.monotonic() >= deadline:
            return None
        time.sleep(0.1)


def wait_ready(endpoint: str, timeout: float = 30.0) -> bool:
    """Poll until the worker answers ping (workers announce asynchronously).

    Only *unreachable* states keep polling (connection refused / reset /
    timed out — the worker just hasn't bound yet).  A worker that ANSWERS
    ping with an error payload is alive but broken (bad plugin, protocol
    mismatch); waiting the full timeout on it would only mask the real
    failure, so that raises :class:`RemoteExecutionError` immediately with
    the worker's own payload in the message.
    """
    deadline = time.monotonic() + timeout
    transport = get_transport(endpoint)
    while True:
        try:
            resp = transport.request({"op": "ping"}, connect_retries=1)
        except RemoteExecutionError:
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.1)
            continue
        if resp.get("ok"):
            return True
        raise RemoteExecutionError(
            f"worker {endpoint} answered ping with an error payload: "
            f"{resp.get('error', resp)!r}"
        )


# -- loopback worker subprocess ----------------------------------------------
class LocalWorker:
    """Context manager: spawn ``repro.core.remote worker`` on loopback.

    The zero-config path for tests/CI and the template for real deployment —
    point the spawn command at ``ssh <dpu> python -m repro.core.remote
    worker`` and nothing else changes.  ``register=`` makes the spawned
    worker join a membership registry (elastic fleets); ``allow_faults=``
    arms the fault-injection surface for soak tests.
    """

    def __init__(
        self,
        plugin_dirs: Any = (),
        startup_timeout: float = 60.0,
        capacity: int = 1,
        register: str | None = None,
        heartbeat_interval_s: float | None = None,
        allow_faults: bool = False,
    ):
        self.plugin_dirs = [str(d) for d in plugin_dirs]
        self.startup_timeout = startup_timeout
        self.capacity = max(1, int(capacity))
        self.register = register
        self.heartbeat_interval_s = heartbeat_interval_s
        self.allow_faults = bool(allow_faults)
        self.endpoint: str | None = None
        self._proc: subprocess.Popen | None = None
        self._announced = threading.Event()

    def _pump_stdout(self, q) -> None:
        # Runs for the worker's lifetime: keeps draining the pipe after the
        # announce so a chatty worker can never block on a full pipe buffer.
        for line in self._proc.stdout:
            if not self._announced.is_set():
                q.put(line)
        q.put(None)

    @property
    def alive(self) -> bool:
        """Whether the worker process is still running (soak respawn check)."""
        return self._proc is not None and self._proc.poll() is None

    def __enter__(self) -> "LocalWorker":
        import queue

        cmd = [
            sys.executable, "-m", "repro.core.remote", "worker",
            "--port", "0", "--capacity", str(self.capacity),
        ]
        if self.register:
            cmd += ["--register", self.register]
        if self.heartbeat_interval_s is not None:
            cmd += ["--heartbeat-interval", str(self.heartbeat_interval_s)]
        if self.allow_faults:
            cmd += ["--allow-faults"]
        for d in self.plugin_dirs:
            cmd += ["--plugin-dir", d]
        env = dict(os.environ)
        # The child must import repro even when the parent runs from a
        # source tree without `pip install -e .`.
        src_root = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src_root, env.get("PYTHONPATH")) if p
        )
        self._proc = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env
        )
        # Read announce lines through a thread so the startup timeout holds
        # even when the worker hangs without printing or exiting.
        q: "queue.Queue[str | None]" = queue.Queue()
        threading.Thread(target=self._pump_stdout, args=(q,), daemon=True).start()
        deadline = time.monotonic() + self.startup_timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._proc.kill()
                raise TimeoutError("worker did not announce its endpoint in time")
            try:
                line = q.get(timeout=min(remaining, 0.5))
            except queue.Empty:
                continue
            if line is None:
                raise RuntimeError(f"worker died on startup (rc={self._proc.wait()})")
            if line.startswith("listening on "):
                self.endpoint = line.split("listening on ", 1)[1].strip()
                self._announced.set()
                return self

    def __exit__(self, *exc) -> None:
        if self.endpoint:
            with _transports_lock:
                t = _TRANSPORTS.pop(self.endpoint, None)
            if t is not None:
                t.close()
            # The async transport (if this process ever started it) holds a
            # persistent connection to the worker; drop its state so the
            # endpoint's port can be reused by a fresh worker cleanly.
            aio = sys.modules.get("repro.core.aiotransport")
            if aio is not None:
                aio.get_async_transport().drop(self.endpoint)
        if self._proc is not None:
            self._proc.terminate()
            try:
                self._proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self._proc.kill()


# -- CLI ---------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.core.remote", description="dpBento remote sweep worker"
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    w = sub.add_parser("worker", help="serve unit payloads over TCP")
    w.add_argument("--host", default="127.0.0.1")
    w.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    w.add_argument(
        "--capacity", type=int, default=1,
        help="units executed concurrently (same-task units still serialize; "
        "set to the host's spare cores on a multi-core DPU)",
    )
    w.add_argument(
        "--advertise-host", default=None, metavar="HOST",
        help="address to announce/register instead of the auto-resolved one "
        "(NAT or multi-homed hosts)",
    )
    w.add_argument(
        "--register", default=None, metavar="HOST:PORT[,HOST:PORT...]",
        help="membership registry replica(s) to join (repro.runtime."
        "membership); the worker registers with, heartbeats to, and "
        "deregisters from EVERY replica — one replica outage never blocks "
        "the beat wave",
    )
    w.add_argument(
        "--heartbeat-interval", type=float, default=HEARTBEAT_INTERVAL_S,
        metavar="SECONDS", help="liveness beat period when registered",
    )
    w.add_argument(
        "--allow-faults", action="store_true",
        help="honor 'fault' ops (kill/hang/slow/partial) — tests/CI soak only",
    )
    w.add_argument(
        "--plugin-dir", action="append", default=[], metavar="DIR",
        help="plugin task directory to preload (repeatable)",
    )
    fl = sub.add_parser(
        "fleet",
        help="serve N workers from ONE process (loopback transport-scale "
        "tests: contexts are shared per (platform, task), and a 'kill' "
        "fault would take the whole fleet down)",
    )
    fl.add_argument("--count", type=int, default=4, metavar="N")
    fl.add_argument("--host", default="127.0.0.1")
    fl.add_argument("--capacity", type=int, default=1)
    fl.add_argument("--register", default=None, metavar="HOST:PORT[,HOST:PORT...]")
    fl.add_argument(
        "--heartbeat-interval", type=float, default=HEARTBEAT_INTERVAL_S, metavar="SECONDS"
    )
    fl.add_argument("--allow-faults", action="store_true")
    fl.add_argument("--plugin-dir", action="append", default=[], metavar="DIR")
    pg = sub.add_parser("ping", help="check a worker endpoint")
    pg.add_argument("endpoint")
    pg.add_argument("--timeout", type=float, default=10.0)
    args = p.parse_args(argv)

    if args.cmd == "worker":
        server = WorkerServer(
            args.host, args.port,
            plugin_dirs=args.plugin_dir,
            capacity=args.capacity,
            advertise_host=args.advertise_host,
            register=args.register,
            heartbeat_interval_s=args.heartbeat_interval,
            allow_faults=args.allow_faults,
        )
        print(f"listening on {server.endpoint}", flush=True)
        server.start_heartbeat()
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.cmd == "fleet":
        if args.count < 1:
            p.error(f"--count must be >= 1, got {args.count}")
        servers = [
            WorkerServer(
                args.host, 0,
                plugin_dirs=args.plugin_dir,
                capacity=args.capacity,
                register=args.register,
                heartbeat_interval_s=args.heartbeat_interval,
                allow_faults=args.allow_faults,
            )
            for _ in range(args.count)
        ]
        for server in servers:
            server.serve_in_thread()
        # One comma-joined announce line: parse_fleet-compatible, and a
        # spawner only has to wait for a single line however large N is.
        print("listening on " + ",".join(s.endpoint for s in servers), flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            for server in servers:
                server.shutdown()
                server.server_close()
        return 0
    if args.cmd == "ping":
        try:
            ok = wait_ready(args.endpoint, timeout=args.timeout)
        except RemoteExecutionError as e:
            print(f"error: {e}")
            return 1
        print("ok" if ok else "unreachable")
        return 0 if ok else 1
    return 2


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = [
    "RemoteExecutionError",
    "WorkerUnreachable",
    "RemoteTransport",
    "WorkerServer",
    "JsonLineHandler",
    "LocalWorker",
    "get_transport",
    "wait_ready",
    "wait_members",
    "wait_any_ready",
    "fleet_members",
    "fleet_view",
    "merge_member_rows",
    "register",
    "heartbeat",
    "deregister",
    "parse_endpoint",
    "parse_fleet",
    "routable_host",
    "unit_deadline_s",
    "samples_from_wire",
    "HEARTBEAT_INTERVAL_S",
    "REQUEST_TIMEOUT_S",
    "REGISTRY_OP_TIMEOUT_S",
]
