"""Remote platform transport: dispatch sweep units to a worker endpoint.

This is the transport the ROADMAP's "remote executor backend" called for:
a ``kind="remote"`` :class:`~repro.core.platform.Platform` (or an
executor-wide ``remote=`` endpoint) serializes each expanded unit as a JSON
payload, ships it to a worker, and streams the measured ``Samples`` +
computed metrics back.  The worker is this same module run as::

    python -m repro.core.remote worker --host 127.0.0.1 --port 0 \
        [--capacity N] [--plugin-dir DIR ...]

It binds a TCP socket (port 0 = ephemeral; the chosen endpoint is announced
as ``listening on HOST:PORT`` on stdout) and executes requests through the
exact code path the process pool uses (``executor._subprocess_run_unit``),
so local, process-pool, and remote execution are behaviourally identical.

Deployment is a config change, not a code change: a loopback subprocess
(:class:`LocalWorker`, used by tests/CI), a second host, or a BlueField DPU
reached over SSH all look like ``host:port`` once the worker runs there,
e.g. ``ssh bf2 python -m repro.core.remote worker --port 7177`` plus an SSH
tunnel, or the worker listening on the DPU's management interface.

Wire format: newline-delimited JSON, request/response, many requests per
connection.  Ops: ``{"op": "ping"}`` -> liveness + known tasks;
``{"op": "run", "payload": {...}}`` -> ``{"ok": true, "metrics": {...},
"samples": {...}}`` or ``{"ok": false, "error": ..., "traceback": ...}``.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import socketserver
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Any, Sequence

from repro.core import registry
from repro.core.cache import EWMA_ALPHA
from repro.core.metrics import Samples

CONNECT_TIMEOUT_S = 10.0
REQUEST_TIMEOUT_S = 600.0  # one unit may legitimately measure for minutes


class RemoteExecutionError(RuntimeError):
    """A worker reported failure (or the transport could not reach one)."""


def parse_endpoint(endpoint: str) -> tuple[str, int]:
    """``"host:port"`` / ``"tcp://host:port"`` -> (host, port)."""
    ep = endpoint.removeprefix("tcp://")
    host, _, port = ep.rpartition(":")
    if not port.isdigit():
        raise ValueError(f"bad endpoint {endpoint!r}; expected host:port")
    return host or "127.0.0.1", int(port)


def parse_fleet(remote: "str | Sequence[str] | None") -> list[str]:
    """``--remote`` value -> list of worker endpoints.

    A single endpoint stays a one-element fleet; a comma-separated string
    (``hostA:7177,hostB:7177``) or a sequence names several workers — the
    dynamic scheduler gives each its own pull sink, and ``@auto`` shard
    weights calibrate from their pings (fleet endpoint i is shard i's home
    worker).  Every endpoint is validated up front.
    """
    if not remote:
        return []
    if isinstance(remote, str):
        parts = [p.strip() for p in remote.split(",")]
    else:
        parts = [str(p).strip() for p in remote]
    endpoints = [p for p in parts if p]
    for ep in endpoints:
        parse_endpoint(ep)
    return endpoints


def samples_from_wire(d: dict[str, Any]) -> Samples:
    """Reconstruct the worker-measured Samples from its wire dict."""
    return Samples(
        times_s=[float(t) for t in d.get("times_s", [])],
        ops_per_iter=float(d.get("ops_per_iter", 0.0)),
        bytes_per_iter=float(d.get("bytes_per_iter", 0.0)),
        items_per_iter=float(d.get("items_per_iter", 0.0)),
        extra={k: float(v) for k, v in d.get("extra", {}).items()},
    )


# -- worker (server) ---------------------------------------------------------
class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        for line in self.rfile:
            line = line.strip()
            if not line:
                continue
            try:
                req = json.loads(line)
            except json.JSONDecodeError as e:
                resp = {"ok": False, "error": f"bad request JSON: {e}"}
            else:
                resp = self.server.dispatch(req)  # type: ignore[attr-defined]
            self.wfile.write((json.dumps(resp, default=str) + "\n").encode())
            self.wfile.flush()


class WorkerServer(socketserver.ThreadingTCPServer):
    """Executes unit payloads for remote runners.

    Concurrency model: up to ``capacity`` units execute at once (a
    multi-core DPU sets ``--capacity`` to its spare cores; the default 1
    keeps the original fully-serialized behaviour), and units of the SAME
    (platform, task) always serialize against each other — that per-key
    lock is the prepare barrier for the shared contexts
    ``_subprocess_run_unit`` keys per (platform, task).  Disjoint tasks run
    concurrently; identical tasks queue.
    """

    allow_reuse_address = True
    daemon_threads = True

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        plugin_dirs: Any = (),
        capacity: int = 1,
    ):
        super().__init__((host, port), _Handler)
        self.capacity = max(1, int(capacity))
        self._slots = threading.BoundedSemaphore(self.capacity)
        self._task_locks: dict[tuple[str, str], threading.Lock] = {}
        self._locks_guard = threading.Lock()
        # Measured throughput, advertised on ping: EWMA of this worker's own
        # unit wall times (overall + per task).  Auto-weight calibration
        # (``--shard i/n@auto``) sizes shards from capacity / ewma_s.
        self._stats_lock = threading.Lock()
        self._units_done = 0
        self._ewma_s: float | None = None
        self._task_ewma_s: dict[str, float] = {}
        registry.load_plugin_dirs(str(d) for d in plugin_dirs)

    @property
    def endpoint(self) -> str:
        host, port = self.server_address[:2]
        return f"{host}:{port}"

    def _task_lock(self, payload: dict[str, Any]) -> threading.Lock:
        platform = payload.get("platform") or {}
        key = (str(platform.get("name", "?")), str(payload.get("task", "?")))
        with self._locks_guard:
            return self._task_locks.setdefault(key, threading.Lock())

    def _observe(self, task: str, elapsed_s: Any) -> None:
        """Fold one finished unit's wall time into the advertised EWMAs."""
        try:
            x = float(elapsed_s)
        except (TypeError, ValueError):
            return
        if x <= 0:
            return
        with self._stats_lock:
            self._units_done += 1
            self._ewma_s = (
                x if self._ewma_s is None
                else EWMA_ALPHA * x + (1.0 - EWMA_ALPHA) * self._ewma_s
            )
            prev = self._task_ewma_s.get(task)
            self._task_ewma_s[task] = (
                x if prev is None else EWMA_ALPHA * x + (1.0 - EWMA_ALPHA) * prev
            )

    def throughput(self) -> dict[str, Any]:
        """The measured-throughput payload advertised on ping."""
        with self._stats_lock:
            return {
                "units": self._units_done,
                "ewma_s": self._ewma_s,
                "per_task": dict(self._task_ewma_s),
            }

    def dispatch(self, req: dict[str, Any]) -> dict[str, Any]:
        from repro.core import executor as executor_mod

        op = req.get("op")
        if op == "ping":
            return {
                "ok": True, "op": "ping", "pid": os.getpid(),
                "capacity": self.capacity, "throughput": self.throughput(),
            }
        if op == "run":
            # Payload plugin dirs load inside _subprocess_run_unit's try, so
            # a broken plugin serializes back as an error response instead of
            # killing the connection.
            payload = req.get("payload") or {}
            # Task lock OUTSIDE the capacity slot: same-task waiters queue
            # on their lock without occupying a slot, so disjoint tasks
            # really do run concurrently up to capacity.  No deadlock: a
            # slot holder is always executing, never waiting on a lock.
            with self._task_lock(payload), self._slots:
                resp = executor_mod._subprocess_run_unit(payload)
            if resp.get("ok"):
                self._observe(str(payload.get("task", "?")), resp.get("elapsed_s"))
            return resp
        return {"ok": False, "error": f"unknown op {op!r}"}

    def serve_in_thread(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t


# -- transport (client) ------------------------------------------------------
class _Conn:
    """One TCP connection to a worker (socket + buffered reader)."""

    def __init__(self, host: str, port: int):
        self.sock = socket.create_connection((host, port), timeout=CONNECT_TIMEOUT_S)
        self.sock.settimeout(REQUEST_TIMEOUT_S)
        self.rfile = self.sock.makefile("rb")

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class RemoteTransport:
    """Client for one worker endpoint.  Thread-safe connection pool.

    Concurrent callers (the executor's thread pool) each check out their
    own connection — the worker serves one request thread per connection,
    so a ``--capacity N`` worker really executes N units at once.  Idle
    connections are pooled and reused; a dead pooled connection (worker
    restarted between sweeps) retries once on a fresh one.
    """

    def __init__(self, endpoint: str):
        self.endpoint = endpoint
        self.host, self.port = parse_endpoint(endpoint)
        self._lock = threading.Lock()
        self._idle: list[_Conn] = []
        self._closed = False
        # In-flight requests are bounded by the worker's advertised capacity
        # (learned from ping on first use): excess callers queue CLIENT-side,
        # so worker-side queue wait never ticks against the socket timeout
        # and a unit is never re-sent while the worker still executes it.
        self._gate_lock = threading.Lock()
        self._gate: threading.BoundedSemaphore | None = None

    def _checkout(self, fresh: bool = False) -> _Conn:
        """Pop an idle connection, or dial.  ``fresh`` always dials — the
        retry path must not pick up ANOTHER stale pooled connection after a
        worker restart invalidated the whole pool."""
        if not fresh:
            with self._lock:
                if self._idle:
                    return self._idle.pop()
        return _Conn(self.host, self.port)

    def _checkin(self, conn: _Conn) -> None:
        with self._lock:
            if not self._closed:
                self._idle.append(conn)
                return
        conn.close()

    def close(self) -> None:
        with self._lock:
            idle, self._idle = self._idle, []
            self._closed = True
        for conn in idle:
            conn.close()

    def _probe_capacity(self) -> int | None:
        """Ping on a dedicated connection; None when unreachable."""
        try:
            conn = _Conn(self.host, self.port)
        except OSError:
            return None
        try:
            conn.sock.sendall(b'{"op": "ping"}\n')
            line = conn.rfile.readline()
            if not line:
                return None
            cap = int(json.loads(line).get("capacity", 1) or 1)
            self._checkin(conn)
            conn = None
            return max(1, cap)
        except (OSError, json.JSONDecodeError, TypeError, ValueError):
            return None
        finally:
            if conn is not None:
                conn.close()

    def _capacity_gate(self) -> "threading.BoundedSemaphore":
        with self._gate_lock:
            if self._gate is not None:
                return self._gate
        cap = self._probe_capacity()
        with self._gate_lock:
            # Only cache a gate learned from a live worker: probing a not-
            # yet-started worker (wait_ready) must not pin capacity to 1.
            if self._gate is None and cap is not None:
                self._gate = threading.BoundedSemaphore(cap)
            return self._gate or threading.BoundedSemaphore(1)

    def request(self, obj: dict[str, Any]) -> dict[str, Any]:
        data = (json.dumps(obj, default=str) + "\n").encode()
        with self._capacity_gate():
            # One retry: a stale pooled connection (worker restart between
            # sweeps) fails on first use; the retry always dials fresh.
            for attempt in (0, 1):
                conn = None
                try:
                    conn = self._checkout(fresh=attempt > 0)
                    conn.sock.sendall(data)
                    line = conn.rfile.readline()
                    if not line:
                        raise ConnectionError("worker closed connection")
                    resp = json.loads(line)
                    self._checkin(conn)
                    return resp
                except (OSError, json.JSONDecodeError) as e:
                    if conn is not None:
                        conn.close()
                    if attempt:
                        raise RemoteExecutionError(
                            f"worker {self.endpoint} unreachable: {e}"
                        ) from e
        raise AssertionError("unreachable")

    def ping(self) -> bool:
        try:
            return bool(self.request({"op": "ping"}).get("ok"))
        except RemoteExecutionError:
            return False

    def info(self) -> dict[str, Any] | None:
        """Full ping payload (capacity, measured throughput) from a live
        worker; ``None`` when the worker is unreachable or answered with an
        error payload."""
        try:
            resp = self.request({"op": "ping"})
        except RemoteExecutionError:
            return None
        return resp if resp.get("ok") else None

    def run_unit(self, payload: dict[str, Any]) -> dict[str, Any]:
        resp = self.request({"op": "run", "payload": payload})
        if not resp.get("ok"):
            raise RemoteExecutionError(
                f"worker {self.endpoint} failed: {resp.get('error', 'unknown error')}"
            )
        return resp


_TRANSPORTS: dict[str, RemoteTransport] = {}
_transports_lock = threading.Lock()


def get_transport(endpoint: str) -> RemoteTransport:
    """Process-wide transport pool: one client per endpoint."""
    with _transports_lock:
        t = _TRANSPORTS.get(endpoint)
        if t is None:
            t = _TRANSPORTS[endpoint] = RemoteTransport(endpoint)
        return t


def wait_ready(endpoint: str, timeout: float = 30.0) -> bool:
    """Poll until the worker answers ping (workers announce asynchronously).

    Only *unreachable* states keep polling (connection refused / reset /
    timed out — the worker just hasn't bound yet).  A worker that ANSWERS
    ping with an error payload is alive but broken (bad plugin, protocol
    mismatch); waiting the full timeout on it would only mask the real
    failure, so that raises :class:`RemoteExecutionError` immediately with
    the worker's own payload in the message.
    """
    deadline = time.monotonic() + timeout
    transport = get_transport(endpoint)
    while True:
        try:
            resp = transport.request({"op": "ping"})
        except RemoteExecutionError:
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.1)
            continue
        if resp.get("ok"):
            return True
        raise RemoteExecutionError(
            f"worker {endpoint} answered ping with an error payload: "
            f"{resp.get('error', resp)!r}"
        )


# -- loopback worker subprocess ----------------------------------------------
class LocalWorker:
    """Context manager: spawn ``repro.core.remote worker`` on loopback.

    The zero-config path for tests/CI and the template for real deployment —
    point the spawn command at ``ssh <dpu> python -m repro.core.remote
    worker`` and nothing else changes.
    """

    def __init__(
        self,
        plugin_dirs: Any = (),
        startup_timeout: float = 60.0,
        capacity: int = 1,
    ):
        self.plugin_dirs = [str(d) for d in plugin_dirs]
        self.startup_timeout = startup_timeout
        self.capacity = max(1, int(capacity))
        self.endpoint: str | None = None
        self._proc: subprocess.Popen | None = None
        self._announced = threading.Event()

    def _pump_stdout(self, q) -> None:
        # Runs for the worker's lifetime: keeps draining the pipe after the
        # announce so a chatty worker can never block on a full pipe buffer.
        for line in self._proc.stdout:
            if not self._announced.is_set():
                q.put(line)
        q.put(None)

    def __enter__(self) -> "LocalWorker":
        import queue

        cmd = [
            sys.executable, "-m", "repro.core.remote", "worker",
            "--port", "0", "--capacity", str(self.capacity),
        ]
        for d in self.plugin_dirs:
            cmd += ["--plugin-dir", d]
        env = dict(os.environ)
        # The child must import repro even when the parent runs from a
        # source tree without `pip install -e .`.
        src_root = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src_root, env.get("PYTHONPATH")) if p
        )
        self._proc = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env
        )
        # Read announce lines through a thread so the startup timeout holds
        # even when the worker hangs without printing or exiting.
        q: "queue.Queue[str | None]" = queue.Queue()
        threading.Thread(target=self._pump_stdout, args=(q,), daemon=True).start()
        deadline = time.monotonic() + self.startup_timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._proc.kill()
                raise TimeoutError("worker did not announce its endpoint in time")
            try:
                line = q.get(timeout=min(remaining, 0.5))
            except queue.Empty:
                continue
            if line is None:
                raise RuntimeError(f"worker died on startup (rc={self._proc.wait()})")
            if line.startswith("listening on "):
                self.endpoint = line.split("listening on ", 1)[1].strip()
                self._announced.set()
                return self

    def __exit__(self, *exc) -> None:
        if self.endpoint:
            with _transports_lock:
                t = _TRANSPORTS.pop(self.endpoint, None)
            if t is not None:
                t.close()
        if self._proc is not None:
            self._proc.terminate()
            try:
                self._proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self._proc.kill()


# -- CLI ---------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.core.remote", description="dpBento remote sweep worker"
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    w = sub.add_parser("worker", help="serve unit payloads over TCP")
    w.add_argument("--host", default="127.0.0.1")
    w.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    w.add_argument(
        "--capacity", type=int, default=1,
        help="units executed concurrently (same-task units still serialize; "
        "set to the host's spare cores on a multi-core DPU)",
    )
    w.add_argument(
        "--plugin-dir", action="append", default=[], metavar="DIR",
        help="plugin task directory to preload (repeatable)",
    )
    pg = sub.add_parser("ping", help="check a worker endpoint")
    pg.add_argument("endpoint")
    pg.add_argument("--timeout", type=float, default=10.0)
    args = p.parse_args(argv)

    if args.cmd == "worker":
        server = WorkerServer(
            args.host, args.port, plugin_dirs=args.plugin_dir, capacity=args.capacity
        )
        print(f"listening on {server.endpoint}", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.cmd == "ping":
        try:
            ok = wait_ready(args.endpoint, timeout=args.timeout)
        except RemoteExecutionError as e:
            print(f"error: {e}")
            return 1
        print("ok" if ok else "unreachable")
        return 0 if ok else 1
    return 2


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = [
    "RemoteExecutionError",
    "RemoteTransport",
    "WorkerServer",
    "LocalWorker",
    "get_transport",
    "wait_ready",
    "parse_endpoint",
    "parse_fleet",
    "samples_from_wire",
]
