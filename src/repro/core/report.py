"""Report generation: aggregated rows -> CSV / markdown, shard merging.

The report step consolidates cached per-test logs into a single table
(paper §3.1 "Report"). Rows are dicts; columns are the union of keys, with
`task` first, `param:*` next (sorted), then metrics (sorted).

Sharded sweeps (``--shard i/n``) each emit a partial report;
:func:`merge_shard_reports` reassembles them into the canonical row order
an unsharded run would have produced, using the box itself as the ordering
oracle (:func:`box_row_order`) — no sequencing metadata needs to travel
with the shards.
"""
from __future__ import annotations

import csv
import io
import json
from pathlib import Path
from typing import TYPE_CHECKING, Any, Iterable, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.box import Box


def _columns(rows: list[dict[str, Any]]) -> list[str]:
    keys: set[str] = set()
    for r in rows:
        keys.update(r)
    params = sorted(k for k in keys if k.startswith("param:"))
    metrics = sorted(k for k in keys if not k.startswith("param:") and k not in ("task", "platform"))
    head = [c for c in ("platform", "task") if c in keys]
    return head + params + metrics


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v != v:  # nan
            return ""
        if abs(v) >= 1e6 or (abs(v) < 1e-3 and v != 0):
            return f"{v:.4g}"
        return f"{v:.4f}".rstrip("0").rstrip(".")
    return "" if v is None else str(v)


def to_csv(rows: list[dict[str, Any]]) -> str:
    if not rows:
        return ""
    cols = _columns(rows)
    buf = io.StringIO()
    buf.write(",".join(cols) + "\n")
    for r in rows:
        buf.write(",".join(_fmt(r.get(c)) for c in cols) + "\n")
    return buf.getvalue()


def to_markdown(rows: list[dict[str, Any]]) -> str:
    if not rows:
        return "(no results)\n"
    cols = _columns(rows)
    buf = io.StringIO()
    buf.write("| " + " | ".join(cols) + " |\n")
    buf.write("|" + "|".join(["---"] * len(cols)) + "|\n")
    for r in rows:
        buf.write("| " + " | ".join(_fmt(r.get(c)) for c in cols) + " |\n")
    return buf.getvalue()


def merge_platform_reports(named_rows: dict[str, list[dict[str, Any]]]) -> list[dict[str, Any]]:
    """Tag each platform's rows and concatenate (cross-platform comparison)."""
    merged: list[dict[str, Any]] = []
    for platform, rows in named_rows.items():
        for r in rows:
            r2 = dict(r)
            r2["platform"] = platform
            merged.append(r2)
    return merged


def _row_key(row: dict[str, Any]) -> tuple:
    """Identity of a report row: (platform, task, param values as strings).

    Values are stringified so rows that round-tripped through CSV compare
    equal to rows straight out of a box expansion.
    """
    return (
        str(row.get("platform", "")),
        str(row.get("task", "")),
        tuple(sorted((k, str(v)) for k, v in row.items() if k.startswith("param:"))),
    )


def box_row_order(box: "Box", platforms: Sequence[Any] | None = None) -> list[tuple]:
    """Canonical report-row key order for a box.

    Mirrors ``SweepExecutor.run_box`` exactly: per platform, tasks in
    first-declaration order (deduped), each task's specs in declaration
    order, each spec's parameter expansions in expansion order.  Rows carry
    a ``platform`` column only for multi-platform sweeps, so single-platform
    keys use the empty platform.
    """
    from repro.core.platform import resolve

    specs = platforms if platforms is not None else (box.platforms or [None])
    names = [resolve(p).name for p in specs]
    multi = len(names) > 1
    keys: list[tuple] = []
    for name in names:
        seen: set[str] = set()
        for spec in box.tasks:
            if spec.task in seen:
                continue
            seen.add(spec.task)
            for spec2 in box.tasks:
                if spec2.task != spec.task:
                    continue
                for params in spec2.expand():
                    keys.append(
                        (
                            name if multi else "",
                            spec.task,
                            tuple(sorted((f"param:{k}", str(v)) for k, v in params.items())),
                        )
                    )
    return keys


def merge_shard_reports(
    shard_rows: Sequence[list[dict[str, Any]]],
    box: "Box | None" = None,
    platforms: Sequence[Any] | None = None,
) -> list[dict[str, Any]]:
    """Merge per-shard report rows back into one canonically-ordered table.

    With ``box`` (and optionally the ``platforms`` the runs swept), rows are
    ordered exactly as an unsharded run would emit them; rows whose key the
    box does not predict (custom aggregate reports) keep their relative
    order after the predicted ones.  Without a box, rows sort by
    (platform, task, params) — deterministic, but not necessarily the
    unsharded order.  Shards are disjoint by construction; should inputs
    overlap anyway (e.g. the same shard file passed twice), each key keeps
    at most as many rows as the box predicts for it (overlapping specs can
    legitimately emit the same grid point more than once), earliest first.
    """
    flat: list[dict[str, Any]] = [row for rows in shard_rows for row in rows]
    if box is None:
        seen: set[tuple] = set()
        decorated = []
        for pos, row in enumerate(flat):
            key = _row_key(row)
            if key in seen:
                continue
            seen.add(key)
            decorated.append(((key, pos), row))
        decorated.sort(key=lambda t: t[0])
        return [row for _, row in decorated]

    # Each canonical key may occur several times (overlapping task specs);
    # hand out its ranks in order and drop anything beyond its multiplicity.
    canonical = box_row_order(box, platforms)
    slots: dict[tuple, list[int]] = {}
    for i, k in enumerate(canonical):
        slots.setdefault(k, []).append(i)
    taken: dict[tuple, int] = {}
    seen_unpredicted: set[tuple] = set()
    decorated = []
    for pos, row in enumerate(flat):
        key = _row_key(row)
        ranks = slots.get(key)
        if ranks is None:
            # Unpredicted (custom aggregate) rows: dedupe, keep arrival order
            # after all predicted rows.
            if key in seen_unpredicted:
                continue
            seen_unpredicted.add(key)
            decorated.append(((len(canonical), pos), row))
            continue
        n = taken.get(key, 0)
        if n >= len(ranks):
            continue  # duplicate input beyond the box's multiplicity
        taken[key] = n + 1
        decorated.append(((ranks[n], pos), row))
    decorated.sort(key=lambda t: t[0])
    return [row for _, row in decorated]


def load_report_rows(path: str | Path) -> list[dict[str, Any]]:
    """Read rows back from a shard report file (.json or .csv).

    JSON preserves value types exactly; CSV rows come back as strings, which
    ``to_csv``/``to_markdown`` pass through verbatim — so CSV-merge-CSV is
    byte-stable even though it is no longer typed.
    """
    path = Path(path)
    text = path.read_text()
    if path.suffix == ".json" or text.lstrip()[:1] in ("{", "["):
        d = json.loads(text)
        rows = d["rows"] if isinstance(d, dict) else d
        return [dict(r) for r in rows]
    return [dict(r) for r in csv.DictReader(io.StringIO(text))]


def speedup_table(
    rows: Iterable[dict[str, Any]], metric: str, baseline_platform: str
) -> list[dict[str, Any]]:
    """Per parameter-combination speedup of each platform vs a baseline."""
    by_key: dict[tuple, dict[str, float]] = {}
    for r in rows:
        key = tuple(sorted((k, str(v)) for k, v in r.items() if k.startswith("param:") or k == "task"))
        if metric in r:
            by_key.setdefault(key, {})[r.get("platform", "?")] = r[metric]
    out = []
    for key, vals in sorted(by_key.items()):
        base = vals.get(baseline_platform)
        if base is None or base == 0:
            continue
        row = dict(key)
        for plat, v in sorted(vals.items()):
            row[f"speedup:{plat}"] = v / base
        out.append(row)
    return out
