"""Report generation: aggregated rows -> CSV / markdown.

The report step consolidates cached per-test logs into a single table
(paper §3.1 "Report"). Rows are dicts; columns are the union of keys, with
`task` first, `param:*` next (sorted), then metrics (sorted).
"""
from __future__ import annotations

import io
from typing import Any, Iterable


def _columns(rows: list[dict[str, Any]]) -> list[str]:
    keys: set[str] = set()
    for r in rows:
        keys.update(r)
    params = sorted(k for k in keys if k.startswith("param:"))
    metrics = sorted(k for k in keys if not k.startswith("param:") and k not in ("task", "platform"))
    head = [c for c in ("platform", "task") if c in keys]
    return head + params + metrics


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v != v:  # nan
            return ""
        if abs(v) >= 1e6 or (abs(v) < 1e-3 and v != 0):
            return f"{v:.4g}"
        return f"{v:.4f}".rstrip("0").rstrip(".")
    return "" if v is None else str(v)


def to_csv(rows: list[dict[str, Any]]) -> str:
    if not rows:
        return ""
    cols = _columns(rows)
    buf = io.StringIO()
    buf.write(",".join(cols) + "\n")
    for r in rows:
        buf.write(",".join(_fmt(r.get(c)) for c in cols) + "\n")
    return buf.getvalue()


def to_markdown(rows: list[dict[str, Any]]) -> str:
    if not rows:
        return "(no results)\n"
    cols = _columns(rows)
    buf = io.StringIO()
    buf.write("| " + " | ".join(cols) + " |\n")
    buf.write("|" + "|".join(["---"] * len(cols)) + "|\n")
    for r in rows:
        buf.write("| " + " | ".join(_fmt(r.get(c)) for c in cols) + " |\n")
    return buf.getvalue()


def merge_platform_reports(named_rows: dict[str, list[dict[str, Any]]]) -> list[dict[str, Any]]:
    """Tag each platform's rows and concatenate (cross-platform comparison)."""
    merged: list[dict[str, Any]] = []
    for platform, rows in named_rows.items():
        for r in rows:
            r2 = dict(r)
            r2["platform"] = platform
            merged.append(r2)
    return merged


def speedup_table(
    rows: Iterable[dict[str, Any]], metric: str, baseline_platform: str
) -> list[dict[str, Any]]:
    """Per parameter-combination speedup of each platform vs a baseline."""
    by_key: dict[tuple, dict[str, float]] = {}
    for r in rows:
        key = tuple(sorted((k, str(v)) for k, v in r.items() if k.startswith("param:") or k == "task"))
        if metric in r:
            by_key.setdefault(key, {})[r.get("platform", "?")] = r[metric]
    out = []
    for key, vals in sorted(by_key.items()):
        base = vals.get(baseline_platform)
        if base is None or base == 0:
            continue
        row = dict(key)
        for plat, v in sorted(vals.items()):
            row[f"speedup:{plat}"] = v / base
        out.append(row)
    return out
