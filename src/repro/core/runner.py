"""Runner: executes a box end-to-end (paper §3.3, Fig. 3).

Workflow per task: (1) prepare once for all of the task's tests, (2) run each
expanded parameter combination sequentially, caching intermediate results in
the context log, (3) report. `clean` is deliberately NOT invoked after each
task — boxes may share prepared state — and is exposed as an explicit call /
CLI, mirroring the paper's design.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

from repro.core import registry, report
from repro.core.box import Box
from repro.core.task import TaskContext, TestResult


@dataclass
class RunnerResult:
    box: str
    platform: str
    rows: list[dict[str, Any]] = field(default_factory=list)
    results: list[TestResult] = field(default_factory=list)
    errors: list[dict[str, str]] = field(default_factory=list)

    def csv(self) -> str:
        return report.to_csv(self.rows)

    def markdown(self) -> str:
        return report.to_markdown(self.rows)


class Runner:
    def __init__(
        self,
        platform: dict[str, Any] | None = None,
        iters: int = 5,
        warmup: int = 2,
        fail_fast: bool = False,
    ):
        self.platform = dict(platform or {"name": "default"})
        self.iters = iters
        self.warmup = warmup
        self.fail_fast = fail_fast
        # Contexts persist across boxes so prepare is shared; cleaned explicitly.
        self._contexts: dict[str, TaskContext] = {}
        self._prepared: set[str] = set()

    def _ctx(self, task_name: str) -> TaskContext:
        if task_name not in self._contexts:
            self._contexts[task_name] = TaskContext(
                platform=self.platform, iters=self.iters, warmup=self.warmup
            )
        return self._contexts[task_name]

    def run_box(self, box: Box) -> RunnerResult:
        out = RunnerResult(box=box.name, platform=self.platform.get("name", "default"))
        for spec in box.tasks:
            task = registry.get(spec.task)
            task.validate_params(spec.params)
            ctx = self._ctx(task.name)
            if task.name not in self._prepared:
                task.prepare(ctx)  # (1) prepare once per task
                self._prepared.add(task.name)
            metrics = spec.metrics or task.default_metrics
            for params in spec.expand():  # (2) sequential test execution
                try:
                    out.results.append(task.execute_test(ctx, params, metrics))
                except Exception as e:  # noqa: BLE001 - report, keep going
                    if self.fail_fast:
                        raise
                    out.errors.append(
                        {"task": task.name, "params": json.dumps(params, default=str),
                         "error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()}
                    )
            # (3) report from accumulated results of this task
            task_results = [r for r in out.results if r.task == task.name]
            out.rows.extend(task.report(ctx, task_results))
        return out

    def clean(self, task_name: str | None = None) -> None:
        """Explicit cleanup (paper step 6) — restores pre-benchmark state."""
        names = [task_name] if task_name else list(self._prepared)
        for name in names:
            task = registry.get(name)
            task.clean(self._ctx(name))
            self._prepared.discard(name)
            self._contexts.pop(name, None)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(prog="repro.core.runner", description="Run a dpBento box")
    p.add_argument("box", nargs="?", help="path to box JSON")
    p.add_argument("--iters", type=int, default=5)
    p.add_argument("--warmup", type=int, default=2)
    p.add_argument("--format", choices=("csv", "md"), default="csv")
    p.add_argument("--out", default=None, help="write report here instead of stdout")
    p.add_argument("--clean", action="store_true", help="clean all tasks and exit")
    p.add_argument("--list-tasks", action="store_true")
    args = p.parse_args(argv)

    if args.list_tasks:
        for name in registry.known_tasks():
            t = registry.get(name)
            print(f"{name}: params={sorted(t.param_space)} metrics={t.default_metrics}")
        return 0
    if args.clean:
        r = Runner()
        for name in registry.known_tasks():
            r.clean(name)
        print("cleaned all tasks")
        return 0
    if not args.box:
        p.error("box path required")
    box = Box.load(args.box)
    runner = Runner(iters=args.iters, warmup=args.warmup)
    res = runner.run_box(box)
    text = res.csv() if args.format == "csv" else res.markdown()
    if args.out:
        Path(args.out).write_text(text)
    else:
        sys.stdout.write(text)
    for err in res.errors:
        print(f"ERROR {err['task']} {err['params']}: {err['error']}", file=sys.stderr)
    return 1 if res.errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
