"""Runner: executes a box end-to-end (paper §3.3, Fig. 3).

Workflow per task: (1) prepare once for all of the task's tests, (2) run each
expanded parameter combination, caching intermediate results in the context
log, (3) report. `clean` is deliberately NOT invoked after each task — boxes
may share prepared state — and is exposed as an explicit call / CLI,
mirroring the paper's design.

Since the sweep-executor refactor the Runner is a thin façade over
:class:`repro.core.executor.SweepExecutor`: ``workers=1`` (the default)
preserves the original strictly-sequential semantics, ``workers>1`` fans the
expanded tests onto a pool, ``platforms`` sweeps several execution backends,
and an optional :class:`repro.core.cache.ResultCache` makes re-runs
incremental.  The CLI exposes all three (``--workers``, ``--platforms``,
``--cache``/``--no-cache``).

Distributed sweeps compose three more flags: ``--shard i/n`` executes only
one consistent-hash slice of the box, ``--merge SHARD...`` reassembles shard
reports into the canonical unsharded table, and ``--remote host:port``
dispatches unit execution to a ``repro.core.remote`` worker.

Heterogeneous fleets schedule by cost: ``--shard i/n@w`` weights shards,
``--shard i/n@auto`` calibrates the weight vector from worker pings + cost
evidence, ``--weighted-shard`` balances estimated per-unit cost (fed by
wall times the cache records) instead of key count, ``--shard-plan``
previews each shard's unit count and cost share, and
``--cache-max-entries`` / ``--cache-max-age`` bound long-lived caches on
flush (an EWMA cost sidecar survives the eviction).

Pooled runs default to ``--schedule dynamic``: a pull-based fleet scheduler
(one cost-descending queue, sinks per worker endpoint honoring advertised
capacity, speculative re-dispatch of stragglers past ``--straggler-factor``
times their estimate).  ``--schedule static`` keeps the up-front LPT plan.

Elastic fleets drop the endpoint list entirely: ``--registry host:port``
discovers workers from a :mod:`repro.runtime.membership` registry
(workers started with ``--register``), grows/shrinks the sink set
mid-sweep on membership events, detects dead/hung workers in seconds via
heartbeats + cost-derived per-unit deadlines, and records per-endpoint
health in a ``health.json`` sidecar for cross-run blacklisting.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Sequence

from repro.core import config as config_mod
from repro.core import registry, report
from repro.core.box import Box
from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor, SweepStats
from repro.core.shard import ShardSpec
from repro.core.task import TestResult


@dataclass
class RunnerResult:
    box: str
    platform: str
    rows: list[dict[str, Any]] = field(default_factory=list)
    results: list[TestResult] = field(default_factory=list)
    errors: list[dict[str, str]] = field(default_factory=list)
    stats: SweepStats = field(default_factory=SweepStats)

    def csv(self) -> str:
        return report.to_csv(self.rows)

    def markdown(self) -> str:
        return report.to_markdown(self.rows)


class Runner:
    def __init__(
        self,
        platform: dict[str, Any] | str | None = None,
        iters: int = 5,
        warmup: int = 2,
        fail_fast: bool = False,
        workers: int = 1,
        platforms: Sequence[str] | None = None,
        cache: ResultCache | None = None,
        pool: str = "thread",
        remote: str | None = None,
        weighted_shard: bool = False,
        schedule: str = "dynamic",
        straggler_factor: float = 4.0,
        min_time_s: float = 0.0,
        fleet_registry: str | None = None,
    ):
        if platforms is not None and platform is not None:
            raise ValueError("pass either platform= or platforms=, not both")
        if platforms is None:
            # None lets box-declared platform sweeps take effect.
            platforms = None if platform is None else [platform]
        self._exec = SweepExecutor(
            platforms=platforms,
            workers=workers,
            iters=iters,
            warmup=warmup,
            fail_fast=fail_fast,
            cache=cache,
            pool=pool,
            remote=remote,
            fleet_registry=fleet_registry,
            weighted_shard=weighted_shard,
            schedule=schedule,
            straggler_factor=straggler_factor,
            min_time_s=min_time_s,
        )
        self.platform = self._exec.platforms[0].describe()
        self.iters = iters
        self.warmup = warmup
        self.fail_fast = fail_fast

    @classmethod
    def from_config(
        cls, cfg: config_mod.SweepConfig, cache: ResultCache | None = None
    ) -> "Runner":
        """Build a Runner from the shared CLI sweep surface (core.config)."""
        if cache is None:
            cache = config_mod.make_cache(cfg)
        return cls(
            iters=cfg.iters,
            warmup=cfg.warmup,
            min_time_s=cfg.min_time_s,
            workers=cfg.workers,
            platforms=cfg.platforms,
            cache=cache,
            pool=cfg.pool,
            remote=cfg.remote,
            fleet_registry=cfg.registry,
            weighted_shard=cfg.weighted_shard,
            schedule=cfg.schedule,
            straggler_factor=cfg.straggler_factor,
        )

    @property
    def executor(self) -> SweepExecutor:
        return self._exec

    def run_box(self, box: Box, shard: ShardSpec | None = None) -> RunnerResult:
        sweep = self._exec.run_box(box, shard=shard)
        name = sweep.platforms[0] if len(sweep.platforms) == 1 else ",".join(sweep.platforms)
        return RunnerResult(
            box=sweep.box,
            platform=name,
            rows=sweep.rows,
            results=sweep.results,
            errors=sweep.errors,
            stats=sweep.stats,
        )

    def clean(self, task_name: str | None = None) -> None:
        """Explicit cleanup (paper step 6) — restores pre-benchmark state."""
        self._exec.clean(task_name)


def _emit(text: str, out: str | None) -> None:
    if out:
        Path(out).write_text(text)
    else:
        sys.stdout.write(text)


def _format_rows(rows: list[dict[str, Any]], fmt: str, box: str = "") -> str:
    if fmt == "md":
        return report.to_markdown(rows)
    if fmt == "json":
        return json.dumps({"box": box, "rows": rows}, indent=1, default=str) + "\n"
    return report.to_csv(rows)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(prog="repro.core.runner", description="Run a dpBento box")
    p.add_argument("box_pos", nargs="?", metavar="box", help="path to box JSON")
    p.add_argument("--box", dest="box_opt", default=None, help="path to box JSON (same as the positional)")
    # The whole sweep surface (--iters/--workers/--platforms/--cache*/
    # --shard*/--remote/--schedule/...) comes from core.config so this CLI,
    # benchmarks.run, and the serving CLI can never drift apart.
    config_mod.add_sweep_args(p)
    p.add_argument("--format", choices=("csv", "md", "json"), default="csv")
    p.add_argument("--out", default=None, help="write report here instead of stdout")
    p.add_argument(
        "--merge", nargs="+", default=None, metavar="REPORT",
        help="merge shard report files (.csv/.json) into one table and exit",
    )
    p.add_argument(
        "--plugin-dir", action="append", default=[], metavar="DIR",
        help="load a directory plugin task before running (repeatable)",
    )
    p.add_argument("--clean", action="store_true", help="clean all tasks and exit")
    p.add_argument("--list-tasks", action="store_true")
    p.add_argument("--list-platforms", action="store_true")
    args = p.parse_args(argv)
    args.box = args.box_opt or args.box_pos

    if args.list_tasks:
        for name in registry.known_tasks():
            t = registry.get(name)
            print(f"{name}: params={sorted(t.param_space)} metrics={t.default_metrics}")
        return 0
    if args.list_platforms:
        from repro.core.platform import get_platform, known_platforms

        for name in known_platforms():
            plat = get_platform(name)
            print(f"{name}: kind={plat.kind} time_scale={plat.time_scale} flags={plat.flags}")
        return 0
    if args.clean:
        r = Runner()
        for name in registry.known_tasks():
            r.clean(name)
        print("cleaned all tasks")
        return 0
    for d in args.plugin_dir:
        registry.load_plugin_dir(d)
    if not args.box:
        p.error("box path required")
    cfg = config_mod.SweepConfig.from_args(args)
    if cfg.platforms:
        from repro.core.platform import get_platform

        try:
            for name in cfg.platforms:
                get_platform(name)
        except KeyError as e:
            p.error(str(e.args[0]))
    box = Box.load(args.box)

    if args.merge:
        # Merge mode: no execution — reassemble shard reports in the box's
        # canonical row order and emit one table.
        shard_rows = [report.load_report_rows(f) for f in args.merge]
        rows = report.merge_shard_reports(shard_rows, box=box, platforms=cfg.platforms)
        _emit(_format_rows(rows, args.format, box.name), args.out)
        print(
            f"# merged {len(rows)} rows from {len(args.merge)} shard reports",
            file=sys.stderr,
        )
        return 0

    shard = config_mod.validate_sweep(cfg, p.error)
    cache = config_mod.make_cache(cfg)
    runner = Runner.from_config(cfg, cache=cache)
    if args.shard_plan:
        plan = runner.executor.shard_plan(box, shard)
        for row in plan:
            print(
                f"shard {row['shard']}  weight {row['weight']:g}  "
                f"units {row['units']}  est_cost {row['est_cost']:.6g}  "
                f"share {row['cost_share']:.1%}"
            )
        measured = plan[0]["measured_points"] if plan else 0
        print(
            f"# plan over {sum(r['units'] for r in plan)} units, "
            f"{measured} measured cost points",
            file=sys.stderr,
        )
        return 0
    res = runner.run_box(box, shard=shard)
    _emit(_format_rows(res.rows, args.format, res.box), args.out)
    if shard is not None:
        print(f"# shard {shard}: {res.stats.total} units", file=sys.stderr)
    if cache is not None:
        print(f"# cached={res.stats.cached}/{res.stats.total}", file=sys.stderr)
    if res.stats.speculated:
        print(
            f"# speculated={res.stats.speculated} straggler unit(s) re-dispatched",
            file=sys.stderr,
        )
    for err in res.errors:
        print(f"ERROR {err['task']} {err['params']}: {err['error']}", file=sys.stderr)
    return 1 if res.errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
