"""Pull-based dynamic fleet scheduler (ROADMAP: straggler re-dispatch).

Static dispatch (``SweepExecutor`` with ``schedule="static"``) decides
everything up front: LPT submission order and per-shard ownership are fixed
before the first unit runs, so a mis-weighted shard or one hung remote unit
stalls the whole sweep — exactly the asymmetric host-vs-SmartNIC behaviour
the BlueField-2 characterizations document.  This module reacts to measured
progress instead:

  * a single **priority work queue** (cost-descending, fed by
    :class:`repro.core.cost.CostModel` estimates) holds every unit;
  * **sink workers** — local thread/process slots and one sink per remote
    worker endpoint, each honoring the worker's advertised capacity — PULL
    the heaviest unit they are eligible for as a slot frees up, so a fast
    sink that drains early keeps taking work instead of idling behind a
    static plan;
  * when the queue is empty and a unit has run longer than
    ``straggler_factor x`` its (runtime-calibrated) cost estimate, a
    **speculative copy** is re-enqueued for the other eligible sinks; the
    first completion wins and the loser is discarded.  Both attempts share
    one cache-key identity, so the duplicate dedupes through the result
    cache and report rows stay byte-identical to a sequential run.

The scheduler is execution-agnostic: a :class:`Sink` is just a name, a
capacity, and a ``run(unit)`` callable, so tests drive it with
controllable-latency fakes and the executor drives it with its
``_run_unit`` / process-pool / remote-transport closures.

Calibration note: cost estimates are *relative* weights, not seconds.  The
monitor learns the seconds-per-cost scale from completed attempts (median of
``elapsed / cost``) and only calls a unit a straggler once its runtime
exceeds ``straggler_factor x cost x scale`` (never less than
``min_straggler_s``), so a uniformly slow fleet is not speculated against —
and with nothing completed yet there is no scale, hence no speculation at
all.
"""
from __future__ import annotations

import heapq
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

#: Re-dispatch a unit once its runtime exceeds this multiple of its
#: calibrated cost estimate (and the queue has drained).
DEFAULT_STRAGGLER_FACTOR = 4.0
#: Never call a unit a straggler before it has run at least this long.
DEFAULT_MIN_STRAGGLER_S = 0.25


@dataclass
class Sink:
    """One pull-capable execution endpoint (local slots or a remote worker).

    Two driving modes:

    * **threaded** (``submit is None``): ``run`` executes one unit and
      returns ``(result, was_cached)``; it is called from up to
      ``capacity`` puller threads at once and may raise to report a unit
      failure.
    * **async** (``submit`` set): no puller threads at all — the
      scheduler's single dispatcher thread calls ``submit(unit, done)``
      whenever the sink has a free in-flight slot (at most ``capacity``
      outstanding), and the sink completes the unit later by calling
      ``done(result=..., was_cached=...)`` or ``done(error=...)`` exactly
      once, from any thread (typically a multiplexed transport's event
      loop).  ``run`` is ignored in this mode (pass a stub).
    """

    name: str
    capacity: int
    run: Callable[[Any], tuple[Any, bool]]
    submit: Callable[[Any, Callable[..., None]], None] | None = None


@dataclass
class WorkItem:
    """One schedulable unit: an opaque payload plus its scheduling inputs.

    ``cost`` is the relative wall-cost estimate (queue priority is
    cost-descending); ``sinks`` restricts execution to those sink indexes
    (``None`` = any sink) — a unit bound to a specific measurement target
    (its remote platform's endpoint) must not run elsewhere.
    """

    unit: Any
    cost: float = 1.0
    sinks: tuple[int, ...] | None = None
    # ``sinks=None`` means *dynamic* eligibility: any live sink, including
    # sinks that join after the run started (elastic membership).  An
    # explicit tuple pins the unit to those sinks forever.


@dataclass
class Outcome:
    """What happened to one work item.

    ``attempts`` counts every claim (errored tries on dead sinks and the
    speculative copy included); ``error`` is only set when NO attempt
    succeeded — a unit that errored on one sink is retried on each
    remaining eligible sink before the error becomes terminal.
    ``elapsed_s`` is the winning attempt's wall time (None for errors).
    """

    item: WorkItem
    result: Any = None
    was_cached: bool = False
    error: BaseException | None = None
    sink: str | None = None
    attempts: int = 0
    speculated: bool = False
    redispatched: bool = False  # re-enqueued because its sink was marked dead
    elapsed_s: float | None = None


class _Tracked:
    """Scheduler-internal state for one work item."""

    __slots__ = (
        "item", "eligible", "dynamic", "waves", "live", "claims", "started",
        "running_on", "tried", "speculated", "done", "outcome",
    )

    def __init__(self, item: WorkItem, eligible: tuple[int, ...], dynamic: bool = False):
        self.item = item
        self.eligible = eligible
        self.dynamic = dynamic  # follow the live sink set as it changes
        self.waves: set[int] = set()  # open (not yet claimed) enqueue waves
        self.live = 0  # attempts currently executing
        self.claims = 0
        self.started = 0.0  # monotonic claim time of the latest attempt
        self.running_on: int | None = None
        self.tried: set[int] = set()  # sinks that have attempted this unit
        self.speculated = False
        self.done = False
        self.outcome = Outcome(item)


class FleetScheduler:
    """Cost-descending work queue drained by pulling sinks.

    Tickets, not assignments: enqueueing a unit pushes one *ticket* per
    eligible sink (a "wave"); the first sink to pop any of the wave's
    tickets claims the unit and the others discard their now-stale copies
    when they surface.  Work therefore flows to whichever eligible sink
    frees up first — no ownership is decided ahead of execution.
    """

    def __init__(
        self,
        sinks: Sequence[Sink],
        *,
        straggler_factor: float = DEFAULT_STRAGGLER_FACTOR,
        min_straggler_s: float = DEFAULT_MIN_STRAGGLER_S,
        fail_fast: bool = False,
        poll_s: float = 0.05,
    ):
        if not sinks:
            raise ValueError("need at least one sink")
        for s in sinks:
            if s.capacity < 1:
                raise ValueError(f"sink {s.name!r} capacity must be >= 1, got {s.capacity}")
        if straggler_factor <= 0:
            raise ValueError(f"straggler_factor must be > 0, got {straggler_factor}")
        self.sinks = list(sinks)
        self.straggler_factor = float(straggler_factor)
        self.min_straggler_s = float(min_straggler_s)
        self.fail_fast = fail_fast
        self.poll_s = float(poll_s)
        self._cv = threading.Condition()
        self._heaps: list[list[tuple[float, int, int, _Tracked]]] = [[] for _ in self.sinks]
        self._seq = 0
        self._next_wave = 0
        self._open_tickets = 0  # open waves across all tracked units
        self._done_count = 0
        self._stop = False
        self._scale_samples: list[float] = []
        self._tracked: list[_Tracked] = []
        self._dead: set[int] = set()  # sinks removed from the live set
        self._running = False
        self._threads: list[threading.Thread] = []
        # Async sinks: in-flight attempt count per sink (admission gate for
        # the dispatcher) + the single dispatcher thread driving them all.
        self._inflight: list[int] = [0] * len(self.sinks)
        self._dispatcher_started = False
        # Dispatch/puller threads ever created (monotonic — pruning dead
        # sinks' threads does not un-count them): the "client-side thread
        # budget" number the transport-scale benchmark asserts on.
        self.threads_started = 0

    # -- queue (all helpers assume self._cv is held) ------------------------
    def _push_wave_locked(self, t: _Tracked, sink_ids: Sequence[int]) -> None:
        wave = self._next_wave
        self._next_wave += 1
        t.waves.add(wave)
        self._open_tickets += 1
        for sid in sink_ids:
            self._seq += 1
            # seq breaks cost ties in submission (grid) order, so with no
            # cost evidence sinks pull in canonical order like static LPT.
            heapq.heappush(self._heaps[sid], (-max(t.item.cost, 0.0), self._seq, wave, t))
        self._cv.notify_all()

    def _eligible_locked(self, t: _Tracked) -> tuple[int, ...]:
        """The unit's CURRENT eligible sinks: live membership for dynamic
        units, the pinned tuple (minus dead sinks) otherwise.  Falls back to
        the full base set when every candidate is dead — an empty set would
        strand the unit with no path to a terminal outcome."""
        base = (
            tuple(range(len(self.sinks))) if t.dynamic else t.eligible
        )
        live = tuple(s for s in base if s not in self._dead)
        return live or base

    def _claim_locked(self, sid: int) -> _Tracked | None:
        if sid in self._dead:
            return None
        heap = self._heaps[sid]
        while heap:
            _, _, wave, t = heapq.heappop(heap)
            if t.done or wave not in t.waves:
                continue  # stale ticket: claimed elsewhere or already finished
            t.waves.discard(wave)
            self._open_tickets -= 1
            t.live += 1
            t.claims += 1
            t.started = time.monotonic()
            t.running_on = sid
            t.tried.add(sid)
            return t
        return None

    # -- pullers ------------------------------------------------------------
    def _puller(self, sid: int) -> None:
        sink = self.sinks[sid]
        while True:
            with self._cv:
                t = None
                while not self._stop and sid not in self._dead:
                    t = self._claim_locked(sid)
                    if t is not None:
                        break
                    self._cv.wait()
                if t is None:
                    return
            t0 = time.monotonic()
            try:
                result, was_cached = sink.run(t.item.unit)
            except BaseException as e:  # noqa: BLE001 - reported per unit
                self._finish(t, sid, error=e)
            else:
                self._finish(
                    t, sid, result=result, was_cached=bool(was_cached),
                    elapsed=time.monotonic() - t0,
                )

    # -- async sinks ---------------------------------------------------------
    def _dispatcher(self) -> None:
        """The single thread driving EVERY async sink.

        Claims work for any async sink with a free in-flight slot, then
        calls ``sink.submit`` OUTSIDE the lock (a submit that completes
        synchronously — e.g. a cache hit — re-enters ``_finish``, which
        takes the lock).  Completion callbacks free the slot and notify,
        waking this thread to claim the next unit.
        """
        while True:
            batch: list[tuple[int, _Tracked]] = []
            with self._cv:
                while not self._stop:
                    for sid, sink in enumerate(self.sinks):
                        if sink.submit is None or sid in self._dead:
                            continue
                        while self._inflight[sid] < sink.capacity:
                            t = self._claim_locked(sid)
                            if t is None:
                                break
                            self._inflight[sid] += 1
                            batch.append((sid, t))
                    if batch:
                        break
                    self._cv.wait()
                if not batch:
                    return  # stopping
            for sid, t in batch:
                self._submit_async(sid, t)

    def _submit_async(self, sid: int, t: _Tracked) -> None:
        sink = self.sinks[sid]
        t0 = time.monotonic()
        fired = [False]

        def done(result: Any = None, was_cached: bool = False,
                 error: BaseException | None = None) -> None:
            with self._cv:
                if fired[0]:
                    return  # a buggy sink calling done twice must not corrupt counts
                fired[0] = True
                self._inflight[sid] -= 1
            if error is not None:
                self._finish(t, sid, error=error)
            else:
                self._finish(
                    t, sid, result=result, was_cached=bool(was_cached),
                    elapsed=time.monotonic() - t0,
                )

        try:
            sink.submit(t.item.unit, done)
        except BaseException as e:  # noqa: BLE001 - reported per unit
            done(error=e)

    def _finish(
        self,
        t: _Tracked,
        sid: int,
        result: Any = None,
        was_cached: bool = False,
        error: BaseException | None = None,
        elapsed: float | None = None,
    ) -> None:
        with self._cv:
            t.live -= 1
            if t.done:
                # The losing attempt of a speculated unit: its result was
                # already deduped through the shared cache identity; drop it.
                self._cv.notify_all()
                return
            if error is not None:
                t.outcome.error = error
                if t.live > 0 or t.waves:
                    return  # another attempt may still win this unit
                untried = tuple(
                    s
                    for s in self._eligible_locked(t)
                    if s not in t.tried and s not in self._dead
                )
                if untried:
                    # An error is only terminal once every eligible sink has
                    # had a go: a crashed fleet worker fast-fails its claims,
                    # and without this hand-off it would out-claim the
                    # healthy sinks and drain the queue into errors.
                    self._push_wave_locked(t, untried)
                    return
            else:
                t.outcome.result = result
                t.outcome.was_cached = was_cached
                t.outcome.error = None
                t.outcome.sink = self.sinks[sid].name
                t.outcome.elapsed_s = elapsed
                if elapsed is not None and not was_cached and t.item.cost > 0:
                    # Cache hits return in microseconds and would collapse
                    # the seconds-per-cost scale, flagging every genuinely
                    # executing unit as a straggler on warm-cache runs.
                    self._scale_samples.append(elapsed / t.item.cost)
            t.outcome.attempts = t.claims
            t.outcome.speculated = t.speculated
            t.done = True
            # Retire still-open waves: a speculative ticket for a unit that
            # just completed must never be claimed.
            self._open_tickets -= len(t.waves)
            t.waves.clear()
            self._done_count += 1
            if t.outcome.error is not None and self.fail_fast:
                self._stop = True
            self._cv.notify_all()

    # -- straggler monitor ---------------------------------------------------
    def _scale_locked(self) -> float | None:
        """Median observed seconds-per-cost over completed attempts."""
        if not self._scale_samples:
            return None
        s = sorted(self._scale_samples)
        return s[len(s) // 2]

    def _maybe_speculate_locked(self) -> None:
        if self._open_tickets:
            return  # work still queued: no sink is starving yet
        scale = self._scale_locked()
        if scale is None:
            # Nothing has completed: there is no basis to call anything a
            # straggler, and speculating against an arbitrary scale would
            # double-run legitimately long units on a cold cache.
            return
        now = time.monotonic()
        for t in self._tracked:
            if t.done or t.live != 1 or t.speculated or t.waves:
                continue
            threshold = max(
                self.min_straggler_s,
                self.straggler_factor * max(t.item.cost, 0.0) * scale,
            )
            if now - t.started <= threshold:
                continue
            # Re-dispatch to the other eligible sinks (they are idle: the
            # queue is empty).  A single-sink unit retries on another slot /
            # connection of the same sink — that still beats a wedged one.
            eligible = tuple(
                s for s in self._eligible_locked(t) if s not in self._dead
            )
            if not eligible:
                continue  # fleet collapsed to dead sinks; nothing to try
            others = tuple(s for s in eligible if s != t.running_on) or eligible
            t.speculated = True
            self._push_wave_locked(t, others)

    # -- elastic membership --------------------------------------------------
    def _resolve_sid(self, sink: "int | str") -> int:
        if isinstance(sink, int):
            if not 0 <= sink < len(self.sinks):
                raise ValueError(f"unknown sink id {sink}")
            return sink
        match = None
        for sid, s in enumerate(self.sinks):
            if s.name == sink:
                match = sid
                if sid not in self._dead:
                    return sid  # prefer the live holder of a reused name
        if match is None:
            raise ValueError(f"unknown sink {sink!r}")
        return match

    def _spawn_pullers(self, sid: int) -> None:
        """Start the sink's driving threads: ``capacity`` pullers for a
        threaded sink, or (once, shared by all async sinks) the single
        dispatcher thread."""
        sink = self.sinks[sid]
        if sink.submit is not None:
            if not self._dispatcher_started:
                self._dispatcher_started = True
                th = threading.Thread(
                    target=self._dispatcher, daemon=True, name="sink-dispatcher"
                )
                th.start()
                self._threads.append(th)
                self.threads_started += 1
            return
        for slot in range(sink.capacity):
            th = threading.Thread(
                target=self._puller, args=(sid,), daemon=True,
                name=f"sink-{sink.name}-{slot}",
            )
            th.start()
            self._threads.append(th)
            self.threads_started += 1

    def add_sink(self, sink: Sink) -> int:
        """Grow the fleet mid-run (a worker registered): dynamic units'
        open waves become claimable by the new sink immediately; pinned
        units are unaffected.  Returns the new sink id."""
        if sink.capacity < 1:
            raise ValueError(f"sink {sink.name!r} capacity must be >= 1, got {sink.capacity}")
        with self._cv:
            sid = len(self.sinks)
            self.sinks.append(sink)
            self._heaps.append([])
            self._inflight.append(0)
            for t in self._tracked:
                if t.done or not t.dynamic:
                    continue
                for wave in t.waves:
                    self._seq += 1
                    heapq.heappush(
                        self._heaps[sid], (-max(t.item.cost, 0.0), self._seq, wave, t)
                    )
            running = self._running
            self._cv.notify_all()
        if running:
            self._spawn_pullers(sid)
        return sid

    def mark_dead(self, sink: "int | str") -> list[Any]:
        """Shrink the fleet: the sink stops claiming, its queued tickets are
        re-homed to live sinks, and its IN-FLIGHT units are re-enqueued
        elsewhere right away (``Outcome.redispatched``) instead of waiting
        for the doomed attempt's transport deadline.  The first completion
        still wins through ``t.done``, so a late reply from a merely-slow
        "dead" worker dedupes exactly like a lost speculation race.
        Returns the units that were re-dispatched.
        """
        redispatched: list[Any] = []
        with self._cv:
            sid = self._resolve_sid(sink)
            if sid in self._dead:
                return []
            self._dead.add(sid)
            for t in self._tracked:
                if t.done:
                    continue
                targets = tuple(
                    s for s in self._eligible_locked(t) if s not in self._dead
                )
                if t.waves:
                    # Re-home queued work: retire every open wave (some may
                    # exist ONLY in the dead heap) and open one fresh wave
                    # across the surviving sinks.
                    self._open_tickets -= len(t.waves)
                    t.waves.clear()
                    if targets:
                        self._push_wave_locked(t, targets)
                    elif t.live == 0:
                        # Pinned to sinks that are all dead, nothing running:
                        # no path to completion — terminal error, not a hang.
                        t.outcome.error = RuntimeError(
                            f"sink {self.sinks[sid].name!r} died and no live "
                            "sink is eligible"
                        )
                        t.outcome.attempts = t.claims
                        t.done = True
                        self._done_count += 1
                        if self.fail_fast:
                            self._stop = True
                    continue
                if t.live > 0 and t.running_on == sid and targets:
                    t.outcome.redispatched = True
                    redispatched.append(t.item.unit)
                    self._push_wave_locked(t, targets)
            self._cv.notify_all()
        # Prune threads that have already exited (this dead sink's pullers
        # unblock on the notify above and die; EARLIER dead sinks' threads
        # are certainly done) instead of accumulating every thread ever
        # started for the life of the sweep.  is_alive() is non-blocking,
        # so a long-lived elastic run stays O(live sinks), not O(churn).
        self._threads = [th for th in self._threads if th.is_alive()]
        return redispatched

    def live_sinks(self) -> list[str]:
        with self._cv:
            return [s.name for sid, s in enumerate(self.sinks) if sid not in self._dead]

    def close(self, timeout_s: float = 2.0) -> None:
        """Stop claiming and join worker threads within a TOTAL bound.

        Threads stuck inside a sink's ``run`` (a wedged remote attempt)
        stay behind as daemons — their late results are discarded by
        ``t.done`` — so shutdown cost is bounded by ``timeout_s`` however
        large the fleet got, not by thread count x per-thread timeout.
        """
        with self._cv:
            self._stop = True
            self._running = False
            self._cv.notify_all()
        deadline = time.monotonic() + max(0.0, timeout_s)
        for th in self._threads:
            th.join(timeout=max(0.0, deadline - time.monotonic()))
        self._threads = [th for th in self._threads if th.is_alive()]

    # -- entry point ---------------------------------------------------------
    def run(self, items: Sequence[WorkItem]) -> list[Outcome]:
        """Execute every item; returns outcomes in input order.

        Returns when all items completed (or, under ``fail_fast``, as soon
        as one unit finally errors — unstarted items then carry neither
        result nor error).  Attempts still executing at return are
        abandoned on daemon threads; their late results are discarded.
        """
        with self._cv:
            initial = len(self.sinks)
            live = tuple(s for s in range(initial) if s not in self._dead)
            self._tracked = []
            for item in items:
                if item.sinks is not None:
                    eligible = tuple(item.sinks)
                    if not eligible:
                        raise ValueError(f"work item {item.unit!r} has no eligible sink")
                    for sid in eligible:
                        if not 0 <= sid < initial:
                            raise ValueError(
                                f"work item {item.unit!r} names unknown sink {sid}"
                            )
                    self._tracked.append(_Tracked(item, eligible))
                else:
                    if not live:
                        raise ValueError(f"work item {item.unit!r} has no eligible sink")
                    self._tracked.append(_Tracked(item, live, dynamic=True))
            for t in self._tracked:
                self._push_wave_locked(t, self._eligible_locked(t))
            self._running = True
        for sid in range(initial):
            if sid not in self._dead:
                self._spawn_pullers(sid)
        try:
            with self._cv:
                while self._done_count < len(self._tracked) and not self._stop:
                    self._cv.wait(timeout=self.poll_s)
                    self._maybe_speculate_locked()
        finally:
            self.close()
        return [t.outcome for t in self._tracked]


__all__ = [
    "FleetScheduler",
    "Sink",
    "WorkItem",
    "Outcome",
    "DEFAULT_STRAGGLER_FACTOR",
    "DEFAULT_MIN_STRAGGLER_S",
]
