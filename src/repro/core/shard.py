"""Sweep sharding: partition a box's expanded units across runner processes.

A *shard* is one slice of a box's (platform x task x params) grid, meant to
run in its own process or on its own host; the union of all shards is the
full sweep (ROADMAP "sweep sharding across machines").  Assignment is a
consistent hash over each unit's cache key — the same identity the result
cache uses — which buys three properties:

  * **Deterministic** — every runner computes the same partition from the
    box alone; no coordinator is needed.
  * **Disjoint cover** — each unit lands on exactly one shard, so merged
    shard reports contain every row exactly once.
  * **Resize stability** — assignment is rendezvous (highest-random-weight)
    hashing, so growing n shards to n+1 moves only the keys won by the new
    shard (~1/(n+1) of them); all movers go TO the new shard.  A mostly-warm
    result cache therefore stays mostly-warm when a host is added.

``SweepExecutor.run_box(box, shard=ShardSpec(i, n))`` executes only the i-th
slice; :func:`repro.core.report.merge_shard_reports` reassembles the rows in
canonical (unsharded) order.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterable, Sequence


@dataclass(frozen=True)
class ShardSpec:
    """This runner executes shard ``index`` of ``count`` total shards."""

    index: int
    count: int

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError(f"shard count must be >= 1, got {self.count}")
        if not 0 <= self.index < self.count:
            raise ValueError(
                f"shard index must be in [0, {self.count}), got {self.index}"
            )

    @staticmethod
    def parse(text: str) -> "ShardSpec":
        """Parse the CLI form ``"i/n"`` (e.g. ``--shard 0/2``)."""
        try:
            idx, _, cnt = text.partition("/")
            return ShardSpec(int(idx), int(cnt))
        except ValueError as e:
            raise ValueError(f"bad shard spec {text!r}; expected 'i/n' like '0/2'") from e

    def __str__(self) -> str:
        return f"{self.index}/{self.count}"

    def owns(self, key: str) -> bool:
        return shard_of(key, self.count) == self.index


def _weight(key: str, shard: int) -> int:
    """Rendezvous weight of (key, shard); 64 bits of a keyed blake2b."""
    h = hashlib.blake2b(f"{key}|{shard}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def shard_of(key: str, count: int) -> int:
    """Highest-random-weight shard for ``key`` among ``count`` shards.

    Each key independently picks the shard whose (key, shard) hash is
    largest.  Going count -> count+1 only reassigns keys whose new weight
    beats their old maximum, i.e. an expected 1/(count+1) fraction — the
    common "add a host" resize keeps >= count/(count+1) of keys in place.
    """
    if count < 1:
        raise ValueError(f"shard count must be >= 1, got {count}")
    if count == 1:
        return 0
    best, best_w = 0, -1
    for i in range(count):
        w = _weight(key, i)
        if w > best_w:
            best, best_w = i, w
    return best


def partition(keys: Iterable[str], count: int) -> list[list[str]]:
    """Split ``keys`` into ``count`` buckets; bucket i is shard i's work."""
    out: list[list[str]] = [[] for _ in range(count)]
    for k in keys:
        out[shard_of(k, count)].append(k)
    return out


def assigned(keys: Sequence[str], spec: ShardSpec) -> list[str]:
    """The subsequence of ``keys`` owned by ``spec`` (original order kept)."""
    return [k for k in keys if spec.owns(k)]


__all__ = ["ShardSpec", "shard_of", "partition", "assigned"]
