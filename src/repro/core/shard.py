"""Sweep sharding: partition a box's expanded units across runner processes.

A *shard* is one slice of a box's (platform x task x params) grid, meant to
run in its own process or on its own host; the union of all shards is the
full sweep (ROADMAP "sweep sharding across machines").  Assignment is a
consistent hash over each unit's cache key — the same identity the result
cache uses — which buys three properties:

  * **Deterministic** — every runner computes the same partition from the
    box alone; no coordinator is needed.
  * **Disjoint cover** — each unit lands on exactly one shard, so merged
    shard reports contain every row exactly once.
  * **Resize stability** — assignment is rendezvous (highest-random-weight)
    hashing, so growing n shards to n+1 moves only the keys won by the new
    shard (~1/(n+1) of them); all movers go TO the new shard.  A mostly-warm
    result cache therefore stays mostly-warm when a host is added.

``SweepExecutor.run_box(box, shard=ShardSpec(i, n))`` executes only the i-th
slice; :func:`repro.core.report.merge_shard_reports` reassembles the rows in
canonical (unsharded) order.

Heterogeneous fleets additionally get **weighted, cost-aware** partitions:

  * Each shard may carry a capacity ``weight`` (``--shard 0/2@0.25`` — a
    DPU-side shard that should take a quarter of the work;
    ``--shard 1/4@0.1:0.3:0.3:0.3`` spells out the whole vector).  Weighted
    rendezvous (:func:`shard_of` with ``weights``) skews expected ownership
    proportionally while keeping the movers-only-to-new-shard resize law.
  * With per-key cost estimates (:class:`repro.core.cost.CostModel`, fed by
    wall times the result cache records), :func:`cost_shard_map` balances
    *estimated cost* rather than key count: keys are placed heaviest-first
    onto their rendezvous-preferred shard while it has capacity headroom,
    overflowing onto the least-loaded (weight-normalized) shard.  The
    result is still a deterministic disjoint cover — any runner with the
    same cost evidence computes the same partition — at the price of full
    hash stability for overflowed keys (documented trade: balance beats
    stickiness exactly when costs are skewed enough to matter).
  * **Auto-calibrated weights** (``--shard i/n@auto``): instead of operator
    guesses, the weight vector is resolved by :func:`resolve_auto_weights`
    from fleet evidence — each worker's ping-advertised concurrency
    capacity and measured per-unit EWMA wall time, with local
    :class:`~repro.core.cost.CostModel` evidence standing in for workers
    that have not measured anything yet.  Resolved shares are snapped to a
    coarse lattice so two runners resolving against the same (quiescent)
    fleet moments apart still agree on the exact same vector, hence the
    same partition.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Any, Iterable, Mapping, Sequence

#: Sentinel accepted wherever a weight vector is: resolve from fleet
#: evidence (worker pings + local cost model) instead of operator guesses.
AUTO_WEIGHTS = "auto"


def resolve_auto_weights(
    count: int,
    evidence: Sequence[Mapping[str, Any] | None] | None = None,
    default_unit_s: float | None = None,
    grid: int = 64,
) -> tuple[float, ...]:
    """Concrete per-shard capacity weights from fleet evidence.

    ``evidence[i]`` describes shard i's home worker: ``capacity`` (units it
    executes concurrently, from its ping) and ``ewma_s`` (its measured
    per-unit wall-time EWMA, also ping-advertised).  A shard's relative
    speed is ``capacity / ewma_s``; workers with no measurements yet fall
    back to ``default_unit_s`` (typically the local CostModel's mean unit
    time) so a fresh worker is sized by capacity alone.  Missing evidence
    entries count as one capacity unit at the default speed.

    Shares are snapped onto a ``1/grid`` lattice (at least one cell each):
    every runner of a sharded sweep resolves this vector independently, and
    quantization absorbs the EWMA jitter between their resolutions so they
    still compute identical partitions.  Resolve against a quiescent fleet
    — a worker measuring units *between* two runners' resolutions can still
    move its share across a lattice boundary.
    """
    if count < 1:
        raise ValueError(f"shard count must be >= 1, got {count}")
    if grid < count:
        raise ValueError(f"grid must be >= shard count, got {grid} < {count}")
    if count == 1:
        return (1.0,)
    ev = list(evidence or [])
    speeds: list[float] = []
    for i in range(count):
        e = ev[i] if i < len(ev) and ev[i] else {}
        try:
            cap = float(e.get("capacity") or 1.0)
        except (TypeError, ValueError):
            cap = 1.0
        try:
            unit_s = float(e.get("ewma_s") or default_unit_s or 1.0)
        except (TypeError, ValueError):
            unit_s = 1.0
        speeds.append(max(cap, 1e-9) / max(unit_s, 1e-9))
    total = sum(speeds)
    if total <= 0 or not math.isfinite(total):
        return (1.0 / count,) * count
    cells = [max(1, round(s / total * grid)) for s in speeds]
    csum = sum(cells)
    return tuple(c / csum for c in cells)


def _parse_weights(text: str, index: int, count: int) -> tuple[float, ...]:
    """Weight suffix of a CLI shard spec -> full per-shard weight vector.

    Two forms: ``w0:w1:...`` spells out all ``count`` weights; a single
    ``w`` is shorthand for "this shard takes fraction w of the work", with
    the remaining ``1 - w`` split evenly over the other shards — so two
    runners launched as ``0/2@0.25`` and ``1/2@0.75`` reconstruct the SAME
    vector (0.25, 0.75) and agree on the partition.
    """
    parts = [p for p in text.split(":") if p]
    vals = [float(p) for p in parts]
    if len(vals) == 1 and count > 1:
        w = vals[0]
        if not 0.0 < w < 1.0:
            raise ValueError(
                f"single-weight shorthand needs 0 < w < 1 (fraction of total), got {w}"
            )
        rest = (1.0 - w) / (count - 1)
        return tuple(w if i == index else rest for i in range(count))
    if len(vals) != count:
        raise ValueError(
            f"weight vector has {len(vals)} entries for {count} shards"
        )
    return tuple(vals)


@dataclass(frozen=True)
class ShardSpec:
    """This runner executes shard ``index`` of ``count`` total shards.

    ``weights`` (optional, len == count) are relative capacity weights for
    ALL shards — every runner needs the full vector to compute the same
    partition.  ``None`` means uniform.  The string ``"auto"``
    (:data:`AUTO_WEIGHTS`, CLI ``i/n@auto``) defers to fleet calibration:
    the executor resolves it into a concrete vector via
    :func:`resolve_auto_weights` before any hashing happens.
    """

    index: int
    count: int
    weights: tuple[float, ...] | str | None = None

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError(f"shard count must be >= 1, got {self.count}")
        if not 0 <= self.index < self.count:
            raise ValueError(
                f"shard index must be in [0, {self.count}), got {self.index}"
            )
        if isinstance(self.weights, str):
            if self.weights != AUTO_WEIGHTS:
                raise ValueError(
                    f"weights must be a vector, None, or {AUTO_WEIGHTS!r}; "
                    f"got {self.weights!r}"
                )
        elif self.weights is not None:
            object.__setattr__(self, "weights", tuple(float(w) for w in self.weights))
            check_weights(self.weights, self.count)

    @staticmethod
    def parse(text: str) -> "ShardSpec":
        """Parse ``"i/n"``, ``"i/n@w"``, ``"i/n@w0:w1:..."`` or ``"i/n@auto"``.

        ``0/2`` — uniform; ``0/2@0.25`` — this shard gets 25% of the work
        (the rest split evenly); ``2/3@0.5:0.25:0.25`` — explicit vector;
        ``0/2@auto`` — weights calibrated from fleet pings + cost evidence.
        """
        spec, sep, wtext = text.partition("@")
        try:
            if sep and not wtext:
                raise ValueError("empty weight suffix after '@'")
            idx_s, _, cnt_s = spec.partition("/")
            idx, cnt = int(idx_s), int(cnt_s)
            if wtext == AUTO_WEIGHTS:
                weights: tuple[float, ...] | str | None = AUTO_WEIGHTS
            else:
                weights = _parse_weights(wtext, idx, cnt) if wtext else None
            return ShardSpec(idx, cnt, weights)
        except ValueError as e:
            raise ValueError(
                f"bad shard spec {text!r}; expected 'i/n', 'i/n@w', "
                f"'i/n@w0:w1:...' or 'i/n@auto' like '0/2@0.25': {e}"
            ) from e

    def __str__(self) -> str:
        base = f"{self.index}/{self.count}"
        if self.weights is None:
            return base
        if isinstance(self.weights, str):
            return base + "@" + self.weights
        return base + "@" + ":".join(f"{w:g}" for w in self.weights)

    @property
    def is_auto(self) -> bool:
        """Weights deferred to fleet calibration (``@auto``), unresolved."""
        return self.weights == AUTO_WEIGHTS

    def resolved(self, weights: Sequence[float]) -> "ShardSpec":
        """A concrete copy of this spec carrying the resolved vector."""
        return ShardSpec(self.index, self.count, tuple(float(w) for w in weights))

    @property
    def weight(self) -> float:
        """This shard's own capacity weight (1.0 when uniform)."""
        if isinstance(self.weights, str):
            raise ValueError(
                "auto weights are unresolved; resolve with resolve_auto_weights "
                "(the executor does this from fleet pings) before reading weight"
            )
        return 1.0 if self.weights is None else self.weights[self.index]

    def owns(self, key: str) -> bool:
        """Does the (weighted) rendezvous hash assign ``key`` to this shard?

        This answers the hash-preference question only.  Cost-aware
        execution (weighted specs / ``weighted_shard``) may overflow a key
        off its preferred shard to respect the load bound — the executor's
        partition is :func:`cost_shard_map` over the WHOLE key set, which a
        single-key predicate cannot reproduce.
        """
        return shard_of(key, self.count, self.weights) == self.index


def check_weights(weights: Sequence[float], count: int) -> None:
    if isinstance(weights, str):
        raise ValueError(
            f"{weights!r} weights are unresolved; resolve them with "
            "resolve_auto_weights(...) before hashing"
        )
    if len(weights) != count:
        raise ValueError(f"need {count} shard weights, got {len(weights)}")
    for w in weights:
        if not math.isfinite(w) or w <= 0.0:
            raise ValueError(f"shard weights must be finite and > 0, got {w}")


def _weight(key: str, shard: int) -> int:
    """Rendezvous weight of (key, shard); 64 bits of a keyed blake2b."""
    h = hashlib.blake2b(f"{key}|{shard}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def _score(key: str, shard: int, w: float) -> float:
    """Weighted rendezvous score: -w / ln(u), u = hash mapped into (0, 1).

    For equal w this is a strictly monotone transform of the raw 64-bit
    hash, so the weighted argmax coincides with the classic unweighted one.
    """
    u = (_weight(key, shard) + 1) / (2.0**64 + 2)
    return -w / math.log(u)


def shard_of(key: str, count: int, weights: Sequence[float] | None = None) -> int:
    """Highest-random-weight shard for ``key`` among ``count`` shards.

    Each key independently picks the shard whose (key, shard) hash is
    largest; with ``weights`` each shard's score is capacity-scaled
    (``-w/ln(u)``), so expected ownership is proportional to weight.
    Either way, going count -> count+1 (or appending a shard to the weight
    vector) only reassigns keys whose NEW shard's score beats their old
    maximum — movers only ever go to the added shard.
    """
    if count < 1:
        raise ValueError(f"shard count must be >= 1, got {count}")
    if weights is not None:
        check_weights(weights, count)
    if count == 1:
        return 0
    if weights is None or len(set(weights)) == 1:
        # Uniform: exact integer argmax (the original, float-free path).
        best, best_w = 0, -1
        for i in range(count):
            w = _weight(key, i)
            if w > best_w:
                best, best_w = i, w
        return best
    best, best_s = 0, float("-inf")
    for i in range(count):
        s = _score(key, i, weights[i])
        if s > best_s:
            best, best_s = i, s
    return best


def rank_shards(key: str, count: int, weights: Sequence[float] | None = None) -> list[int]:
    """Shards ordered by this key's (weighted) rendezvous preference."""
    if weights is None:
        return sorted(range(count), key=lambda i: -_weight(key, i))
    check_weights(weights, count)
    return sorted(range(count), key=lambda i: -_score(key, i, weights[i]))


def partition(
    keys: Iterable[str], count: int, weights: Sequence[float] | None = None
) -> list[list[str]]:
    """Split ``keys`` into ``count`` buckets; bucket i is shard i's work."""
    out: list[list[str]] = [[] for _ in range(count)]
    for k in keys:
        out[shard_of(k, count, weights)].append(k)
    return out


def assigned(keys: Sequence[str], spec: ShardSpec) -> list[str]:
    """The subsequence of ``keys`` owned by ``spec`` (original order kept).

    Pure rendezvous view — see :meth:`ShardSpec.owns` for how cost-aware
    execution can differ; use :func:`cost_partition` to mirror it.
    """
    return [k for k in keys if spec.owns(k)]


# -- cost-aware weighted partition -------------------------------------------
def cost_shard_map(
    keys: Sequence[str],
    count: int,
    weights: Sequence[float] | str | None = None,
    costs: Mapping[str, float] | None = None,
    slack: float = 1.5,
    evidence: Sequence[Mapping[str, Any] | None] | None = None,
) -> dict[str, int]:
    """Deterministic cost-balanced assignment: unique key -> shard index.

    Keys are placed heaviest-first (ties broken by key, so any runner with
    the same cost evidence computes the same map).  Each key goes to its
    weighted-rendezvous home shard while that shard's load stays within
    ``slack`` x its weight-proportional fair share of total cost; otherwise
    it overflows onto the shard with the least projected weight-normalized
    load (preferring the key's own rendezvous ranking on ties).  Duplicate
    keys in the input (overlapping task specs) count once per occurrence
    toward load and share one assignment.

    ``weights=AUTO_WEIGHTS`` resolves the vector from ``evidence`` (per-
    shard worker capacity/EWMA dicts) via :func:`resolve_auto_weights`
    first; with no evidence the resolution is uniform.

    Guarantees: disjoint cover; max weight-normalized load <= slack x the
    fair share whenever a placement under the bound exists, degrading to
    least-loaded greedy (classic LPT behaviour) when single keys exceed it.
    """
    if count < 1:
        raise ValueError(f"shard count must be >= 1, got {count}")
    if isinstance(weights, str):
        if weights != AUTO_WEIGHTS:
            raise ValueError(f"weights must be a vector, None, or {AUTO_WEIGHTS!r}")
        weights = resolve_auto_weights(count, evidence)
    if weights is not None:
        check_weights(weights, count)
    if slack < 1.0:
        raise ValueError(f"slack must be >= 1.0, got {slack}")
    w = tuple(float(x) for x in (weights or (1.0,) * count))
    costs = costs or {}
    # Effective cost per unique key = unit cost x multiplicity.
    mult: dict[str, int] = {}
    for k in keys:
        mult[k] = mult.get(k, 0) + 1
    eff = {k: max(float(costs.get(k, 1.0)), 0.0) * m for k, m in mult.items()}
    total = sum(eff.values())
    if count == 1 or not eff:
        return {k: 0 for k in mult}
    wsum = sum(w)
    fair = [total * wi / wsum for wi in w]
    loads = [0.0] * count
    owner: dict[str, int] = {}
    for k in sorted(eff, key=lambda k: (-eff[k], k)):
        prefs = rank_shards(k, count, weights)
        home = prefs[0]
        if loads[home] + eff[k] <= slack * fair[home]:
            pick = home
        else:
            rank_pos = {s: r for r, s in enumerate(prefs)}
            pick = min(
                range(count),
                key=lambda i: ((loads[i] + eff[k]) / w[i], rank_pos[i]),
            )
        loads[pick] += eff[k]
        owner[k] = pick
    return owner


def cost_partition(
    keys: Sequence[str],
    count: int,
    weights: Sequence[float] | str | None = None,
    costs: Mapping[str, float] | None = None,
    slack: float = 1.5,
    evidence: Sequence[Mapping[str, Any] | None] | None = None,
) -> list[list[str]]:
    """Cost-balanced counterpart of :func:`partition` (input order kept,
    duplicates preserved in their owner's bucket)."""
    owner = cost_shard_map(keys, count, weights, costs, slack, evidence)
    out: list[list[str]] = [[] for _ in range(count)]
    for k in keys:
        out[owner[k]].append(k)
    return out


__all__ = [
    "AUTO_WEIGHTS",
    "ShardSpec",
    "shard_of",
    "rank_shards",
    "partition",
    "assigned",
    "cost_shard_map",
    "cost_partition",
    "check_weights",
    "resolve_auto_weights",
]
