"""dpBento task abstraction.

A *task* is a parameterized performance test with a four-phase lifecycle:

    prepare -> run (once per generated test) -> report -> clean

`prepare` sets up state shared by every test of the task (compile jitted
functions, generate datasets). `run` executes one concrete test — one point
of the parameter cross-product — and returns raw `Samples`. `report` turns
accumulated results into report rows. `clean` removes all prepared state.

Tasks declare a `param_space` (name -> allowed/default values) so boxes can
be validated before anything executes, and `default_metrics`.
"""
from __future__ import annotations

import abc
import hashlib
import inspect
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

from repro.core.metrics import Samples, compute_metrics


@dataclass
class TaskContext:
    """Shared state handed to every phase.

    `platform` describes the execution target (name + capability flags);
    `scratch` is the task's private prepared state; `log` accumulates
    intermediate per-test records (the paper's cached logs).
    """

    platform: dict[str, Any] = field(default_factory=dict)
    scratch: dict[str, Any] = field(default_factory=dict)
    log: list[dict[str, Any]] = field(default_factory=list)
    iters: int = 5
    warmup: int = 2
    # Minimum measured wall time per test: tasks keep iterating past `iters`
    # until this much time accumulates (core.timing.measure's min_time_s),
    # so microsecond-scale points aren't noise-dominated by 5 samples.
    min_time_s: float = 0.0


@dataclass
class TestResult:
    task: str
    params: dict[str, Any]
    metrics: dict[str, float]
    # Name of the execution platform that measured this test; the legacy
    # single-platform path leaves the default.
    platform: str = "default"


class Task(abc.ABC):
    """Base class for built-in and plugin tasks."""

    #: unique registry name
    name: str = ""
    #: parameter name -> list of default values (cross-product expanded)
    param_space: dict[str, list[Any]] = {}
    #: metrics computed when a box does not name any
    default_metrics: tuple[str, ...] = ("avg_latency_us",)

    # -- lifecycle ---------------------------------------------------------
    def prepare(self, ctx: TaskContext) -> None:  # pragma: no cover - default
        pass

    @abc.abstractmethod
    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        ...

    def report(self, ctx: TaskContext, results: list[TestResult]) -> list[dict[str, Any]]:
        rows = []
        for r in results:
            row: dict[str, Any] = {"task": r.task}
            row.update({f"param:{k}": v for k, v in r.params.items()})
            row.update(r.metrics)
            rows.append(row)
        return rows

    def clean(self, ctx: TaskContext) -> None:  # pragma: no cover - default
        ctx.scratch.clear()

    # -- helpers -----------------------------------------------------------
    def source_fingerprint(self) -> str:
        """Content hash of the task's implementation source.

        Part of the result-cache key: cached metrics are only trustworthy
        while the code that measured them is unchanged, so editing a task
        module must miss the cache.  Hashes the defining module's file when
        it exists on disk (covers helpers the task calls in the same
        module), else the class source; unknowable sources hash to "" and
        rely on the rest of the key.
        """
        mod = sys.modules.get(type(self).__module__)
        path = getattr(mod, "__file__", None)
        try:
            if path and Path(path).is_file():
                blob = Path(path).read_bytes()
            else:
                blob = inspect.getsource(type(self)).encode()
        except (OSError, TypeError):
            return ""
        return hashlib.sha256(blob).hexdigest()[:16]

    def validate_params(self, params: dict[str, Any]) -> None:
        unknown = set(params) - set(self.param_space)
        if unknown:
            raise ValueError(f"task {self.name!r}: unknown params {sorted(unknown)}")

    def execute_test(
        self, ctx: TaskContext, params: dict[str, Any], metrics: tuple[str, ...]
    ) -> TestResult:
        samples = self.run(ctx, params)
        vals = compute_metrics(samples, metrics or self.default_metrics)
        ctx.log.append({"task": self.name, "params": dict(params), "metrics": dict(vals)})
        return TestResult(self.name, dict(params), vals)
