"""Wall-clock measurement harness for jitted callables.

Blocks on all output leaves; runs warmup iterations first so compile time
never pollutes samples (dpBento's `prepare` phase compiles, `run` measures).
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax


def block(tree: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def measure(
    fn: Callable[..., Any],
    *args: Any,
    iters: int = 5,
    warmup: int = 2,
    min_time_s: float = 0.0,
) -> list[float]:
    """Return per-iteration wall times in seconds (post-warmup)."""
    for _ in range(warmup):
        block(fn(*args))
    times: list[float] = []
    total = 0.0
    i = 0
    while i < iters or total < min_time_s:
        t0 = time.perf_counter()
        block(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        i += 1
        if i > 10000:  # safety valve
            break
    return times
