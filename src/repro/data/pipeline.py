"""Deterministic synthetic LM data pipeline, sharded per host.

Batches derive purely from (seed, step): restart/resume needs no data-state
checkpoint beyond the step counter, and every host generates exactly its own
shard (process_index-sliced) — the multi-host analogue of a sharded file
reader without the filesystem dependency. Targets are a fixed bigram-ish
function of the inputs so loss decreases measurably during the e2e train
examples (pure-noise labels would hide optimizer bugs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: label = (a*token + b) % mod — a deterministic
    # per-token map onto `mod` classes. mod << vocab keeps the target
    # low-rank (a full-vocab permutation is unlearnable through a small
    # d_model embedding bottleneck), so loss decreases measurably fast.
    struct_a: int = 31
    struct_b: int = 7
    struct_mod: int = 64


class SyntheticLM:
    """Stateless-per-step token stream. `batch_at(step)` is pure."""

    def __init__(self, cfg: DataConfig, d_model: int = 0, embed_inputs: bool = True,
                 encoder_decoder: bool = False, mrope: bool = False):
        self.cfg = cfg
        self.d_model = d_model
        self.embed_inputs = embed_inputs
        self.encoder_decoder = encoder_decoder
        self.mrope = mrope
        n_proc = jax.process_count()
        assert cfg.global_batch % n_proc == 0, (cfg.global_batch, n_proc)
        self.host_batch = cfg.global_batch // n_proc

    def _key(self, step: int) -> jax.Array:
        k = jax.random.PRNGKey(self.cfg.seed)
        k = jax.random.fold_in(k, step)
        return jax.random.fold_in(k, jax.process_index())

    def batch_at(self, step: int) -> dict[str, jax.Array]:
        cfg = self.cfg
        b, s, v = self.host_batch, cfg.seq_len, cfg.vocab_size
        key = self._key(step)
        tokens = jax.random.randint(key, (b, s), 0, v, jnp.int32)
        labels = (cfg.struct_a * tokens + cfg.struct_b) % min(cfg.struct_mod, v)
        if self.encoder_decoder:
            kf = jax.random.fold_in(key, 1)
            frames = jax.random.normal(kf, (b, s, self.d_model), jnp.float32) * 0.02
            return {"frames": frames, "tgt_tokens": tokens, "labels": labels}
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if self.mrope:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
        if not self.embed_inputs:
            ke = jax.random.fold_in(key, 2)
            inputs: jax.Array = jax.random.normal(ke, (b, s, self.d_model), jnp.float32) * 0.02
        else:
            inputs = tokens
        return {"inputs": inputs, "labels": labels, "positions": positions}

    def iterate(self, start_step: int = 0) -> Iterator[dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def for_model(cfg_arch, seq_len: int, global_batch: int, seed: int = 0) -> SyntheticLM:
    """Pipeline matching an ArchConfig's input contract."""
    return SyntheticLM(
        DataConfig(vocab_size=cfg_arch.vocab_size, seq_len=seq_len,
                   global_batch=global_batch, seed=seed),
        d_model=cfg_arch.d_model,
        embed_inputs=cfg_arch.embed_inputs,
        encoder_decoder=cfg_arch.encoder_decoder,
        mrope=cfg_arch.rope == "mrope",
    )
