from repro.engine.table import Table, concat

__all__ = ["Table", "concat"]
