"""Deterministic TPC-H-like synthetic data (lineitem / orders).

Scale factor 1 ~= 6M lineitem rows, matching TPC-H row-count scaling.
Column value distributions follow the TPC-H spec shapes (uniform quantities
1..50, prices around 900..105000 scaled, discount 0..0.10, dates over ~7
years, l_returnflag/linestatus categoricals) so selectivities of the paper's
predicates carry over. Everything derives from a PRNGKey — no files, fully
reproducible, generated directly on device (sharded when run under a mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.table import Table

LINEITEM_ROWS_PER_SF = 6_001_215
ORDERS_ROWS_PER_SF = 1_500_000

# dictionary-encoded categoricals
RETURNFLAG = ("A", "N", "R")
LINESTATUS = ("F", "O")
SHIPMODE = ("AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK")
ORDERPRIORITY = ("1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW")

DATE_EPOCH_DAYS = 8035  # 1992-01-01 in days-since-1970
DATE_RANGE_DAYS = 2526  # through 1998-12-01


def lineitem(
    key: jax.Array,
    scale: float = 0.01,
    rows: int | None = None,
    num_orders: int | None = None,
) -> Table:
    """TPC-H lineitem columns used by Q1/Q6/Q12-pattern queries.

    ``l_orderkey`` is drawn from ``[0, num_orders)`` so that joining against an
    ``orders`` table generated with the matching row count preserves FK
    integrity.  When ``rows`` overrides the scale-derived count, the order
    count follows the spec's ~4:1 lineitem:orders ratio unless given.
    """
    n = rows if rows is not None else max(int(LINEITEM_ROWS_PER_SF * scale), 1024)
    if num_orders is None:
        num_orders = max(n // 4, 256) if rows is not None else max(int(ORDERS_ROWS_PER_SF * scale), 256)
    ks = jax.random.split(key, 10)
    quantity = jax.random.randint(ks[0], (n,), 1, 51).astype(jnp.float32)
    extendedprice = jax.random.uniform(ks[1], (n,), jnp.float32, 900.0, 105000.0)
    discount = jnp.round(jax.random.uniform(ks[2], (n,), jnp.float32, 0.0, 0.10) * 100) / 100
    tax = jnp.round(jax.random.uniform(ks[3], (n,), jnp.float32, 0.0, 0.08) * 100) / 100
    shipdate = jax.random.randint(ks[4], (n,), DATE_EPOCH_DAYS, DATE_EPOCH_DAYS + DATE_RANGE_DAYS)
    commitdate = shipdate + jax.random.randint(ks[5], (n,), -60, 60)
    receiptdate = shipdate + jax.random.randint(ks[6], (n,), 1, 31)
    returnflag = jax.random.randint(ks[7], (n,), 0, len(RETURNFLAG))
    linestatus = (shipdate > DATE_EPOCH_DAYS + 1460).astype(jnp.int32)  # correlated, as in spec
    orderkey = jax.random.randint(ks[8], (n,), 0, num_orders)
    shipmode = jax.random.randint(ks[9], (n,), 0, len(SHIPMODE))
    return Table(
        {
            "l_quantity": quantity,
            "l_extendedprice": extendedprice,
            "l_discount": discount,
            "l_tax": tax,
            "l_shipdate": shipdate.astype(jnp.float32),
            "l_commitdate": commitdate.astype(jnp.float32),
            "l_receiptdate": receiptdate.astype(jnp.float32),
            "l_returnflag": returnflag.astype(jnp.int32),
            "l_linestatus": linestatus,
            "l_orderkey": orderkey.astype(jnp.int32),
            "l_shipmode": shipmode.astype(jnp.int32),
        }
    )


def orders(key: jax.Array, scale: float = 0.01, rows: int | None = None) -> Table:
    n = rows if rows is not None else max(int(ORDERS_ROWS_PER_SF * scale), 256)
    ks = jax.random.split(key, 4)
    orderkey = jnp.arange(n, dtype=jnp.int32)
    custkey = jax.random.randint(ks[0], (n,), 0, max(n // 10, 16))
    totalprice = jax.random.uniform(ks[1], (n,), jnp.float32, 850.0, 560000.0)
    orderdate = jax.random.randint(ks[2], (n,), DATE_EPOCH_DAYS, DATE_EPOCH_DAYS + DATE_RANGE_DAYS)
    priority = jax.random.randint(ks[3], (n,), 0, len(ORDERPRIORITY))
    return Table(
        {
            "o_orderkey": orderkey,
            "o_custkey": custkey.astype(jnp.int32),
            "o_totalprice": totalprice,
            "o_orderdate": orderdate.astype(jnp.float32),
            "o_orderpriority": priority.astype(jnp.int32),
        }
    )


def date(year: int, month: int = 1, day: int = 1) -> float:
    """Approximate days-since-1970 for predicate constants (spec-grade)."""
    return float((year - 1970) * 365.2425 + (month - 1) * 30.44 + (day - 1))
