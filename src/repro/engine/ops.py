"""Relational operators in pure JAX (jit-compiled, shardable).

TPU-idiomatic choices:
  * filters evaluate to masks, and downstream aggregates are mask-weighted —
    compaction (gather of qualifying rows) is available but optional, since
    masked reduction avoids dynamic shapes entirely;
  * group-by is segment_sum over dictionary-coded keys (static cardinality);
  * joins are FK index-joins when the build side is dense-keyed, else
    sort-merge (argsort + searchsorted) — both collective-friendly under
    SPMD row sharding.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.engine.table import Table


# ---------------------------------------------------------------------------
# Predicates -> masks.
def pred_between(col: jax.Array, lo, hi) -> jax.Array:
    return (col >= lo) & (col < hi)


def pred_in(col: jax.Array, values: tuple) -> jax.Array:
    m = jnp.zeros(col.shape, bool)
    for v in values:
        m = m | (col == v)
    return m


def filter_mask(table: Table, *preds: Callable[[Table], jax.Array]) -> jax.Array:
    mask = jnp.ones((table.num_rows,), bool)
    for p in preds:
        mask = mask & p(table)
    return mask


def compact(
    table: Table, mask: jax.Array, max_rows: int, use_pallas: bool = False,
    stream: str = "auto",
) -> tuple[Table, jax.Array]:
    """Gather qualifying rows into a fixed-size buffer (static shapes).

    Rows beyond max_rows are dropped; returns (table, count). This is the
    'return qualified tuples' half of predicate pushdown — the network
    payload is max_rows-bounded rather than data-dependent.

    ``use_pallas=True`` routes through the fused ``block_compact`` kernel
    (one pass: per-block mask count + prefix-offset scatter) instead of
    ``nonzero`` + one gather per column; only 1-D columns whose values are
    exactly representable in f32 survive the kernel's column matrix, so the
    caller selects the scanned columns first (the pushdown plan does).
    ``stream`` passes through to the kernel wrapper: ``"auto"`` keeps small
    capacities on the VMEM-resident kernel and switches to the HBM-streaming
    kernel once the output buffer would blow the VMEM budget, so
    ``max_rows`` is memory-bounded rather than VMEM-bounded.
    """
    if use_pallas:
        from repro.kernels import ops as kops

        names = table.names
        colmat = jnp.stack([table[n].astype(jnp.float32) for n in names])
        packed, cnt = kops.block_compact(colmat, mask, max_rows, stream=stream)
        out = Table(
            {n: packed[i].astype(table[n].dtype) for i, n in enumerate(names)}
        )
        return out, cnt
    idx = jnp.nonzero(mask, size=max_rows, fill_value=table.num_rows)[0]
    in_range = idx < table.num_rows
    safe = jnp.where(in_range, idx, 0)
    out = table.take(safe)
    # zero out the slots past the real count so payloads are deterministic
    out = Table({n: jnp.where(_bmask(in_range, c.ndim), c, 0) for n, c in out.columns.items()})
    return out, jnp.sum(mask.astype(jnp.int32))


def _bmask(m: jax.Array, ndim: int) -> jax.Array:
    return m.reshape(m.shape + (1,) * (ndim - 1))


# ---------------------------------------------------------------------------
# Aggregation.
def masked_sum(col: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.sum(jnp.where(mask, col.astype(jnp.float32), 0.0))


def masked_count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32))


def group_aggregate(
    keys: jax.Array,  # [N] int32 codes in [0, num_groups)
    values: dict[str, jax.Array],  # named value columns
    mask: jax.Array,  # [N] bool
    num_groups: int,
) -> dict[str, jax.Array]:
    """Per-group sums + counts. Returns {name: [num_groups] f32} + "count"."""
    w = mask.astype(jnp.float32)
    out = {
        name: jax.ops.segment_sum(col.astype(jnp.float32) * w, keys, num_segments=num_groups)
        for name, col in values.items()
    }
    out["count"] = jax.ops.segment_sum(w, keys, num_segments=num_groups)
    return out


# ---------------------------------------------------------------------------
# Joins.
def fk_index_join(
    fact: Table, fk_col: str, dim: Table, pk_col: str, carry: tuple[str, ...]
) -> Table:
    """Foreign-key join where dim[pk_col] == arange(len(dim)) (dense keys):
    a pure gather — the fastest join a columnar engine can do."""
    idx = fact[fk_col]
    cols = {n: jnp.take(dim[n], idx, axis=0) for n in carry}
    return fact.with_columns(**cols)


def sort_merge_join(
    left: Table, lkey: str, right: Table, rkey: str, carry: tuple[str, ...]
) -> tuple[Table, jax.Array]:
    """Inner join, right side keys unique. Returns (left + carried right
    columns, match mask). Sort the right side, binary-search each left key."""
    order = jnp.argsort(right[rkey])
    rk_sorted = right[rkey][order]
    pos = jnp.searchsorted(rk_sorted, left[lkey])
    pos = jnp.clip(pos, 0, rk_sorted.shape[0] - 1)
    matched = rk_sorted[pos] == left[lkey]
    cols = {n: jnp.take(right[n][order], pos, axis=0) for n in carry}
    return left.with_columns(**cols), matched


# ---------------------------------------------------------------------------
# Order/top-k.
def top_k(table: Table, col: str, k: int, descending: bool = True) -> Table:
    v = table[col]
    v = v if descending else -v
    _, idx = jax.lax.top_k(v, k)
    return table.take(idx)
