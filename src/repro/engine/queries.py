"""TPC-H-pattern queries over the mini engine (the paper's DBMS workload).

Q1  — scan-heavy group-by aggregate over lineitem;
Q6  — the predicate-pushdown filter+aggregate (also the Pallas filter_agg
      kernel's workload);
Q12 — join lineitem x orders + grouped conditional counts.

Each query is a jit-able Table -> dict[str, Array] function; benchmarks
compare host-style execution vs pushdown-style (see tasks/pushdown.py) and
Pallas-accelerated variants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine import datagen, ops
from repro.engine.table import Table


def q1(lineitem: Table, delta_days: float = 90.0) -> dict[str, jax.Array]:
    """Pricing summary report: 6 (returnflag x linestatus) groups."""
    cutoff = datagen.date(1998, 12, 1) - delta_days
    mask = lineitem["l_shipdate"] <= cutoff
    keys = lineitem["l_returnflag"] * 2 + lineitem["l_linestatus"]  # 6 groups
    disc_price = lineitem["l_extendedprice"] * (1.0 - lineitem["l_discount"])
    charge = disc_price * (1.0 + lineitem["l_tax"])
    agg = ops.group_aggregate(
        keys,
        {
            "sum_qty": lineitem["l_quantity"],
            "sum_base_price": lineitem["l_extendedprice"],
            "sum_disc_price": disc_price,
            "sum_charge": charge,
            "sum_disc": lineitem["l_discount"],
        },
        mask,
        num_groups=6,
    )
    cnt = jnp.maximum(agg["count"], 1.0)
    agg["avg_qty"] = agg["sum_qty"] / cnt
    agg["avg_price"] = agg["sum_base_price"] / cnt
    agg["avg_disc"] = agg["sum_disc"] / cnt
    return agg


def q6(lineitem: Table, year: int = 1994, discount: float = 0.06, qty: float = 24.0):
    """Forecasting revenue change: one filtered product-sum."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    mask = ops.filter_mask(
        lineitem,
        lambda t: ops.pred_between(t["l_shipdate"], lo, hi),
        lambda t: ops.pred_between(t["l_discount"], discount - 0.011, discount + 0.011),
        lambda t: t["l_quantity"] < qty,
    )
    revenue = ops.masked_sum(lineitem["l_extendedprice"] * lineitem["l_discount"], mask)
    return {"revenue": revenue, "rows": ops.masked_count(mask)}


def q6_columns(lineitem: Table, year: int = 1994, discount: float = 0.06, qty: float = 24.0):
    """Q6 reshaped for the fused Pallas filter_agg kernel: a [4, N] column
    block + bounds. quantity < qty folds into a between(0, qty) bound by
    packing quantity as filter-col-1; the discount band becomes the c0 bound
    after swapping roles (two range predicates exactly fit the kernel; the
    third is pre-masked into the value column — documented junk-free)."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    qmask = lineitem["l_quantity"] < qty
    value = jnp.where(qmask, lineitem["l_extendedprice"], 0.0)
    cols = jnp.stack(
        [lineitem["l_shipdate"], lineitem["l_discount"], value, lineitem["l_discount"]]
    )
    return cols, (lo, hi, discount - 0.011, discount + 0.011)


def q12(lineitem: Table, orders: Table, year: int = 1994):
    """Shipping modes & order priority: join + grouped conditional counts."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    joined = ops.fk_index_join(lineitem, "l_orderkey", orders, "o_orderkey", ("o_orderpriority",))
    mask = ops.filter_mask(
        joined,
        lambda t: ops.pred_in(t["l_shipmode"], (2, 5)),  # MAIL, SHIP
        lambda t: t["l_commitdate"] < t["l_receiptdate"],
        lambda t: t["l_shipdate"] < t["l_commitdate"],
        lambda t: ops.pred_between(t["l_receiptdate"], lo, hi),
    )
    high = (joined["o_orderpriority"] <= 1) & mask  # 1-URGENT, 2-HIGH
    low = (joined["o_orderpriority"] > 1) & mask
    agg = ops.group_aggregate(
        joined["l_shipmode"],
        {"high_line_count": high.astype(jnp.float32), "low_line_count": low.astype(jnp.float32)},
        mask,
        num_groups=len(datagen.SHIPMODE),
    )
    return agg


QUERIES = {"q1": q1, "q6": q6, "q12": q12}
