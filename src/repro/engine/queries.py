"""TPC-H-pattern queries over the mini engine (the paper's DBMS workload).

Q1  — scan-heavy group-by aggregate over lineitem;
Q6  — the predicate-pushdown filter+aggregate (also the Pallas filter_agg
      kernel's workload);
Q12 — join lineitem x orders + grouped conditional counts.

Each query is a jit-able Table -> dict[str, Array] function; benchmarks
compare host-style execution vs pushdown-style (see tasks/pushdown.py) and
Pallas-accelerated variants.

The ``*_fused`` variants (FUSED_QUERIES) express the same queries as ONE
``group_filter_agg`` kernel pass each: the predicate program evaluates the
WHERE clause in registers, derived columns (Q1's disc_price/charge) are
term products computed in-flight, and the grouped sums/counts accumulate in
a VMEM tile — instead of the unfused jnp graph's one-HBM-pass-per-aggregate
``segment_sum`` plan.  Counts and integer-valued aggregates match the
unfused results exactly; float sums agree to accumulation-order tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import datagen, ops
from repro.engine.table import Table
from repro.kernels import ops as kops
from repro.kernels.group_filter_agg import encode_aggregates, encode_predicates


def _le_bound(cutoff: float) -> float:
    """The exclusive f32 upper bound equivalent to ``col <= cutoff``."""
    return float(np.nextafter(np.float32(cutoff), np.float32(np.inf)))


def q1(lineitem: Table, delta_days: float = 90.0) -> dict[str, jax.Array]:
    """Pricing summary report: 6 (returnflag x linestatus) groups."""
    cutoff = datagen.date(1998, 12, 1) - delta_days
    mask = lineitem["l_shipdate"] <= cutoff
    keys = lineitem["l_returnflag"] * 2 + lineitem["l_linestatus"]  # 6 groups
    disc_price = lineitem["l_extendedprice"] * (1.0 - lineitem["l_discount"])
    charge = disc_price * (1.0 + lineitem["l_tax"])
    agg = ops.group_aggregate(
        keys,
        {
            "sum_qty": lineitem["l_quantity"],
            "sum_base_price": lineitem["l_extendedprice"],
            "sum_disc_price": disc_price,
            "sum_charge": charge,
            "sum_disc": lineitem["l_discount"],
        },
        mask,
        num_groups=6,
    )
    cnt = jnp.maximum(agg["count"], 1.0)
    agg["avg_qty"] = agg["sum_qty"] / cnt
    agg["avg_price"] = agg["sum_base_price"] / cnt
    agg["avg_disc"] = agg["sum_disc"] / cnt
    return agg


def q6(lineitem: Table, year: int = 1994, discount: float = 0.06, qty: float = 24.0):
    """Forecasting revenue change: one filtered product-sum."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    mask = ops.filter_mask(
        lineitem,
        lambda t: ops.pred_between(t["l_shipdate"], lo, hi),
        lambda t: ops.pred_between(t["l_discount"], discount - 0.011, discount + 0.011),
        lambda t: t["l_quantity"] < qty,
    )
    revenue = ops.masked_sum(lineitem["l_extendedprice"] * lineitem["l_discount"], mask)
    return {"revenue": revenue, "rows": ops.masked_count(mask)}


def q6_columns(lineitem: Table, year: int = 1994, discount: float = 0.06, qty: float = 24.0):
    """Q6 reshaped for the fused Pallas filter_agg kernel: a [4, N] column
    block + bounds. quantity < qty folds into a between(0, qty) bound by
    packing quantity as filter-col-1; the discount band becomes the c0 bound
    after swapping roles (two range predicates exactly fit the kernel; the
    third is pre-masked into the value column — documented junk-free)."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    qmask = lineitem["l_quantity"] < qty
    value = jnp.where(qmask, lineitem["l_extendedprice"], 0.0)
    cols = jnp.stack(
        [lineitem["l_shipdate"], lineitem["l_discount"], value, lineitem["l_discount"]]
    )
    return cols, (lo, hi, discount - 0.011, discount + 0.011)


# Q12's shipmode IN-list, resolved against the dictionary order once so the
# fused and unfused plans can't drift apart.
Q12_SHIPMODES = tuple(datagen.SHIPMODE.index(m) for m in ("MAIL", "SHIP"))


def q12(lineitem: Table, orders: Table, year: int = 1994):
    """Shipping modes & order priority: join + grouped conditional counts."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    joined = ops.fk_index_join(lineitem, "l_orderkey", orders, "o_orderkey", ("o_orderpriority",))
    mask = ops.filter_mask(
        joined,
        lambda t: ops.pred_in(t["l_shipmode"], Q12_SHIPMODES),
        lambda t: t["l_commitdate"] < t["l_receiptdate"],
        lambda t: t["l_shipdate"] < t["l_commitdate"],
        lambda t: ops.pred_between(t["l_receiptdate"], lo, hi),
    )
    high = (joined["o_orderpriority"] <= 1) & mask  # 1-URGENT, 2-HIGH
    low = (joined["o_orderpriority"] > 1) & mask
    agg = ops.group_aggregate(
        joined["l_shipmode"],
        {"high_line_count": high.astype(jnp.float32), "low_line_count": low.astype(jnp.float32)},
        mask,
        num_groups=len(datagen.SHIPMODE),
    )
    return agg


# ---------------------------------------------------------------------------
# Fused variants: each query as one group_filter_agg pass.  The kernel
# programs are built by per-query ``*_program`` functions so that constants
# can also be stacked into *batch inputs* for the scan-sharing serving path
# (``fused_query_batch``) instead of being baked at trace time.
def q1_program(delta_days: float = 90.0):
    """Q1's kernel program: (pred_ops, pred_consts, agg_ops, agg_consts)."""
    cutoff = datagen.date(1998, 12, 1) - delta_days
    pred = encode_predicates([("range", 0, None, _le_bound(cutoff))])  # shipdate <= cutoff
    agg = encode_aggregates(
        [
            [("col", 1)],  # sum_qty
            [("col", 2)],  # sum_base_price
            [("col", 2), ("one_minus", 3)],  # sum_disc_price
            [("col", 2), ("one_minus", 3), ("one_plus", 4)],  # sum_charge
            [("col", 3)],  # sum_disc
        ]
    )
    return (*pred, *agg)


def _q1_layout(lineitem: Table) -> tuple[jax.Array, jax.Array]:
    cols = jnp.stack(
        [
            lineitem["l_shipdate"],  # 0: predicate
            lineitem["l_quantity"],  # 1
            lineitem["l_extendedprice"],  # 2
            lineitem["l_discount"],  # 3
            lineitem["l_tax"],  # 4
        ]
    )
    keys = lineitem["l_returnflag"] * 2 + lineitem["l_linestatus"]
    return cols, keys


def _q1_demux(out: jax.Array) -> dict[str, jax.Array]:
    """Q1 result dict from one [6, 6] kernel output row-block."""
    agg = {
        "sum_qty": out[:, 0],
        "sum_base_price": out[:, 1],
        "sum_disc_price": out[:, 2],
        "sum_charge": out[:, 3],
        "sum_disc": out[:, 4],
        "count": out[:, 5],
    }
    cnt = jnp.maximum(agg["count"], 1.0)
    agg["avg_qty"] = agg["sum_qty"] / cnt
    agg["avg_price"] = agg["sum_base_price"] / cnt
    agg["avg_disc"] = agg["sum_disc"] / cnt
    return agg


def q1_fused(
    lineitem: Table, delta_days: float = 90.0, use_pallas: bool = True
) -> dict[str, jax.Array]:
    """Q1 as a single kernel pass: 6 groups x 5 aggregates + count, with
    disc_price/charge evaluated in-register by the term program."""
    cols, keys = _q1_layout(lineitem)
    pred_ops, pred_consts, agg_ops, agg_consts = q1_program(delta_days)
    out = kops.group_filter_agg(
        cols, keys, pred_ops, pred_consts, agg_ops, agg_consts,
        num_groups=6, use_pallas=use_pallas,
    )
    return _q1_demux(out)


def q6_program(year: int = 1994, discount: float = 0.06, qty: float = 24.0):
    """Q6's kernel program: three range predicates + one product-sum."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    pred = encode_predicates(
        [
            ("range", 0, lo, hi),
            ("range", 1, discount - 0.011, discount + 0.011),
            ("range", 2, None, qty),  # quantity < qty
        ]
    )
    agg = encode_aggregates([[("col", 3), ("col", 1)]])
    return (*pred, *agg)


def _q6_layout(lineitem: Table) -> tuple[jax.Array, jax.Array]:
    cols = jnp.stack(
        [
            lineitem["l_shipdate"],  # 0
            lineitem["l_discount"],  # 1
            lineitem["l_quantity"],  # 2
            lineitem["l_extendedprice"],  # 3
        ]
    )
    keys = jnp.zeros((lineitem.num_rows,), jnp.int32)
    return cols, keys


def _q6_demux(out: jax.Array) -> dict[str, jax.Array]:
    return {"revenue": out[0, 0], "rows": out[0, 1].astype(jnp.int32)}


def q6_fused(
    lineitem: Table,
    year: int = 1994,
    discount: float = 0.06,
    qty: float = 24.0,
    use_pallas: bool = True,
):
    """Q6 as a 1-group program: three range predicates + one product-sum.

    Unlike ``q6_columns`` (which pre-masks the quantity predicate into the
    value column to fit ``filter_agg``'s fixed two-predicate shape), the
    general kernel expresses all three predicates, so the returned row
    count matches ``q6`` exactly too.
    """
    cols, keys = _q6_layout(lineitem)
    pred_ops, pred_consts, agg_ops, agg_consts = q6_program(year, discount, qty)
    out = kops.group_filter_agg(
        cols, keys, pred_ops, pred_consts, agg_ops, agg_consts,
        num_groups=1, use_pallas=use_pallas,
    )
    return _q6_demux(out)


def q12_program(year: int = 1994):
    """Q12's kernel program over the joined layout."""
    lo = datagen.date(year)
    hi = datagen.date(year + 1)
    pred = encode_predicates(
        [
            ("lt", 0, 1),  # commitdate < receiptdate
            ("lt", 2, 0),  # shipdate < commitdate
            ("range", 1, lo, hi),  # receiptdate in the year window
        ]
    )
    agg = encode_aggregates(
        [
            [("le", 3, 1.0)],  # high priority: 1-URGENT, 2-HIGH
            [("gt", 3, 1.0)],  # low priority
        ]
    )
    return (*pred, *agg)


def _q12_layout(lineitem: Table, orders: Table) -> tuple[jax.Array, jax.Array]:
    """Join once; the join does not depend on the predicate constants, so
    the serving path amortizes it across every request of the batch."""
    joined = ops.fk_index_join(
        lineitem, "l_orderkey", orders, "o_orderkey", ("o_orderpriority",)
    )
    cols = jnp.stack(
        [
            joined["l_commitdate"],  # 0
            joined["l_receiptdate"],  # 1
            joined["l_shipdate"],  # 2
            joined["o_orderpriority"].astype(jnp.float32),  # 3
        ]
    )
    return cols, joined["l_shipmode"]


def _q12_demux(out: jax.Array) -> dict[str, jax.Array]:
    num_groups = len(datagen.SHIPMODE)
    sel = jnp.zeros((num_groups,), jnp.float32).at[jnp.asarray(Q12_SHIPMODES)].set(1.0)
    return {
        "high_line_count": out[:, 0] * sel,
        "low_line_count": out[:, 1] * sel,
        "count": out[:, 2] * sel,
    }


def q12_fused(
    lineitem: Table, orders: Table, year: int = 1994, use_pallas: bool = True
):
    """Q12 as join-gather + one kernel pass over all 7 shipmode groups.

    The ``shipmode IN (MAIL, SHIP)`` membership predicate is equivalent to
    selecting those groups of the full grouped result (rows of other
    shipmodes land in other groups), so it becomes a post-kernel group mask
    instead of a row predicate — counts stay integer-exact.
    """
    cols, keys = _q12_layout(lineitem, orders)
    pred_ops, pred_consts, agg_ops, agg_consts = q12_program(year)
    out = kops.group_filter_agg(
        cols, keys, pred_ops, pred_consts, agg_ops, agg_consts,
        num_groups=len(datagen.SHIPMODE), use_pallas=use_pallas,
    )
    return _q12_demux(out)


QUERIES = {"q1": q1, "q6": q6, "q12": q12}
FUSED_QUERIES = {"q1": q1_fused, "q6": q6_fused, "q12": q12_fused}


# ---------------------------------------------------------------------------
# Serving plans: the query-shape contract behind scan-sharing micro-batches.
@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One query shape, ready to serve requests whose constants arrive at
    run time.

    ``cols``/``keys`` are the parameter-independent column layout (for Q12
    including the join, computed once); ``pred_ops``/``agg_ops`` the shared
    opcode structure; ``program(params)`` builds one request's constant
    tables; ``demux(out)`` turns one ``[G, A + 1]`` kernel output slot back
    into the query's result dict.
    """

    name: str
    cols: jax.Array
    keys: jax.Array
    pred_ops: jax.Array
    agg_ops: jax.Array
    num_groups: int
    program: Callable[[dict[str, Any]], tuple[jax.Array, jax.Array]]
    demux: Callable[[jax.Array], dict[str, jax.Array]]


def _plan_program(program_fn) -> Callable[[dict[str, Any]], tuple[jax.Array, jax.Array]]:
    def consts(params: dict[str, Any]) -> tuple[jax.Array, jax.Array]:
        _, pred_consts, _, agg_consts = program_fn(**params)
        return pred_consts, agg_consts

    return consts


def make_serving_plans(
    lineitem: Table, orders: Table | None = None
) -> dict[str, ServingPlan]:
    """Serving plans for every fused query servable over these tables.

    Q12 needs ``orders`` for its join; without it only Q1/Q6 are planned.
    """
    plans: dict[str, ServingPlan] = {}
    specs: list[tuple[str, tuple[jax.Array, jax.Array], Any, int, Any]] = [
        ("q1", _q1_layout(lineitem), q1_program, 6, _q1_demux),
        ("q6", _q6_layout(lineitem), q6_program, 1, _q6_demux),
    ]
    if orders is not None:
        specs.append(
            ("q12", _q12_layout(lineitem, orders), q12_program, len(datagen.SHIPMODE), _q12_demux)
        )
    for name, (cols, keys), program_fn, num_groups, demux in specs:
        pred_ops, _, agg_ops, _ = program_fn()
        plans[name] = ServingPlan(
            name=name,
            cols=cols,
            keys=keys,
            pred_ops=pred_ops,
            agg_ops=agg_ops,
            num_groups=num_groups,
            program=_plan_program(program_fn),
            demux=demux,
        )
    return plans


def fused_query_serial(
    plan: ServingPlan, params: dict[str, Any], *, use_pallas: bool = True
) -> dict[str, jax.Array]:
    """One request through the single-program kernel — the serving oracle."""
    pred_consts, agg_consts = plan.program(params)
    out = kops.group_filter_agg(
        plan.cols, plan.keys, plan.pred_ops, pred_consts, plan.agg_ops, agg_consts,
        num_groups=plan.num_groups, use_pallas=use_pallas,
    )
    return plan.demux(out)


def fused_query_batch(
    plan: ServingPlan,
    param_list: list[dict[str, Any]],
    *,
    use_pallas: bool = True,
) -> list[dict[str, jax.Array]]:
    """Scan sharing: N same-shape requests, ONE kernel pass over the data.

    Each request's constants become one slot of the batched SMEM program
    tables; results demultiplex per request and are bit-equal to
    ``fused_query_serial`` on the same constants (the kernel's per-program
    block-accumulation order is identical to the single-program path).
    """
    consts = [plan.program(p) for p in param_list]
    pred_consts = jnp.stack([c[0] for c in consts])
    agg_consts = jnp.stack([c[1] for c in consts])
    out = kops.group_filter_agg_multi(
        plan.cols, plan.keys, plan.pred_ops, pred_consts, plan.agg_ops, agg_consts,
        num_groups=plan.num_groups, use_pallas=use_pallas,
    )
    return [plan.demux(out[b]) for b in range(len(param_list))]
