"""Columnar tables for the mini query engine (the DuckDB analogue).

A Table is a frozen mapping column-name -> jnp array, all the same length.
Variable-length strings don't exist on TPU; dictionary-encoded categoricals
(int32 codes) and fixed-point decimals (scaled int64 / f32) stand in, which
matches how columnar engines physically store them anyway.

Tables are pytrees, so they jit, shard (rows over ("pod","data")), and
donate like any other JAX value.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Table:
    columns: dict[str, jax.Array]

    def __post_init__(self):
        lens = {k: v.shape[0] for k, v in self.columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(f"ragged columns: {lens}")

    # -- pytree --------------------------------------------------------------
    def tree_flatten(self):
        names = sorted(self.columns)
        return [self.columns[n] for n in names], names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        obj = object.__new__(cls)
        object.__setattr__(obj, "columns", dict(zip(names, leaves)))
        return obj

    # -- accessors -------------------------------------------------------------
    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0] if self.columns else 0

    @property
    def names(self) -> list[str]:
        return sorted(self.columns)

    def nbytes(self) -> int:
        return sum(v.size * v.dtype.itemsize for v in self.columns.values())

    # -- construction ----------------------------------------------------------
    def with_columns(self, **cols: jax.Array) -> "Table":
        return Table({**self.columns, **cols})

    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def take(self, idx: jax.Array) -> "Table":
        return Table({n: jnp.take(c, idx, axis=0) for n, c in self.columns.items()})

    def slice_rows(self, start: int, size: int) -> "Table":
        return Table(
            {n: jax.lax.dynamic_slice_in_dim(c, start, size, 0) for n, c in self.columns.items()}
        )

    def __repr__(self) -> str:
        cols = ", ".join(f"{n}:{c.dtype}" for n, c in sorted(self.columns.items()))
        return f"Table[{self.num_rows} rows]({cols})"


def concat(tables: list[Table]) -> Table:
    names = tables[0].names
    return Table({n: jnp.concatenate([t[n] for t in tables]) for n in names})
