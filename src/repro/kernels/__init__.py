"""Pallas TPU kernels (validated interpret=True on CPU) + pure-jnp oracles.

Public API lives in repro.kernels.ops: flash_attention, decode_attention,
ssd_intra, gmm, filter_agg — each with a use_pallas=False oracle path.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
