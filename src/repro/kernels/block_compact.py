"""Fused block compaction kernel (the pushdown "return qualifying rows" path).

``engine.ops.compact`` materializes qualifying rows with ``jnp.nonzero`` +
gather: one full pass to build the index vector in HBM, then one gather pass
per column.  The fused plan is one pass: each input block computes its mask
count and in-block prefix offsets (exclusive cumsum of the mask), converts
the offsets into a scatter permutation, and writes its qualifying rows
densely into a capacity-bounded output buffer at the running global offset.

Mechanics per SUB-row sub-tile (SUB = 512, keeps the permutation matrix at
SUB x SUB f32 = 1 MB):

  * ``pos = cumsum(mask) - mask`` — each qualifying row's slot among the
    sub-tile's qualifiers;
  * scatter-as-matmul: ``P[r, j] = mask[r] & (pos[r] == j)``, and
    ``cols_sub [C, SUB] @ P [SUB, SUB]`` lands every qualifying row at its
    slot (MXU work instead of an unsupported vector scatter);
  * the compacted sub-tile is stored at ``out[:, base : base + SUB]`` where
    ``base`` is the global running count — slots past the sub-tile's own
    count hold zeros and are overwritten by the next sub-tile's store (TPU
    grids iterate sequentially, so later stores win).

Capacity semantics match the ``nonzero(size=cap)`` oracle: qualifying rows
with global position >= cap are dropped, slots in [count, cap) are zero.
The output buffer is padded by one sub-tile so an almost-full store never
writes out of bounds (stores whose base would pass ``cap`` clamp into the
trimmed pad region).

The returned count is exact and independent of ``cap``; it rides in an i32
[1, LANES] tile that doubles as the running-offset carry between grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams

LANES = 128
SUB = 512  # sub-tile width: the scatter permutation is [SUB, SUB] f32


def _kernel(cols_ref, mask_ref, out_ref, cnt_ref, *, cap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bn = cols_ref.shape[1]
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)

    def body(s, base):
        m = mask_ref[:, pl.ds(s * SUB, SUB)]  # [1, SUB] i32
        sub = cols_ref[:, pl.ds(s * SUB, SUB)]  # [C, SUB]
        pos = jnp.cumsum(m, axis=1) - m  # exclusive prefix: target slot
        cnt = jnp.sum(m)
        # P[r, j] = qualifying row r goes to slot j; scatter via MXU.
        perm = (
            (pos.reshape(SUB, 1) == slot_ids) & (m.reshape(SUB, 1) != 0)
        ).astype(jnp.float32)
        packed = jax.lax.dot_general(
            sub, perm, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        # Rows past cap are dropped: clamp the store into the pad region,
        # where it only ever overwrites other dropped rows.
        start = jnp.minimum(base, cap)
        out_ref[:, pl.ds(start, SUB)] = packed
        return base + cnt

    base0 = cnt_ref[0, 0]
    total = jax.lax.fori_loop(0, bn // SUB, body, base0)
    cnt_ref[...] = jnp.full((1, LANES), total, jnp.int32)


def block_compact(
    cols: jax.Array,  # [C, N] f32 column block
    mask: jax.Array,  # [1, N] i32 (0/1) row mask
    cap: int,
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [C, cap] f32, count i32 scalar).

    ``out[:, j]`` is the j-th qualifying row for ``j < min(count, cap)``,
    zero beyond; ``count`` is the total mask population regardless of cap.
    """
    c, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    assert bn % SUB == 0, (bn, SUB)
    assert cap >= 1

    out, cnt = pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((c, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_specs=(
            pl.BlockSpec((c, cap + SUB), lambda i: (0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c, cap + SUB), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.int32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(cols, mask)
    return out[:, :cap], cnt[0, 0]
