"""Fused block compaction kernel (the pushdown "return qualifying rows" path).

``engine.ops.compact`` materializes qualifying rows with ``jnp.nonzero`` +
gather: one full pass to build the index vector in HBM, then one gather pass
per column.  The fused plan is one pass: each input block computes its mask
count and in-block prefix offsets (exclusive cumsum of the mask), converts
the offsets into a scatter permutation, and writes its qualifying rows
densely into a capacity-bounded output buffer at the running global offset.

Mechanics per SUB-row sub-tile (SUB = 512, keeps the permutation matrix at
SUB x SUB f32 = 1 MB):

  * ``pos = cumsum(mask) - mask`` — each qualifying row's slot among the
    sub-tile's qualifiers;
  * scatter-as-matmul: ``P[r, j] = mask[r] & (pos[r] == j)``, and
    ``cols_sub [C, SUB] @ P [SUB, SUB]`` lands every qualifying row at its
    slot (MXU work instead of an unsupported vector scatter);
  * the compacted sub-tile is stored at ``out[:, base : base + SUB]`` where
    ``base`` is the global running count — slots past the sub-tile's own
    count hold zeros and are overwritten by the next sub-tile's store (TPU
    grids iterate sequentially, so later stores win).

Capacity semantics match the ``nonzero(size=cap)`` oracle: qualifying rows
with global position >= cap are dropped, slots in [count, cap) are zero.
The output buffer is padded by one sub-tile so an almost-full store never
writes out of bounds (stores whose base would pass ``cap`` clamp into the
trimmed pad region).

The returned count is exact and independent of ``cap``; it rides in an i32
[1, LANES] tile that doubles as the running-offset carry between grid steps.

Two variants share that per-sub-tile compaction core:

  * the **resident** kernel above keeps the whole ``[C, cap + SUB]`` output
    in VMEM, so ``cap`` is bounded by the ~8 MB VMEM budget — fine for the
    low-selectivity points, impossible for the 6M-row sweep at high
    selectivity;
  * the **streaming** kernel (:func:`block_compact_stream`) keeps the output
    in HBM (``pltpu.ANY``) and emits each completed SUB-wide tile with a
    double-buffered manual DMA (:mod:`repro.kernels.pipeline`), overlapping
    the copy of tile *i* with the mask/cumsum/scatter-matmul compute of the
    sub-tiles that fill tile *i+1*.  Capacity is HBM-bounded.

The streaming write path cannot reuse the resident kernel's overlapping-
store trick: two in-flight DMAs to overlapping HBM ranges have no ordering,
so stores must be exact-length and disjoint.  Instead a one-sub-tile carry
buffer holds the partially-filled tail tile; each sub-tile's qualifying rows
are scattered directly to ``carry_fill + pos`` slots of a ``[C, 2*SUB]``
window (one widened scatter matmul), the first half merges with the carry,
and whenever the carry fills a whole tile it is emitted at a SUB-aligned
HBM offset (aligned + disjoint = safe to double-buffer).  The final
partial tile is flushed by the epilogue in :func:`stream_finalize`.

Overflow keeps oracle semantics without per-row drops: a tile whose base
passes ``cap`` is simply not emitted (every row in it has global position
>= cap), and the tile straddling ``cap`` lands in the trimmed ``[cap,
cap_ceil)`` pad region.  Chunking: the kernel threads (out, state, carry)
through ``input_output_aliases``, so a driver may split an arbitrarily long
input across calls — ``stream_init`` / ``stream_chunk`` / ``stream_finalize``
are the composable surface the chunked driver in :mod:`repro.kernels.ops`
uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pipeline
from repro.kernels.compat import CompilerParams

LANES = 128
SUB = 512  # sub-tile width: the scatter permutation is [SUB, SUB] f32


def _kernel(cols_ref, mask_ref, out_ref, cnt_ref, *, cap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bn = cols_ref.shape[1]
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)

    def body(s, base):
        m = mask_ref[:, pl.ds(s * SUB, SUB)]  # [1, SUB] i32
        sub = cols_ref[:, pl.ds(s * SUB, SUB)]  # [C, SUB]
        pos = jnp.cumsum(m, axis=1) - m  # exclusive prefix: target slot
        cnt = jnp.sum(m)
        # P[r, j] = qualifying row r goes to slot j; scatter via MXU.
        perm = (
            (pos.reshape(SUB, 1) == slot_ids) & (m.reshape(SUB, 1) != 0)
        ).astype(jnp.float32)
        packed = jax.lax.dot_general(
            sub, perm, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        # Rows past cap are dropped: clamp the store into the pad region,
        # where it only ever overwrites other dropped rows.
        start = jnp.minimum(base, cap)
        out_ref[:, pl.ds(start, SUB)] = packed
        return base + cnt

    base0 = cnt_ref[0, 0]
    total = jax.lax.fori_loop(0, bn // SUB, body, base0)
    cnt_ref[...] = jnp.full((1, LANES), total, jnp.int32)


def block_compact(
    cols: jax.Array,  # [C, N] f32 column block
    mask: jax.Array,  # [1, N] i32 (0/1) row mask
    cap: int,
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [C, cap] f32, count i32 scalar).

    ``out[:, j]`` is the j-th qualifying row for ``j < min(count, cap)``,
    zero beyond; ``count`` is the total mask population regardless of cap.
    """
    c, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    assert bn % SUB == 0, (bn, SUB)
    assert cap >= 1

    out, cnt = pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((c, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_specs=(
            pl.BlockSpec((c, cap + SUB), lambda i: (0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c, cap + SUB), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.int32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(cols, mask)
    return out[:, :cap], cnt[0, 0]


# ---------------------------------------------------------------------------
# Streaming variant: HBM-resident output, double-buffered DMA emission.
#
# Cross-chunk state is (out [C, cap_ceil + SUB] in HBM, state [1, LANES] i32,
# carry [C, SUB] f32).  State lanes: 0 = total mask count so far, 1 = carry
# fill (rows held in the carry tile), 2 = next tile index (global offset of
# the carry tile is tile * SUB).

_TOTAL, _FILL, _TILE = 0, 1, 2


def _pack_state(total, fill, tile):
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    st = jnp.where(lane == _TOTAL, total, 0)
    st = jnp.where(lane == _FILL, fill, st)
    return jnp.where(lane == _TILE, tile, st)


def _stream_kernel(
    cols_ref, mask_ref, state_in_ref, carry_in_ref, hbm_in_ref,
    out_ref, state_ref, carry_ref,
    stage_ref, sem_ref, *, cap_ceil: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():  # fold the previous chunk's state into the revisited tiles
        state_ref[...] = state_in_ref[...]
        carry_ref[...] = carry_in_ref[...]

    bn = cols_ref.shape[1]
    pad_tile = cap_ceil // SUB  # first tile index wholly past cap: not emitted
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (SUB, 2 * SUB), 1)

    def body(s, st):
        total, fill, tile, seq, carry = st
        m = mask_ref[:, pl.ds(s * SUB, SUB)]  # [1, SUB] i32
        sub = cols_ref[:, pl.ds(s * SUB, SUB)]  # [C, SUB]
        # Slot among (carry rows + this sub-tile's qualifiers): the widened
        # scatter lands row r at fill + (exclusive prefix of mask)[r], so
        # the carry merge is a plain add against disjoint zero slots.
        pos = jnp.cumsum(m, axis=1) - m + fill
        cnt = jnp.sum(m)
        perm = (
            (pos.reshape(SUB, 1) == slot_ids) & (m.reshape(SUB, 1) != 0)
        ).astype(jnp.float32)
        window = jax.lax.dot_general(
            sub, perm, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # [C, 2*SUB]: qualifying rows at slots [fill, fill + cnt)
        merged = carry + window[:, :SUB]
        spill = window[:, SUB:]
        new_fill = fill + cnt
        is_full = new_fill >= SUB
        emit_now = is_full & (tile < pad_tile)

        @pl.when(emit_now)
        def _emit():
            pipeline.emit_tile(
                stage_ref, sem_ref, seq, merged,
                out_ref.at[:, pl.ds(tile * SUB, SUB)],
            )

        carry = jnp.where(is_full, spill, merged)
        fill = jnp.where(is_full, new_fill - SUB, new_fill)
        tile = tile + is_full.astype(jnp.int32)
        seq = seq + emit_now.astype(jnp.int32)
        return total + cnt, fill, tile, seq, carry

    total, fill, tile, seq, carry = jax.lax.fori_loop(
        0, bn // SUB, body,
        (state_ref[0, _TOTAL], state_ref[0, _FILL], state_ref[0, _TILE],
         jnp.int32(0), carry_ref[...]),
    )
    # Settle this grid step's in-flight copies: scratch DMA semaphores must
    # be zero when the kernel ends, and the input pipeline may rotate our
    # staging source underneath an unfinished copy otherwise.
    pipeline.drain(stage_ref, sem_ref, seq, out_ref.at[:, pl.ds(0, SUB)])
    carry_ref[...] = carry
    state_ref[...] = _pack_state(total, fill, tile)


def _cap_ceil(cap: int) -> int:
    return -(-cap // SUB) * SUB


def stream_init(c: int, cap: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fresh (out, state, carry) streaming state for a [c, N] compaction.

    ``out`` is the HBM-resident output, one SUB-tile wider than
    ``cap_ceil`` so the tile straddling ``cap`` always has somewhere exact
    to land; the zeros-init is one write pass that gives ``[count, cap)``
    its oracle zeros without any in-kernel zero-fill traffic.
    """
    return (
        jnp.zeros((c, _cap_ceil(cap) + SUB), jnp.float32),
        jnp.zeros((1, LANES), jnp.int32),
        jnp.zeros((c, SUB), jnp.float32),
    )


def stream_chunk(
    state: tuple[jax.Array, jax.Array, jax.Array],
    cols: jax.Array,  # [C, n] f32, n a multiple of SUB
    mask: jax.Array,  # [1, n] i32 (0/1)
    cap: int,
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compact one input chunk into the running stream state.

    The HBM output buffer is threaded through ``input_output_aliases`` so
    successive chunks DMA into ONE allocation — no copy of the (possibly
    many-MB) output per call; offset and count carry in the state tile.
    """
    out, st, carry = state
    c, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    assert bn % SUB == 0, (bn, SUB)
    assert cap >= 1
    cap_pad = _cap_ceil(cap) + SUB
    assert out.shape == (c, cap_pad), (out.shape, c, cap_pad)

    out, st, carry = pl.pallas_call(
        functools.partial(_stream_kernel, cap_ceil=_cap_ceil(cap)),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((c, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
            pl.BlockSpec((c, SUB), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
            pl.BlockSpec((c, SUB), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c, cap_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.int32),
            jax.ShapeDtypeStruct((c, SUB), jnp.float32),
        ),
        scratch_shapes=list(pipeline.emit_slots(c, SUB, jnp.float32)),
        input_output_aliases={4: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(cols, mask, st, carry, out)
    return out, st, carry


def stream_finalize(
    state: tuple[jax.Array, jax.Array, jax.Array], cap: int
) -> tuple[jax.Array, jax.Array]:
    """Epilogue: flush the ragged carry tail, trim to cap, return count.

    The carry tile holds ``fill < SUB`` rows (zeros beyond), written as one
    exact-length update at the running offset — clamped into the pad tile
    when the stream already passed ``cap``, where it only covers dropped
    rows.
    """
    out, st, carry = state
    start = jnp.minimum(st[0, _TILE] * SUB, _cap_ceil(cap))
    out = jax.lax.dynamic_update_slice(out, carry, (0, start))
    return out[:, :cap], st[0, _TOTAL]


def block_compact_stream(
    cols: jax.Array,  # [C, N] f32 column block
    mask: jax.Array,  # [1, N] i32 (0/1) row mask
    cap: int,
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-call streaming compaction; same contract as :func:`block_compact`
    with ``cap`` bounded by HBM instead of VMEM."""
    state = stream_init(cols.shape[0], cap)
    state = stream_chunk(
        state, cols, mask, cap, block_n=block_n, interpret=interpret
    )
    return stream_finalize(state, cap)
