"""Pallas API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
field set we use (``dimension_semantics``) is identical in both. Resolve the
name once here so every kernel works on either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
