"""Flash-decoding attention: one query token vs a long KV cache, Pallas TPU.

Grid (B, Hkv, nK) — all G query heads of a KV group are processed together
(q tile [G, dh]), so the MXU sees a [G, dh] x [dh, bk] matmul per step
instead of G rank-1 products. The per-sequence valid length (kv_len) masks
cache tail slots AND gates whole blocks via @pl.when, so a 32k-slot cache
with 1k valid tokens reads ~1k keys, not 32k.

The online-softmax state is [G, LANES] VMEM scratch carried over K blocks
(sequential innermost dim), identical in structure to the prefill kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 - dtype/memory-space helpers
from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, bk, nk):
    ki = pl.program_id(2)
    kv_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * bk < kv_len)  # skip blocks entirely past the valid length
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # [G, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, bk]
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_next = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_next, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [B, Hq, dh]
    k: jax.Array,  # [B, S, Hkv, dh]
    v: jax.Array,  # [B, S, Hkv, dh]
    kv_len: jax.Array,  # [B] int32
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, dh = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk

    grid = (b, hkv, nk)
    kern = functools.partial(_kernel, scale=dh**-0.5, bk=bk, nk=nk)
    qg = q.reshape(b, hkv, g, dh)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, ki: (b_,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda b_, h, ki: (b_, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b_, h, ki: (b_, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b_, h, ki: (b_, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h, ki: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_len, qg.reshape(b, hkv, g, dh), k, v)
    return out.reshape(b, hq, dh)
