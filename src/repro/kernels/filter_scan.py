"""Fused scan+filter+aggregate kernel (the predicate-pushdown hot loop).

dpBento's predicate-pushdown module scans table tuples and returns only the
qualifying work (paper §3.5.1 / Fig. 13). On TPU the profitable fusion is
scan -> predicate -> masked aggregate in one VMEM pass: columns stream
HBM->VMEM once, the mask never materializes in HBM, and the reduction
accumulates in a revisited [1, 128] output tile (TPU grids iterate
sequentially, so a running accumulator across blocks is safe).

The aggregate pattern matches TPC-H Q6: SUM(col2 * col3) + COUNT(*) WHERE
lo <= col0 < hi AND lo2 <= col1 < hi2. Bounds arrive via SMEM (scalars).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 - dtype/memory-space helpers
from repro.kernels.compat import CompilerParams

LANES = 128


def _kernel(bounds_ref, cols_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lo, hi, lo2, hi2 = bounds_ref[0], bounds_ref[1], bounds_ref[2], bounds_ref[3]
    c0 = cols_ref[0, :]
    c1 = cols_ref[1, :]
    c2 = cols_ref[2, :]
    c3 = cols_ref[3, :]
    mask = (c0 >= lo) & (c0 < hi) & (c1 >= lo2) & (c1 < hi2)
    prod = jnp.where(mask, c2.astype(jnp.float32) * c3.astype(jnp.float32), 0.0)
    cnt = mask.astype(jnp.float32)
    # lane 0 accumulates sum, lane 1 count; remaining lanes stay zero
    upd = jnp.zeros((1, LANES), jnp.float32)
    upd = upd.at[0, 0].set(jnp.sum(prod)).at[0, 1].set(jnp.sum(cnt))
    out_ref[...] += upd


def filter_agg(
    cols: jax.Array,  # [4, N] f32 — (filter0, filter1, value-a, value-b)
    lo: float,
    hi: float,
    lo2: float,
    hi2: float,
    *,
    block_n: int = 16384,
    interpret: bool = False,
) -> jax.Array:
    """Returns [2] f32: (SUM(c2*c3 | mask), COUNT(mask))."""
    _, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    bounds = jnp.asarray([lo, hi, lo2, hi2], jnp.float32)

    out = pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((4, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, LANES), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(bounds, cols)
    return out[0, :2]
