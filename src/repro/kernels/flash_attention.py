"""Causal GQA flash-attention forward, Pallas TPU.

Grid (B, Hq, nQ, nK) — nK innermost, sequential ("arbitrary") so the online
softmax state lives in VMEM scratch across K blocks. Q/K/V tiles are pulled
HBM->VMEM by BlockSpec; GQA is expressed in the K/V index_map (query head h
reads KV head h // group). Causal skipping is a @pl.when on the block's
visibility, so fully-masked tiles cost no MXU work.

Block sizes default to (512, 512): VMEM per step =
q (512x128 f32) + k/v (2x) + acc (512x128 f32) + m/l ~= 1 MB << 16 MB VMEM,
and 512 is a multiple of the 128-lane register width.

Masked lanes use a large-negative (-1e30) instead of -inf so rows with no
visible keys produce zeros, never NaNs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 - dtype/memory-space helpers
from repro.kernels.compat import CompilerParams

NEG_INF = -1e30
LANES = 128  # m/l scratch replicated across the lane dim


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, bq, bk, nk, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Visibility: causal block (qi*bq .. qi*bq+bq-1) sees keys < qi*bq+bq.
    visible = jnp.bool_(True) if not causal else (ki * bk <= qi * bq + bq - 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[:, :1]  # [bq, 1] (lanes replicated)
        l_prev = l_scr[:, :1]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)  # [bq, bk]
        l_next = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_next, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, dh]
    k: jax.Array,  # [B, Sk, Hkv, dh]
    v: jax.Array,  # [B, Sk, Hkv, dh]
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    if causal:
        assert sq == sk, "causal flash kernel expects square attention"

    grid = (b, hq, nq, nk)
    kern = functools.partial(
        _kernel, scale=dh**-0.5, bq=bq, bk=bk, nk=nk, causal=causal
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b_, h, qi, ki, g=g: (b_, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b_, h, qi, ki, g=g: (b_, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda b_, h, qi, ki: (b_, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),  # m
            pltpu.VMEM((bq, LANES), jnp.float32),  # l
            pltpu.VMEM((bq, dh), jnp.float32),  # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
