"""Single-pass fused grouped filter+aggregate kernel (the DBMS hot loop).

``filter_scan.filter_agg`` fuses exactly one query shape (TPC-H Q6: two
range predicates, one product-sum).  The DBMS workloads (paper §3.6,
Fig. 15) need the general form: Q1 is a 6-group × 5-aggregate scan with two
derived columns, Q12 is grouped conditional counts behind four predicates —
both executed today as unfused jnp graphs that stream every column through
HBM several times (mask pass, derived-column passes, then one
``segment_sum`` pass per aggregate).

This kernel makes any such query ONE pass over a ``[C, N]`` column block:

  * a small **predicate program** arrives in SMEM — K predicates, each
    either a range test ``lo <= cols[a] < hi`` or a column compare
    ``cols[a] < cols[b]``, AND-combined into the row mask in registers;
  * an **aggregate program** (also SMEM) — A aggregates, each the product
    of up to 3 *terms*, where a term transforms one column
    (identity / ``1-c`` / ``1+c`` / ``c <= const`` / ``c > const``).  Q1's
    derived ``disc_price = price * (1 - discount)`` and
    ``charge = disc_price * (1 + tax)`` are term products evaluated
    in-register, never materialized in HBM;
  * per-group accumulation for G dictionary-coded groups lands in a
    revisited ``[G, LANES]`` VMEM tile via a one-hot MXU matmul
    (``onehot[G, bn] @ vals[bn, A+1]``); TPU grids iterate sequentially, so
    the running accumulator across blocks is safe (same trick as
    ``filter_scan``).

Padding contract: rows whose key is outside ``[0, num_groups)`` (the ops
wrapper pads with -1) match no one-hot row and therefore contribute to no
group, regardless of what the predicate program evaluates to on padded
junk — padding correctness does not depend on the program.

Output layout: ``out[g, a]`` = sum of aggregate ``a`` over masked rows of
group ``g`` for ``a < A``; ``out[g, A]`` = masked row count of group ``g``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.kernels.compat import CompilerParams

LANES = 128

# Predicate opcodes (pred_ops[k, 0]).
PRED_RANGE = 0  # lo <= cols[a] < hi
PRED_LT = 1  # cols[a] < cols[b]

# Aggregate term modes (agg_ops[k, 2*t]).
TERM_NONE = 0  # 1.0 (unused term slot)
TERM_COL = 1  # cols[i]
TERM_ONE_MINUS = 2  # 1 - cols[i]
TERM_ONE_PLUS = 3  # 1 + cols[i]
TERM_LE = 4  # cols[i] <= const  (0/1 indicator)
TERM_GT = 5  # cols[i] > const   (0/1 indicator)

MAX_TERMS = 3

_FLOAT_MIN = float(np.finfo(np.float32).min)
_FLOAT_MAX = float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Program encoding: tiny int/float tables a query builds once at trace time.
def encode_predicates(preds) -> tuple[jax.Array, jax.Array]:
    """preds: sequence of ("range", col, lo, hi) | ("lt", col_a, col_b).

    ``lo``/``hi`` may be ``None`` for an open bound.  Returns
    (pred_ops [K, 3] i32, pred_consts [K, 2] f32); K >= 1 (an empty program
    encodes one always-true range predicate on column 0).
    """
    ops, consts = [], []
    for p in preds:
        kind = p[0]
        if kind == "range":
            _, col, lo, hi = p
            ops.append((PRED_RANGE, int(col), 0))
            consts.append((
                _FLOAT_MIN if lo is None else float(lo),
                _FLOAT_MAX if hi is None else float(hi),
            ))
        elif kind == "lt":
            _, a, b = p
            ops.append((PRED_LT, int(a), int(b)))
            consts.append((0.0, 0.0))
        else:
            raise ValueError(f"unknown predicate kind {kind!r}")
    if not ops:
        ops.append((PRED_RANGE, 0, 0))
        consts.append((_FLOAT_MIN, _FLOAT_MAX))
    return (
        jnp.asarray(ops, jnp.int32),
        jnp.asarray(consts, jnp.float32),
    )


_TERM_CODES = {
    "col": TERM_COL,
    "one_minus": TERM_ONE_MINUS,
    "one_plus": TERM_ONE_PLUS,
    "le": TERM_LE,
    "gt": TERM_GT,
}


def encode_aggregates(aggs) -> tuple[jax.Array, jax.Array]:
    """aggs: sequence of aggregates; each is a sequence of <= MAX_TERMS terms.

    A term is ("col", i) | ("one_minus", i) | ("one_plus", i)
    | ("le", i, const) | ("gt", i, const).  The aggregate's per-row value is
    the product of its terms.  Returns (agg_ops [A, 2*MAX_TERMS] i32,
    agg_consts [A, MAX_TERMS] f32).
    """
    if not aggs:
        raise ValueError("need at least one aggregate")
    ops = np.zeros((len(aggs), 2 * MAX_TERMS), np.int32)
    consts = np.zeros((len(aggs), MAX_TERMS), np.float32)
    for a, terms in enumerate(aggs):
        if not 1 <= len(terms) <= MAX_TERMS:
            raise ValueError(f"aggregate {a}: need 1..{MAX_TERMS} terms, got {len(terms)}")
        for t, term in enumerate(terms):
            kind = _TERM_CODES.get(term[0])
            if kind is None:
                raise ValueError(f"unknown term kind {term[0]!r}")
            ops[a, 2 * t] = kind
            ops[a, 2 * t + 1] = int(term[1])
            if kind in (TERM_LE, TERM_GT):
                consts[a, t] = float(term[2])
    return jnp.asarray(ops), jnp.asarray(consts)


# ---------------------------------------------------------------------------
def _eval_mask(pred_ops_ref, pred_consts_ref, cols_ref, num_preds: int, prog=None):
    """Row mask [1, bn] from the SMEM predicate program (all preds ANDed).

    ``prog`` indexes the program slot of a batched ``[B, K, 2]`` constants
    table (the multi-program dispatch path); ``None`` reads the flat
    ``[K, 2]`` layout.
    """
    bn = cols_ref.shape[1]
    mask = jnp.ones((1, bn), jnp.bool_)
    for k in range(num_preds):
        kind = pred_ops_ref[k, 0]
        a = pred_ops_ref[k, 1]
        b = pred_ops_ref[k, 2]
        if prog is None:
            lo = pred_consts_ref[k, 0]
            hi = pred_consts_ref[k, 1]
        else:
            lo = pred_consts_ref[prog, k, 0]
            hi = pred_consts_ref[prog, k, 1]
        ca = cols_ref[pl.ds(a, 1), :]
        cb = cols_ref[pl.ds(b, 1), :]
        in_range = (ca >= lo) & (ca < hi)
        mask &= jnp.where(kind == PRED_RANGE, in_range, ca < cb)
    return mask


def _eval_terms(agg_ops_ref, agg_consts_ref, cols_ref, a: int, prog=None):
    """Per-row value [1, bn] of aggregate ``a``: the product of its terms."""
    bn = cols_ref.shape[1]
    val = jnp.ones((1, bn), jnp.float32)
    for t in range(MAX_TERMS):
        mode = agg_ops_ref[a, 2 * t]
        col = agg_ops_ref[a, 2 * t + 1]
        const = agg_consts_ref[a, t] if prog is None else agg_consts_ref[prog, a, t]
        c = cols_ref[pl.ds(col, 1), :].astype(jnp.float32)
        term = jnp.where(mode == TERM_COL, c, 1.0)
        term = jnp.where(mode == TERM_ONE_MINUS, 1.0 - c, term)
        term = jnp.where(mode == TERM_ONE_PLUS, 1.0 + c, term)
        term = jnp.where(mode == TERM_LE, (c <= const).astype(jnp.float32), term)
        term = jnp.where(mode == TERM_GT, (c > const).astype(jnp.float32), term)
        val = val * term
    return val


def _kernel(
    pred_ops_ref,
    pred_consts_ref,
    agg_ops_ref,
    agg_consts_ref,
    cols_ref,
    keys_ref,
    out_ref,
    *,
    num_groups: int,
    num_preds: int,
    num_aggs: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bn = cols_ref.shape[1]
    maskf = _eval_mask(pred_ops_ref, pred_consts_ref, cols_ref, num_preds).astype(jnp.float32)

    # Masked one-hot group membership [G, bn]; padded rows carry key -1 and
    # match no row of the iota, so they vanish from every group.
    keys = keys_ref[...]  # [1, bn] i32
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (num_groups, bn), 0)
    onehot = (group_ids == keys).astype(jnp.float32) * maskf

    # Per-row aggregate values [A + 1, bn]; the trailing row of ones becomes
    # the per-group masked count through the same matmul.
    rows = [
        _eval_terms(agg_ops_ref, agg_consts_ref, cols_ref, a) for a in range(num_aggs)
    ]
    rows.append(jnp.ones((1, bn), jnp.float32))
    vals = jnp.concatenate(rows, axis=0)

    # [G, bn] x [A+1, bn]^T -> [G, A+1]: the whole grouped aggregation for
    # this block in one MXU pass, accumulated into the revisited output tile.
    upd = jax.lax.dot_general(
        onehot, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += jnp.pad(upd, ((0, 0), (0, LANES - (num_aggs + 1))))


def group_filter_agg(
    cols: jax.Array,  # [C, N] f32 column block
    keys: jax.Array,  # [1, N] i32 dictionary-coded group ids (may be -1 = pad)
    pred_ops: jax.Array,  # [K, 3] i32 predicate program
    pred_consts: jax.Array,  # [K, 2] f32
    agg_ops: jax.Array,  # [A, 2*MAX_TERMS] i32 aggregate program
    agg_consts: jax.Array,  # [A, MAX_TERMS] f32
    *,
    num_groups: int,
    block_n: int = 16384,
    interpret: bool = False,
) -> jax.Array:
    """Returns [num_groups, A + 1] f32: per-group aggregate sums + count."""
    _, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    num_preds = pred_ops.shape[0]
    num_aggs = agg_ops.shape[0]
    assert num_aggs + 1 <= LANES, num_aggs
    assert num_groups >= 1

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            num_groups=num_groups,
            num_preds=num_preds,
            num_aggs=num_aggs,
        ),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((cols.shape[0], bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_groups, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, LANES), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(pred_ops, pred_consts, agg_ops, agg_consts, cols, keys)
    return out[:, : num_aggs + 1]


# ---------------------------------------------------------------------------
# Multi-program dispatch: B constant sets, one HBM pass (scan sharing).
def _kernel_multi(
    pred_ops_ref,
    pred_consts_ref,  # [B, K, 2] SMEM — per-program predicate constants
    agg_ops_ref,
    agg_consts_ref,  # [B, A, MAX_TERMS] SMEM — per-program term constants
    cols_ref,
    keys_ref,
    out_ref,  # [1, G, LANES] block of the [B, G, LANES] output
    *,
    num_groups: int,
    num_preds: int,
    num_aggs: int,
):
    i = pl.program_id(0)  # data block (outer grid dim)
    b = pl.program_id(1)  # program slot (inner grid dim)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bn = cols_ref.shape[1]
    maskf = _eval_mask(
        pred_ops_ref, pred_consts_ref, cols_ref, num_preds, prog=b
    ).astype(jnp.float32)
    keys = keys_ref[...]
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (num_groups, bn), 0)
    onehot = (group_ids == keys).astype(jnp.float32) * maskf
    rows = [
        _eval_terms(agg_ops_ref, agg_consts_ref, cols_ref, a, prog=b)
        for a in range(num_aggs)
    ]
    rows.append(jnp.ones((1, bn), jnp.float32))
    vals = jnp.concatenate(rows, axis=0)
    upd = jax.lax.dot_general(
        onehot, vals, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += jnp.pad(upd, ((0, 0), (0, LANES - (num_aggs + 1))))[None]


def group_filter_agg_multi(
    cols: jax.Array,  # [C, N] f32 column block — scanned ONCE for all programs
    keys: jax.Array,  # [1, N] i32 dictionary-coded group ids (may be -1 = pad)
    pred_ops: jax.Array,  # [K, 3] i32 predicate program, shared across the batch
    pred_consts: jax.Array,  # [B, K, 2] f32 per-program predicate constants
    agg_ops: jax.Array,  # [A, 2*MAX_TERMS] i32 aggregate program, shared
    agg_consts: jax.Array,  # [B, A, MAX_TERMS] f32 per-program term constants
    *,
    num_groups: int,
    block_n: int = 16384,
    interpret: bool = False,
) -> jax.Array:
    """Scan-shared batch of ``group_filter_agg``: B programs, one HBM pass.

    All programs share one opcode structure (same query shape) but carry
    their own constants — N concurrent q6 requests with different predicate
    bounds become one kernel invocation.  The grid is ``(blocks, B)`` with
    the program slot innermost: each ``[C, bn]`` column block's index map is
    constant across the inner dimension, so Pallas keeps the block resident
    in VMEM while every program runs over it, and HBM sees each row exactly
    once regardless of B.  Per program the block-accumulation order is
    identical to the single-program kernel, so ``out[b]`` is bit-equal to
    ``group_filter_agg(..., pred_consts[b], ..., agg_consts[b], ...)``.

    Returns ``[B, num_groups, A + 1]`` f32.
    """
    _, n = cols.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    num_progs = pred_consts.shape[0]
    assert agg_consts.shape[0] == num_progs, (pred_consts.shape, agg_consts.shape)
    num_preds = pred_ops.shape[0]
    num_aggs = agg_ops.shape[0]
    assert num_aggs + 1 <= LANES, num_aggs
    assert num_groups >= 1

    out = pl.pallas_call(
        functools.partial(
            _kernel_multi,
            num_groups=num_groups,
            num_preds=num_preds,
            num_aggs=num_aggs,
        ),
        grid=(n // bn, num_progs),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((cols.shape[0], bn), lambda i, b: (0, i)),
            pl.BlockSpec((1, bn), lambda i, b: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, num_groups, LANES), lambda i, b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_progs, num_groups, LANES), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(pred_ops, pred_consts, agg_ops, agg_consts, cols, keys)
    return out[:, :, : num_aggs + 1]
