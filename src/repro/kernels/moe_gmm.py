"""Grouped (per-expert) matmul kernel: [E, C, d] x [E, d, f] -> [E, C, f].

Classic tiled matmul with an expert (group) grid dim: grid
(E, C/bc, F/bf, D/bd), the contraction dim innermost with a f32 VMEM
accumulator. Tile defaults (bc, bf, bd) = (256, 256, 512) keep
256x512 + 512x256 operand tiles + 256x256 acc ~= 0.9 MB in VMEM and all
MXU dims at multiples of 128.

This is the expert-FFN hot loop for the MoE archs (kimi-k2: E=384 experts
of [7168 -> 2048]); the dispatch scatter/gather stays in XLA where the SPMD
partitioner can fuse it with the surrounding collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 - dtype/memory-space helpers
from repro.kernels.compat import CompilerParams


def _kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, nd):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[0, :, :],
        rhs_ref[0, :, :],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == nd - 1)
    def _flush():
        out_ref[0, :, :] = acc_ref[...].astype(out_ref.dtype)


def gmm(
    lhs: jax.Array,  # [E, C, d]
    rhs: jax.Array,  # [E, d, f]
    *,
    block_c: int = 256,
    block_f: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = lhs.shape
    _, _, f = rhs.shape
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (lhs.shape, rhs.shape, (bc, bf, bd))
    nd = d // bd

    grid = (e, c // bc, f // bf, nd)
    return pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, ci, fi, di: (e_, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e_, ci, fi, di: (e_, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, ci, fi, di: (e_, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lhs, rhs)
