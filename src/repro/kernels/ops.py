"""Jit'd public wrappers over the Pallas kernels.

Each op auto-selects interpret mode off-TPU (the kernels VALIDATE on CPU via
the interpreter and TARGET TPU), pads awkward shapes up to tile multiples,
and exposes a `use_pallas=False` escape hatch that routes to the ref oracle
— the models use that flag so CPU smoke tests and TPU runs share one code
path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.filter_scan import filter_agg as _filter_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.moe_gmm import gmm as _gmm_kernel
from repro.kernels.ssd_scan import ssd_intra as _ssd_kernel


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads), n


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "use_pallas"))
def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 512, block_k: int = 512,
    use_pallas: bool = True,
):
    """[B, Sq, Hq, dh] x [B, Sk, Hkv, dh]^2 -> [B, Sq, Hq, dh]."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    if q.shape[1] % bq or k.shape[1] % bk:  # ragged tails -> oracle
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_kernel(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("block_k", "use_pallas"))
def decode_attention(q, k, v, kv_len, *, block_k: int = 512, use_pallas: bool = True):
    """q [B, Hq, dh], cache [B, S, Hkv, dh], kv_len [B] -> [B, Hq, dh]."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, kv_len)
    k_p, s0 = _pad_to(k, 1, min(block_k, k.shape[1]))
    v_p, _ = _pad_to(v, 1, min(block_k, v.shape[1]))
    return _decode_kernel(
        q, k_p, v_p, kv_len.astype(jnp.int32), block_k=block_k, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_intra(x, bmat, cmat, dt, a, *, chunk: int = 128, use_pallas: bool = True):
    """Intra-chunk SSD; see kernels/ssd_scan.py. Falls back to a vmapped oracle."""
    if not use_pallas:
        b, s, h, p = x.shape
        q = min(chunk, s)
        nc = s // q
        xr = x.reshape(b * nc, q, h, p) if False else None  # noqa - clarity below
        def one(args):
            xc, bc, cc, dtc = args
            return ref.ssd_intra_ref(xc[None], bc[None], cc[None], dtc[None], a)
        ys, sts = [], []
        for c in range(nc):
            sl = slice(c * q, (c + 1) * q)
            y, st = ref.ssd_intra_ref(x[:, sl], bmat[:, sl], cmat[:, sl], dt[:, sl], a)
            ys.append(y)
            sts.append(st)
        return jnp.concatenate(ys, 1), jnp.stack(sts, 1)
    return _ssd_kernel(x, bmat, cmat, dt, a, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "use_pallas"))
def gmm(lhs, rhs, *, block_c: int = 256, block_f: int = 256, block_d: int = 512,
        use_pallas: bool = True):
    """[E, C, d] x [E, d, f] -> [E, C, f]."""
    if not use_pallas:
        return ref.gmm_ref(lhs, rhs)
    e, c, d = lhs.shape
    f = rhs.shape[-1]
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    if c % bc or f % bf or d % bd:
        return ref.gmm_ref(lhs, rhs)
    return _gmm_kernel(lhs, rhs, block_c=bc, block_f=bf, block_d=bd, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_n", "use_pallas"))
def filter_agg(cols, lo, hi, lo2, hi2, *, block_n: int = 16384, use_pallas: bool = True):
    """Fused filter+aggregate; returns [2] (sum, count)."""
    if not use_pallas:
        return ref.filter_agg_ref(cols, lo, hi, lo2, hi2)
    cols_p, n0 = _pad_to(cols, 1, min(block_n, cols.shape[1]))
    if cols_p.shape != cols.shape:
        # padded rows must fail the predicate: fill filter cols with +inf
        pad = cols_p.shape[1] - cols.shape[1]
        filler = jnp.full((4, pad), jnp.finfo(jnp.float32).max, cols.dtype)
        cols_p = jnp.concatenate([cols, filler], axis=1)
    return _filter_kernel(cols_p, lo, hi, lo2, hi2, block_n=block_n, interpret=_interpret())
