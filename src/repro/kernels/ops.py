"""Jit'd public wrappers over the Pallas kernels.

Each op auto-selects interpret mode off-TPU (the kernels VALIDATE on CPU via
the interpreter and TARGET TPU), pads awkward shapes up to tile multiples,
and exposes a `use_pallas=False` escape hatch that routes to the ref oracle
— the models use that flag so CPU smoke tests and TPU runs share one code
path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_compact import SUB as _COMPACT_SUB
from repro.kernels.block_compact import block_compact as _compact_kernel
from repro.kernels.block_compact import (
    stream_chunk as _stream_chunk,
    stream_finalize as _stream_finalize,
    stream_init as _stream_init,
)
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.filter_scan import filter_agg as _filter_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.group_filter_agg import group_filter_agg as _group_kernel
from repro.kernels.group_filter_agg import group_filter_agg_multi as _group_multi_kernel
from repro.kernels.moe_gmm import gmm as _gmm_kernel
from repro.kernels.ssd_scan import ssd_intra as _ssd_kernel


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads), n


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "use_pallas"))
def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 512, block_k: int = 512,
    use_pallas: bool = True,
):
    """[B, Sq, Hq, dh] x [B, Sk, Hkv, dh]^2 -> [B, Sq, Hq, dh]."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    if q.shape[1] % bq or k.shape[1] % bk:  # ragged tails -> oracle
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_kernel(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("block_k", "use_pallas"))
def decode_attention(q, k, v, kv_len, *, block_k: int = 512, use_pallas: bool = True):
    """q [B, Hq, dh], cache [B, S, Hkv, dh], kv_len [B] -> [B, Hq, dh]."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, kv_len)
    k_p, s0 = _pad_to(k, 1, min(block_k, k.shape[1]))
    v_p, _ = _pad_to(v, 1, min(block_k, v.shape[1]))
    return _decode_kernel(
        q, k_p, v_p, kv_len.astype(jnp.int32), block_k=block_k, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_intra(x, bmat, cmat, dt, a, *, chunk: int = 128, use_pallas: bool = True):
    """Intra-chunk SSD; see kernels/ssd_scan.py. Falls back to a vmapped oracle."""
    if not use_pallas:
        _, s, _, _ = x.shape
        q = min(chunk, s)
        nc = s // q
        ys, sts = [], []
        for c in range(nc):
            sl = slice(c * q, (c + 1) * q)
            y, st = ref.ssd_intra_ref(x[:, sl], bmat[:, sl], cmat[:, sl], dt[:, sl], a)
            ys.append(y)
            sts.append(st)
        return jnp.concatenate(ys, 1), jnp.stack(sts, 1)
    return _ssd_kernel(x, bmat, cmat, dt, a, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "use_pallas"))
def gmm(lhs, rhs, *, block_c: int = 256, block_f: int = 256, block_d: int = 512,
        use_pallas: bool = True):
    """[E, C, d] x [E, d, f] -> [E, C, f]."""
    if not use_pallas:
        return ref.gmm_ref(lhs, rhs)
    e, c, d = lhs.shape
    f = rhs.shape[-1]
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    if c % bc or f % bf or d % bd:
        return ref.gmm_ref(lhs, rhs)
    return _gmm_kernel(lhs, rhs, block_c=bc, block_f=bf, block_d=bd, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_n", "use_pallas"))
def filter_agg(cols, lo, hi, lo2, hi2, *, block_n: int = 16384, use_pallas: bool = True):
    """Fused filter+aggregate; returns [2] (sum, count)."""
    if not use_pallas:
        return ref.filter_agg_ref(cols, lo, hi, lo2, hi2)
    cols_p, n0 = _pad_to(cols, 1, min(block_n, cols.shape[1]))
    if cols_p.shape != cols.shape:
        # padded rows must fail the predicate: fill filter cols with +inf
        pad = cols_p.shape[1] - cols.shape[1]
        filler = jnp.full((cols.shape[0], pad), jnp.finfo(jnp.float32).max, cols.dtype)
        cols_p = jnp.concatenate([cols, filler], axis=1)
    return _filter_kernel(cols_p, lo, hi, lo2, hi2, block_n=block_n, interpret=_interpret())


@functools.partial(
    jax.jit, static_argnames=("num_groups", "block_n", "use_pallas")
)
def group_filter_agg(
    cols, keys, pred_ops, pred_consts, agg_ops, agg_consts, *,
    num_groups: int, block_n: int = 16384, use_pallas: bool = True,
):
    """Single-pass grouped filter+aggregate over a [C, N] column block.

    ``pred_ops``/``pred_consts``/``agg_ops``/``agg_consts`` encode the
    predicate and aggregate programs (see kernels/group_filter_agg.py —
    ``encode_predicates`` / ``encode_aggregates`` build them).  Returns
    [num_groups, A + 1]: per-group aggregate sums, then the masked count.
    """
    if not use_pallas:
        return ref.group_filter_agg_ref(
            cols, keys, pred_ops, pred_consts, agg_ops, agg_consts, num_groups
        )
    keys = keys.reshape(1, -1).astype(jnp.int32)
    n = cols.shape[1]
    bn = min(block_n, n)
    target = -(-n // bn) * bn
    if target != n:
        # Padded rows carry key -1: they match no group regardless of what
        # the predicate program evaluates to on the zero-filled columns.
        cols = jnp.pad(cols, ((0, 0), (0, target - n)))
        keys = jnp.pad(keys, ((0, 0), (0, target - n)), constant_values=-1)
    return _group_kernel(
        cols, keys, pred_ops, pred_consts, agg_ops, agg_consts,
        num_groups=num_groups, block_n=bn, interpret=_interpret(),
    )


@functools.partial(
    jax.jit, static_argnames=("num_groups", "block_n", "use_pallas")
)
def group_filter_agg_multi(
    cols, keys, pred_ops, pred_consts, agg_ops, agg_consts, *,
    num_groups: int, block_n: int = 16384, use_pallas: bool = True,
):
    """Scan-shared batch of ``group_filter_agg``: B constant sets, one pass.

    ``pred_consts``/``agg_consts`` carry a leading program dimension
    (``[B, K, 2]`` / ``[B, A, MAX_TERMS]``) and are *traced inputs*, not
    trace-time constants — one compiled executable serves any predicate
    bounds of the same query shape.  Returns ``[B, num_groups, A + 1]``;
    slot ``b`` is bit-equal to the single-program call with that program's
    constants (same block-accumulation order).
    """
    if not use_pallas:
        return ref.group_filter_agg_multi_ref(
            cols, keys, pred_ops, pred_consts, agg_ops, agg_consts, num_groups
        )
    keys = keys.reshape(1, -1).astype(jnp.int32)
    n = cols.shape[1]
    bn = min(block_n, n)
    target = -(-n // bn) * bn
    if target != n:
        # Same padding contract as the single-program wrapper: key -1
        # matches no group, so padded rows vanish from every program.
        cols = jnp.pad(cols, ((0, 0), (0, target - n)))
        keys = jnp.pad(keys, ((0, 0), (0, target - n)), constant_values=-1)
    return _group_multi_kernel(
        cols, keys, pred_ops, pred_consts, agg_ops, agg_consts,
        num_groups=num_groups, block_n=bn, interpret=_interpret(),
    )


#: VMEM the resident block_compact may spend on its [C, cap + SUB] output
#: before ``stream="auto"`` switches to the HBM-streaming variant.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@functools.partial(
    jax.jit, static_argnames=("cap", "block_n", "stream", "chunk_n", "use_pallas")
)
def block_compact(
    cols, mask, cap: int, *,
    block_n: int = 65536,
    stream: str = "auto",
    chunk_n: int = 1 << 21,
    use_pallas: bool = True,
):
    """Compact the masked rows of a [C, N] block into a [C, cap] buffer.

    Returns (out, count): ``out[:, j]`` is the j-th qualifying row for
    ``j < min(count, cap)``, zero beyond; ``count`` is the total mask
    population.  One fused pass instead of ``nonzero`` + per-column gather.

    ``stream`` picks the kernel variant: ``"never"`` is the VMEM-resident
    kernel (cap bounded by :data:`VMEM_BUDGET_BYTES`), ``"always"`` the
    HBM-streaming kernel (cap bounded by HBM), and ``"auto"`` (default)
    streams exactly when the resident output would blow the budget — so
    callers never lose the small-cap fast path.  Streamed inputs longer
    than ``chunk_n`` rows are split across kernel invocations with the
    offset/count state carried between calls (the chunked driver).
    """
    if not use_pallas:
        return ref.block_compact_ref(cols, mask, cap)
    mask = (mask.reshape(1, -1) != 0).astype(jnp.int32)
    c, n = cols.shape
    # Blocks must hold whole sub-tiles; pad the tail with mask=0 rows.
    bn = min(-(-block_n // _COMPACT_SUB) * _COMPACT_SUB,
             -(-n // _COMPACT_SUB) * _COMPACT_SUB)
    target = -(-n // bn) * bn
    if target != n:
        cols = jnp.pad(cols, ((0, 0), (0, target - n)))
        mask = jnp.pad(mask, ((0, 0), (0, target - n)))
    if stream == "auto":
        resident_bytes = c * (cap + _COMPACT_SUB) * 4
        stream = "always" if resident_bytes > VMEM_BUDGET_BYTES else "never"
    if stream == "never":
        return _compact_kernel(cols, mask, cap, block_n=bn, interpret=_interpret())
    if stream != "always":
        raise ValueError(f"stream must be auto/always/never, got {stream!r}")
    # Chunked driver: one streaming-kernel invocation per chunk_n rows, the
    # (out, state, carry) triple threaded through input_output_aliases so
    # every chunk lands in one HBM allocation.
    cn = max(bn, (chunk_n // bn) * bn)
    state = _stream_init(c, cap)
    for s in range(0, target, cn):
        e = min(s + cn, target)
        state = _stream_chunk(
            state, cols[:, s:e], mask[:, s:e], cap,
            block_n=bn, interpret=_interpret(),
        )
    return _stream_finalize(state, cap)
