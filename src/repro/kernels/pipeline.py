"""Double-buffered VMEM->HBM DMA emit pipeline (manual async copies).

Pallas pipelines *inputs* for free (BlockSpec index maps), but kernels whose
output lives in HBM (``pltpu.ANY`` memory space) must move every result tile
themselves.  The naive way — compute a tile, DMA it, wait, compute the next —
serializes the store path behind compute.  This module packages the standard
double-buffering discipline so every out-of-VMEM kernel in the repo shares
one implementation (``block_compact``'s streaming variant is the first user;
the planned HBM-streaming ``group_filter_agg`` is written against the same
surface):

  * a staging scratch of :data:`NBUF` tile slots lives in VMEM, flat-packed
    as ``[NBUF * rows, width]`` (dynamic indexing on the second-minor axis
    lowers on TPU; a leading buffer axis may not);
  * :func:`emit_tile` stages tile ``seq`` into slot ``seq % NBUF`` and
    starts its async copy — the DMA of tile ``seq`` is in flight while the
    caller computes tile ``seq + 1``, which is the whole point;
  * re-staging a slot first waits for the DMA launched :data:`NBUF`
    emissions ago, so a slot is never overwritten under an active copy;
  * :func:`drain` settles every outstanding copy — call it before the
    kernel (or grid step) ends, since scratch DMA semaphores must read
    zero when the kernel completes.

Semaphore-wait fine print: ``make_async_copy(...).wait()`` decrements the
semaphore by the descriptor's *size*, so waits are reconstructed with the
current slot's source slice and ANY same-shaped destination slice — the wait
does not need to name the exact destination the in-flight copy targeted.
Every helper here relies on that, which is why a pipeline must emit
same-shaped tiles throughout its lifetime.

Usage sketch (inside a kernel body)::

    # pallas_call(..., scratch_shapes=[*emit_slots(c, w), ...])
    def kernel(..., out_hbm_ref, stage_ref, sem_ref):
        def step(seq, ...):
            tile = ...                              # [c, w] in registers
            emit_tile(stage_ref, sem_ref, seq, tile,
                      out_hbm_ref.at[:, pl.ds(seq * w, w)])
            return seq + 1
        seq = ...loop over step...
        drain(stage_ref, sem_ref, seq, out_hbm_ref.at[:, pl.ds(0, w)])

``emit_tile`` is side-effecting only — callers own the ``seq`` counter (a
traced i32) and advance it themselves, which keeps the helper usable under
``pl.when`` for conditional emission (advance ``seq`` with ``jnp.where`` on
the same predicate).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Staging depth.  Two slots give full overlap of one in-flight DMA with one
#: tile of compute; deeper buffers only help when compute per tile is far
#: cheaper than the copy, which none of our emitters are.
NBUF = 2

#: f32 sublane granule: slot strides are padded to it so the dynamic
#: second-minor offsets (``slot * stride``) stay aligned on TPU.
_SUBLANE = 8


def _stride(rows: int) -> int:
    return -(-rows // _SUBLANE) * _SUBLANE


def emit_slots(rows: int, width: int, dtype) -> tuple:
    """The two ``scratch_shapes`` entries an emit pipeline needs.

    Returns ``(vmem_stage, dma_semaphores)`` for a ``[rows, width]`` tile
    shape: a flat ``[NBUF * stride, width]`` staging buffer (``stride`` =
    ``rows`` padded to the sublane granule) plus one DMA semaphore per
    slot.  Splat into ``pallas_call(scratch_shapes=[...])`` and pass the
    resulting two refs to :func:`emit_tile` / :func:`drain`.
    """
    return (
        pltpu.VMEM((NBUF * _stride(rows), width), dtype),
        pltpu.SemaphoreType.DMA((NBUF,)),
    )


def _slot_rows(stage_ref, slot, rows: int):
    stride = stage_ref.shape[0] // NBUF
    return stage_ref.at[pl.ds(slot * stride, rows), :]


def emit_tile(stage_ref, sem_ref, seq, tile, dst) -> None:
    """Stage ``tile`` (emission number ``seq``) and start its DMA to ``dst``.

    ``seq`` is the caller-owned emission counter (traced i32, starting at
    0); ``dst`` is a ref slice with ``tile``'s exact shape.  If the slot is
    being reused (``seq >= NBUF``) the copy launched ``NBUF`` emissions ago
    is waited first.  Side-effecting only: safe under ``pl.when``; the
    caller advances ``seq`` itself.
    """
    rows = tile.shape[0]
    slot = jax.lax.rem(seq, NBUF)
    src = _slot_rows(stage_ref, slot, rows)

    @pl.when(seq >= NBUF)
    def _settle_previous():
        pltpu.make_async_copy(src, dst, sem_ref.at[slot]).wait()

    stage_ref[pl.ds(slot * (stage_ref.shape[0] // NBUF), rows), :] = tile
    pltpu.make_async_copy(src, dst, sem_ref.at[slot]).start()


def drain(stage_ref, sem_ref, seq, dst_like) -> None:
    """Wait for every copy still in flight after ``seq`` total emissions.

    ``dst_like`` is any destination slice of the pipeline's tile shape (the
    wait only uses its size — see the module docstring).  Must run before
    the kernel or grid step finishes so no scratch semaphore is left armed.
    """
    rows = dst_like.shape[0]
    for k in range(NBUF):

        @pl.when(seq > k)
        def _settle(k=k):
            slot = jax.lax.rem(seq - 1 - k, NBUF)
            pltpu.make_async_copy(
                _slot_rows(stage_ref, slot, rows), dst_like, sem_ref.at[slot]
            ).wait()
