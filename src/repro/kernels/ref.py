"""Pure-jnp oracles for every Pallas kernel. The kernels must match these
(assert_allclose in tests/test_kernels.py over shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
def flash_attention_ref(
    q: jax.Array,  # [B, Sq, Hq, dh]
    k: jax.Array,  # [B, Sk, Hkv, dh]
    v: jax.Array,  # [B, Sk, Hkv, dh]
    *,
    causal: bool = True,
) -> jax.Array:
    """f32 softmax attention with GQA head grouping. Output [B, Sq, Hq, dh]."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    s = s * (dh**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool), k.shape[1] - sq)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, dh).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # [B, Hq, dh] — one query token per sequence
    k: jax.Array,  # [B, S, Hkv, dh]
    v: jax.Array,  # [B, S, Hkv, dh]
    kv_len: jax.Array,  # [B] int32 — valid cache length per sequence
) -> jax.Array:
    b, hq, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32)
    scores = scores * (dh**-0.5)
    valid = jnp.arange(s)[None] < kv_len[:, None]  # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
def ssd_intra_ref(
    x: jax.Array,  # [B, Q, H, P] — one chunk
    bmat: jax.Array,  # [B, Q, N]
    cmat: jax.Array,  # [B, Q, N]
    dt: jax.Array,  # [B, Q, H] (post-softplus, f32)
    a: jax.Array,  # [H] (negative)
) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD: returns (y [B, Q, H, P] f32, chunk_state [B, H, P, N] f32)."""
    bsz, q, h, p = x.shape
    dta = dt.astype(jnp.float32) * a.astype(jnp.float32)  # [B,Q,H]
    lcum = jnp.cumsum(dta, axis=1)
    l_last = lcum[:, -1]  # [B,H]
    cb = jnp.einsum("bqn,bkn->bqk", cmat, bmat, preferred_element_type=jnp.float32)
    decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # [B,Q,K,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, :, :, None], decay, 0.0)
    m = cb[..., None] * decay * dt[:, None, :, :].astype(jnp.float32)  # [B,Q,K,H]
    y = jnp.einsum("bqkh,bkhp->bqhp", m, x.astype(jnp.float32))
    seg = jnp.exp(l_last[:, None, :] - lcum) * dt.astype(jnp.float32)  # [B,Q,H]
    state = jnp.einsum("bkh,bkn,bkhp->bhpn", seg, bmat.astype(jnp.float32), x.astype(jnp.float32))
    return y, state


# ---------------------------------------------------------------------------
def gmm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    """Grouped (per-expert) matmul: [E, C, d] x [E, d, f] -> [E, C, f]."""
    return jnp.einsum("ecd,edf->ecf", lhs, rhs, preferred_element_type=jnp.float32).astype(
        lhs.dtype
    )


# ---------------------------------------------------------------------------
def _program_mask(cols: jax.Array, pred_ops: jax.Array, pred_consts: jax.Array) -> jax.Array:
    """Row mask [N] from a group_filter_agg predicate program."""
    n = cols.shape[1]
    mask = jnp.ones((n,), bool)
    for k in range(pred_ops.shape[0]):
        kind, a, b = pred_ops[k, 0], pred_ops[k, 1], pred_ops[k, 2]
        lo, hi = pred_consts[k, 0], pred_consts[k, 1]
        ca = jax.lax.dynamic_index_in_dim(cols, a, 0, keepdims=False)
        cb = jax.lax.dynamic_index_in_dim(cols, b, 0, keepdims=False)
        mask &= jnp.where(kind == 0, (ca >= lo) & (ca < hi), ca < cb)
    return mask


def _program_values(cols: jax.Array, agg_ops: jax.Array, agg_consts: jax.Array) -> jax.Array:
    """Per-row aggregate values [A, N] from a group_filter_agg term program."""
    num_aggs, n = agg_ops.shape[0], cols.shape[1]
    max_terms = agg_consts.shape[1]
    vals = []
    for a in range(num_aggs):
        v = jnp.ones((n,), jnp.float32)
        for t in range(max_terms):
            mode, col = agg_ops[a, 2 * t], agg_ops[a, 2 * t + 1]
            const = agg_consts[a, t]
            c = jax.lax.dynamic_index_in_dim(cols, col, 0, keepdims=False)
            c = c.astype(jnp.float32)
            term = jnp.where(mode == 1, c, 1.0)
            term = jnp.where(mode == 2, 1.0 - c, term)
            term = jnp.where(mode == 3, 1.0 + c, term)
            term = jnp.where(mode == 4, (c <= const).astype(jnp.float32), term)
            term = jnp.where(mode == 5, (c > const).astype(jnp.float32), term)
            v = v * term
        vals.append(v)
    return jnp.stack(vals)


def group_filter_agg_ref(
    cols: jax.Array,  # [C, N] f32
    keys: jax.Array,  # [1, N] or [N] i32 group ids (negative = dropped)
    pred_ops: jax.Array,  # [K, 3] i32 — see kernels/group_filter_agg.py
    pred_consts: jax.Array,  # [K, 2] f32
    agg_ops: jax.Array,  # [A, 2*MAX_TERMS] i32
    agg_consts: jax.Array,  # [A, MAX_TERMS] f32
    num_groups: int,
) -> jax.Array:
    """Fused grouped filter+aggregate oracle.  Returns [G, A + 1] f32:
    per-group masked aggregate sums, then the masked row count."""
    keys = keys.reshape(-1)
    w = _program_mask(cols, pred_ops, pred_consts).astype(jnp.float32)
    # Out-of-range keys (the wrapper's -1 padding) must contribute nothing.
    w = w * ((keys >= 0) & (keys < num_groups)).astype(jnp.float32)
    seg_keys = jnp.clip(keys, 0, num_groups - 1)
    vals = _program_values(cols, agg_ops, agg_consts)
    parts = [
        jax.ops.segment_sum(vals[a] * w, seg_keys, num_segments=num_groups)
        for a in range(agg_ops.shape[0])
    ]
    parts.append(jax.ops.segment_sum(w, seg_keys, num_segments=num_groups))
    return jnp.stack(parts, axis=1)


def group_filter_agg_multi_ref(
    cols: jax.Array,  # [C, N] f32
    keys: jax.Array,  # [1, N] or [N] i32
    pred_ops: jax.Array,  # [K, 3] i32, shared across programs
    pred_consts: jax.Array,  # [B, K, 2] f32 per-program constants
    agg_ops: jax.Array,  # [A, 2*MAX_TERMS] i32, shared
    agg_consts: jax.Array,  # [B, A, MAX_TERMS] f32 per-program constants
    num_groups: int,
) -> jax.Array:
    """Scan-shared multi-program oracle: per program slot, exactly the
    single-program oracle.  Returns [B, G, A + 1] f32."""
    return jnp.stack(
        [
            group_filter_agg_ref(
                cols, keys, pred_ops, pred_consts[b], agg_ops, agg_consts[b], num_groups
            )
            for b in range(pred_consts.shape[0])
        ]
    )


def block_compact_ref(
    cols: jax.Array,  # [C, N] f32
    mask: jax.Array,  # [1, N] or [N] — nonzero selects the row
    cap: int,
) -> tuple[jax.Array, jax.Array]:
    """Compaction oracle: (out [C, cap] with the first min(count, cap)
    qualifying rows then zeros, total count).  Matches engine.ops.compact's
    nonzero+gather semantics."""
    mask = mask.reshape(-1) != 0
    n = mask.shape[0]
    idx = jnp.nonzero(mask, size=cap, fill_value=n)[0]
    in_range = idx < n
    safe = jnp.where(in_range, idx, 0)
    out = jnp.take(cols, safe, axis=1)
    out = jnp.where(in_range[None, :], out, 0.0)
    return out, jnp.sum(mask.astype(jnp.int32))


# ---------------------------------------------------------------------------
def filter_agg_ref(
    cols: jax.Array,  # [4, N] f32: (key, lo-col, hi-col, value) layout per op
    lo: jax.Array,  # scalar predicate bounds on cols[0]
    hi: jax.Array,
    lo2: jax.Array,  # bounds on cols[1]
    hi2: jax.Array,
) -> jax.Array:
    """Fused scan+filter+aggregate (TPC-H Q6 pattern):
    sum(cols[2] * cols[3]) where lo <= cols[0] < hi and lo2 <= cols[1] < hi2.
    Returns [2]: (sum, count)."""
    c0, c1, c2, c3 = cols
    mask = (c0 >= lo) & (c0 < hi) & (c1 >= lo2) & (c1 < hi2)
    s = jnp.sum(jnp.where(mask, c2.astype(jnp.float32) * c3.astype(jnp.float32), 0.0))
    n = jnp.sum(mask.astype(jnp.float32))
    return jnp.stack([s, n])
