"""Pure-jnp oracles for every Pallas kernel. The kernels must match these
(assert_allclose in tests/test_kernels.py over shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
def flash_attention_ref(
    q: jax.Array,  # [B, Sq, Hq, dh]
    k: jax.Array,  # [B, Sk, Hkv, dh]
    v: jax.Array,  # [B, Sk, Hkv, dh]
    *,
    causal: bool = True,
) -> jax.Array:
    """f32 softmax attention with GQA head grouping. Output [B, Sq, Hq, dh]."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    s = s * (dh**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool), k.shape[1] - sq)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, dh).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # [B, Hq, dh] — one query token per sequence
    k: jax.Array,  # [B, S, Hkv, dh]
    v: jax.Array,  # [B, S, Hkv, dh]
    kv_len: jax.Array,  # [B] int32 — valid cache length per sequence
) -> jax.Array:
    b, hq, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32)
    scores = scores * (dh**-0.5)
    valid = jnp.arange(s)[None] < kv_len[:, None]  # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
def ssd_intra_ref(
    x: jax.Array,  # [B, Q, H, P] — one chunk
    bmat: jax.Array,  # [B, Q, N]
    cmat: jax.Array,  # [B, Q, N]
    dt: jax.Array,  # [B, Q, H] (post-softplus, f32)
    a: jax.Array,  # [H] (negative)
) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD: returns (y [B, Q, H, P] f32, chunk_state [B, H, P, N] f32)."""
    bsz, q, h, p = x.shape
    dta = dt.astype(jnp.float32) * a.astype(jnp.float32)  # [B,Q,H]
    lcum = jnp.cumsum(dta, axis=1)
    l_last = lcum[:, -1]  # [B,H]
    cb = jnp.einsum("bqn,bkn->bqk", cmat, bmat, preferred_element_type=jnp.float32)
    decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # [B,Q,K,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, :, :, None], decay, 0.0)
    m = cb[..., None] * decay * dt[:, None, :, :].astype(jnp.float32)  # [B,Q,K,H]
    y = jnp.einsum("bqkh,bkhp->bqhp", m, x.astype(jnp.float32))
    seg = jnp.exp(l_last[:, None, :] - lcum) * dt.astype(jnp.float32)  # [B,Q,H]
    state = jnp.einsum("bkh,bkn,bkhp->bhpn", seg, bmat.astype(jnp.float32), x.astype(jnp.float32))
    return y, state


# ---------------------------------------------------------------------------
def gmm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    """Grouped (per-expert) matmul: [E, C, d] x [E, d, f] -> [E, C, f]."""
    return jnp.einsum("ecd,edf->ecf", lhs, rhs, preferred_element_type=jnp.float32).astype(
        lhs.dtype
    )


# ---------------------------------------------------------------------------
def filter_agg_ref(
    cols: jax.Array,  # [4, N] f32: (key, lo-col, hi-col, value) layout per op
    lo: jax.Array,  # scalar predicate bounds on cols[0]
    hi: jax.Array,
    lo2: jax.Array,  # bounds on cols[1]
    hi2: jax.Array,
) -> jax.Array:
    """Fused scan+filter+aggregate (TPC-H Q6 pattern):
    sum(cols[2] * cols[3]) where lo <= cols[0] < hi and lo2 <= cols[1] < hi2.
    Returns [2]: (sum, count)."""
    c0, c1, c2, c3 = cols
    mask = (c0 >= lo) & (c0 < hi) & (c1 >= lo2) & (c1 < hi2)
    s = jnp.sum(jnp.where(mask, c2.astype(jnp.float32) * c3.astype(jnp.float32), 0.0))
    n = jnp.sum(mask.astype(jnp.float32))
    return jnp.stack([s, n])
