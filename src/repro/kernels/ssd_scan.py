"""SSD intra-chunk kernel (Mamba2), Pallas TPU.

Computes, for one (batch, chunk, head) grid cell:
  y     = (C B^T (.) decay (.) dt) @ x        [Q, P]   (causal within chunk)
  state = x^T-weighted outer sum               [P, N]   (chunk's outgoing state)

dt and the per-step log-decay (dta = dt * A[h]) arrive pre-transposed to
[B, H, S] so the kernel's last-axis tile is the Q chunk (lane-aligned when
Q >= 128; Q=64 chunks still lower, padded). B/C are shared across heads
(ngroups=1), expressed by an index_map that ignores the head coordinate —
Pallas keeps the tile resident in VMEM across the H-inner grid steps.

The inter-chunk recurrence (a [B, H, P, N] running state over nc steps) is
sequential-by-construction and stays as a lax.scan in ops.py; this kernel
covers the O(S·Q·(N+P)) intra-chunk work, which dominates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams


def _kernel(x_ref, b_ref, c_ref, dt_ref, dta_ref, y_ref, st_ref, *, q):
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [Q, P]
    bm = b_ref[0, :, :].astype(jnp.float32)  # [Q, N]
    cm = c_ref[0, :, :].astype(jnp.float32)  # [Q, N]
    dt = dt_ref[0, 0, :].astype(jnp.float32)  # [Q]
    dta = dta_ref[0, 0, :].astype(jnp.float32)  # [Q]

    lcum = jnp.cumsum(dta)  # [Q]
    l_last = lcum[q - 1]

    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # decay(i, j) = exp(lcum_i - lcum_j) for i >= j, else 0
    ldiff = lcum[:, None] - lcum[None, :]
    decay = jnp.where(rows >= cols, jnp.exp(ldiff), 0.0)
    m = cb * decay * dt[None, :]  # [Q, Q]
    y_ref[0, :, 0, :] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    seg = jnp.exp(l_last - lcum) * dt  # [Q]
    xw = x * seg[:, None]  # [Q, P]
    st_ref[0, 0, 0, :, :] = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)  # [P, N]


def ssd_intra(
    x: jax.Array,  # [B, S, H, P]
    bmat: jax.Array,  # [B, S, N]
    cmat: jax.Array,  # [B, S, N]
    dt: jax.Array,  # [B, S, H] f32 (post-softplus)
    a: jax.Array,  # [H] f32 (negative)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, H, P] f32, chunk_states [B, nc, H, P, N] f32)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    dt_t = jnp.moveaxis(dt, -1, 1).astype(jnp.float32)  # [B, H, S]
    dta_t = dt_t * a[None, :, None].astype(jnp.float32)

    grid = (b, nc, h)
    y, st = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b_, c, h_: (b_, c, h_, 0)),
            pl.BlockSpec((1, q, n), lambda b_, c, h_: (b_, c, 0)),
            pl.BlockSpec((1, q, n), lambda b_, c, h_: (b_, c, 0)),
            pl.BlockSpec((1, 1, q), lambda b_, c, h_: (b_, h_, c)),
            pl.BlockSpec((1, 1, q), lambda b_, c, h_: (b_, h_, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b_, c, h_: (b_, c, h_, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda b_, c, h_: (b_, c, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, bmat, cmat, dt_t, dta_t)
    return y, st
