import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: inputs are
ShapeDtypeStructs (zero allocation), `jax.jit(step).lower(...).compile()`
must succeed on the single-pod (16,16) and multi-pod (2,16,16) meshes, and
the compiled artifact yields memory_analysis / cost_analysis / the HLO the
roofline reads.

Results are cached as JSON under results/dryrun/<mesh>/<arch>/<cell>.json;
re-runs skip completed cells (--force to redo).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --cell train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh both] [--sharding <profile>]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import SHAPES, all_archs, cells_for, get_arch  # noqa: E402
from repro.launch import roofline as rf  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    batch_pspecs,
    logical_rules,
    make_production_mesh,
    named,
    zero1_specs,
)
from repro.models.model import Model, input_specs  # noqa: E402
from repro.optim import make_optimizer, make_schedule, state_logical_specs  # noqa: E402
from repro.runtime.train_loop import make_train_step  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
        "generated_code_size_in_bytes", "host_argument_size_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def lower_cell(
    arch: str,
    cell_name: str,
    mesh,
    *,
    sharding_profile: str = "base",
    unroll: bool = True,
    overrides: dict | None = None,
):
    """Returns (lowered, aux) for the cell's step function on `mesh`."""
    import dataclasses

    cfg = get_arch(arch)
    # Unroll the layer scan so XLA cost analysis counts every layer (loop
    # bodies are otherwise costed once); sharding/compile success is
    # unaffected — the unrolled module is what production would run anyway.
    # The multi-pod pass only proves the sharding compiles (the roofline
    # table is single-pod), so it keeps the scan for compile speed.
    cfg = dataclasses.replace(cfg, unroll_layers=unroll, **(overrides or {}))
    cell = SHAPES[cell_name]
    rules = logical_rules(cfg, mesh, cell)
    if sharding_profile != "base":
        from repro.launch import profiles

        rules = profiles.apply(sharding_profile, cfg, mesh, cell, rules)
    model = Model(cfg)
    aparams = model.abstract_params()
    pspecs = named(mesh, rules.tree_specs(model.param_specs()))
    bspecs = named(mesh, batch_pspecs(cfg, cell, rules))
    abatch = input_specs(cfg, cell)

    if cell.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        astate = opt.abstract_init(aparams)
        slogical = state_logical_specs(opt, model.param_specs(), aparams)
        sspecs = named(mesh, zero1_specs(slogical, astate, rules, mesh))
        schedule = make_schedule("warmup_cosine", peak_lr=3e-4, warmup_steps=100, total_steps=10_000)
        import contextlib

        from repro.models import transformer as tfm_mod

        hook_ctx = contextlib.nullcontext()
        top_hook = None
        if cfg.zero3_gather:
            from repro.launch.mesh import zero3_gather_hook

            all_specs = model.param_specs()
            # body params: gathered per-layer INSIDE the scan (at-use, the
            # ZeRO-3 dataflow); strip the "layers" stacking axis from specs.
            body_logical = jax.tree_util.tree_map(
                lambda axes: tuple(axes[1:]),
                all_specs["body"],
                is_leaf=lambda v: isinstance(v, tuple) and all(a is None or isinstance(a, str) for a in v),
            )
            hook_ctx = tfm_mod.layer_param_hook(zero3_gather_hook(rules, body_logical, mesh))
            # non-body params (embed/lm_head/first/final_norm): gathered once
            top_specs = {k: v for k, v in all_specs.items() if k != "body"}
            sub_hook = zero3_gather_hook(rules, top_specs, mesh)

            def top_hook(params):
                sub = sub_hook({k: params[k] for k in top_specs})
                return {**params, **sub}

        step_fn = make_train_step(model, opt, schedule, param_hook=top_hook)
        jitted = jax.jit(
            step_fn,
            in_shardings=(pspecs, sspecs, bspecs, None),
            out_shardings=(pspecs, sspecs, None),
            donate_argnums=(0, 1),
        )
        with mesh, hook_ctx:
            lowered = jitted.lower(aparams, astate, abatch, jax.ShapeDtypeStruct((), jnp.int32))
        return lowered, {"cfg": cfg, "cell": cell}

    # serving cells: cache is an input (abstract — no allocation)
    acache = model.init_cache(cell.global_batch, cell.seq_len, abstract=True)
    cspecs = named(mesh, rules.tree_specs(model.cache_specs()))
    if cell.kind == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(pspecs, bspecs, cspecs),
            out_shardings=(None, cspecs),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jitted.lower(aparams, abatch, acache)
        return lowered, {"cfg": cfg, "cell": cell}

    # decode
    def decode_step(params, batch, cache, index):
        return model.decode(params, batch, cache, index)

    jitted = jax.jit(
        decode_step,
        in_shardings=(pspecs, bspecs, cspecs, None),
        out_shardings=(None, cspecs),
        donate_argnums=(2,),
    )
    with mesh:
        lowered = jitted.lower(
            aparams, abatch, acache, jax.ShapeDtypeStruct((), jnp.int32)
        )
    return lowered, {"cfg": cfg, "cell": cell}


def run_cell(
    arch: str,
    cell_name: str,
    mesh_kind: str,
    *,
    out_dir: Path = RESULTS,
    force: bool = False,
    sharding_profile: str = "base",
    overrides: dict | None = None,
    unroll: bool | None = None,
    verbose: bool = True,
) -> dict:
    tag = f"{mesh_kind}/{arch}/{cell_name}"
    suffix = "" if sharding_profile == "base" else f".{sharding_profile}"
    if overrides:
        suffix += "." + "-".join(f"{k}={v}" for k, v in sorted(overrides.items()))
    if unroll is None:
        unroll = mesh_kind != "multipod"
    if not unroll and mesh_kind != "multipod":
        suffix += ".scan"
    out_path = out_dir / mesh_kind / arch / f"{cell_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.size
    t0 = time.time()
    lowered, aux = lower_cell(
        arch, cell_name, mesh,
        sharding_profile=sharding_profile, unroll=unroll, overrides=overrides,
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    mf = rf.model_flops(aux["cfg"], aux["cell"])
    roof = rf.analyze(cost, hlo, n_chips=n_chips, model_flops_total=mf)
    mem = _memory_stats(compiled)

    result = {
        "arch": arch,
        "cell": cell_name,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "unrolled": unroll,
        "sharding_profile": sharding_profile,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
        "status": "ok",
    }
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(result, indent=1))
    if verbose:
        dom = roof.bottleneck
        print(
            f"[ok] {tag}{suffix}: compile {t_compile:.1f}s  "
            f"compute {roof.compute_s*1e3:.2f}ms  memory {roof.memory_s*1e3:.2f}ms  "
            f"collective {roof.collective_s*1e3:.2f}ms  <-{dom}  "
            f"useful {roof.useful_flops_ratio:.2f}",
            flush=True,
        )
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="multi-pod dry-run")
    p.add_argument("--arch", default=None)
    p.add_argument("--cell", default=None)
    p.add_argument("--mesh", choices=("pod", "multipod", "both"), default="pod")
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--sharding", default="base", help="sharding profile (perf iterations)")
    p.add_argument(
        "--set", action="append", default=[], metavar="KEY=VALUE",
        help="ArchConfig override, e.g. --set remat=dots --set moe_groups=16",
    )
    p.add_argument(
        "--scan", action="store_true",
        help="keep the layer scan (fast compile proxy for perf iterations)",
    )
    p.add_argument("--out", default=str(RESULTS))
    args = p.parse_args(argv)

    overrides: dict = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = all_archs() if (args.all or args.arch is None) else [args.arch]

    failures = []
    for mesh_kind in meshes:
        for arch in archs:
            cfg = get_arch(arch)
            cells = cells_for(cfg) if args.cell is None else [args.cell]
            for cell in cells:
                try:
                    run_cell(
                        arch, cell, mesh_kind,
                        out_dir=Path(args.out), force=args.force,
                        sharding_profile=args.sharding,
                        overrides=overrides or None,
                        unroll=(False if args.scan else None),
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((mesh_kind, arch, cell, f"{type(e).__name__}: {e}"))
                    print(f"[FAIL] {mesh_kind}/{arch}/{cell}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", *f)
        return 1
    print("\nall dry-run cells passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
