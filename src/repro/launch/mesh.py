"""Mesh construction + logical-axis sharding rules.

Every init function in models/ has a mirror `*_specs` returning tuples of
*logical* axis names per parameter dim. This module maps logical axes to
mesh axes per (arch, mesh, shape-cell):

  batch      -> ("pod","data")        activations' leading dim (DP)
  embed      -> ("data",)+pod if fsdp  ZeRO-3-style param sharding
  heads/mlp/vocab/inner/ssm_heads -> "model"   tensor parallelism
  experts    -> "model" when E % model == 0 (EP), else expert_ff -> "model"
  kv_heads   -> replicated (GQA kv=8 < 16-way model axis)
  cache_seq  -> "model" (+ "data" when batch can't shard, e.g. long_500k B=1)

ZeRO-1 is applied on top for optimizer moments: the largest still-free dim
divisible by the data-axis size gets the data axes.

`make_production_mesh` is a function (never module-level) so importing this
file touches no jax device state.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.moe import expert_sharding


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over actually-present devices (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis name -> mesh axes (None = replicate)."""

    table: dict[str, Any]

    def spec(self, axes: tuple) -> P:
        return P(*[self.table.get(a) for a in axes])

    def tree_specs(self, spec_tree: Any) -> Any:
        """Map a logical-axes pytree -> PartitionSpec pytree."""
        return jax.tree_util.tree_map(
            lambda axes: self.spec(axes), spec_tree, is_leaf=_is_axes
        )

    def shardings(self, mesh: Mesh, spec_tree: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda axes: NamedSharding(mesh, self.spec(axes)), spec_tree, is_leaf=_is_axes
        )


def _is_axes(v: Any) -> bool:
    return isinstance(v, tuple) and all(a is None or isinstance(a, str) for a in v)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def logical_rules(cfg: ArchConfig, mesh: Mesh, cell: ShapeCell | None = None) -> Rules:
    has_pod = "pod" in mesh.axis_names
    data_axes: Any = ("pod", "data") if has_pod else ("data",)
    n_data = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
    n_model = _axis_size(mesh, "model")

    batch_axes: Any = data_axes
    cache_seq: Any = ("model",)
    if cell is not None and cell.global_batch % max(n_data, 1) != 0:
        # batch too small for DP (long_500k B=1): spread the cache/sequence
        # over the data axes instead and replicate the batch.
        batch_axes = None
        cache_seq = data_axes + ("model",)

    ep = expert_sharding(cfg, n_model) if cfg.is_moe else "ep"
    fsdp_axes = data_axes if cfg.fsdp else None

    table: dict[str, Any] = {
        "batch": batch_axes,
        "embed": fsdp_axes,
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model" if ep == "ep" else None,
        "expert_ff": None if ep == "ep" else "model",
        "layers": None,
        "cache_seq": cache_seq,
        "inner": "model",
        "ssm_heads": "model",
        "conv_ch": None,
        "seq": None,
    }
    return Rules(table)


# ---------------------------------------------------------------------------
def batch_pspecs(cfg: ArchConfig, cell: ShapeCell, rules: Rules) -> dict[str, P]:
    """PartitionSpec per input-batch entry (matches models.model.input_specs)."""
    b = rules.table["batch"]
    if cell.kind == "train":
        if cfg.encoder_decoder:
            return {"frames": P(b, None, None), "tgt_tokens": P(b, None), "labels": P(b, None)}
        inp = P(b, None) if cfg.embed_inputs else P(b, None, None)
        pos = P(None, b, None) if cfg.rope == "mrope" else P(b, None)
        return {"inputs": inp, "labels": P(b, None), "positions": pos}
    if cell.kind == "prefill":
        if cfg.encoder_decoder:
            return {"frames": P(b, None, None), "tgt_tokens": P(b, None)}
        inp = P(b, None) if cfg.embed_inputs else P(b, None, None)
        pos = P(None, b, None) if cfg.rope == "mrope" else P(b, None)
        return {"inputs": inp, "positions": pos}
    # decode
    if cfg.encoder_decoder or cfg.embed_inputs:
        return {"tokens": P(b, None)}
    return {"tokens": P(b, None, None)}


def zero1_specs(
    state_logical: Any, state_abstract: Any, rules: Rules, mesh: Mesh
) -> Any:
    """PartitionSpecs for optimizer state: base rules + shard the largest
    still-replicated dim over the data axes (ZeRO-1)."""
    has_pod = "pod" in mesh.axis_names
    data_axes = ("pod", "data") if has_pod else ("data",)
    n_data = int(np.prod([_axis_size(mesh, a) for a in data_axes]))

    def one(axes, ab):
        spec = list(rules.spec(axes))
        spec += [None] * (len(ab.shape) - len(spec))
        used = {a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))}
        if "data" in used or n_data <= 1:
            return P(*spec)
        # largest free, divisible dim gets the data axes
        cands = [
            (ab.shape[i], i)
            for i in range(len(ab.shape))
            if spec[i] is None and ab.shape[i] % n_data == 0 and ab.shape[i] >= n_data
        ]
        if cands:
            _, i = max(cands)
            spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map(one, state_logical, state_abstract, is_leaf=_is_axes)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda v: isinstance(v, P)
    )


def zero3_gather_hook(rules: Rules, param_logical: Any, mesh: Mesh):
    """fn(params)->params that strips data axes off FSDP-sharded params via
    with_sharding_constraint (explicit ZeRO-3 weight gathering).

    Left to itself, the SPMD partitioner may satisfy a contraction whose
    contracting dim is data-sharded (params with logical "embed" under FSDP)
    by all-reducing the partial-sum ACTIVATIONS over the data axis — orders
    of magnitude more wire than gathering the weights. Constraining each
    such parameter to its data-axis-free spec forces the (cheap) weight
    all-gather; the constraint's transpose reduce-scatters the gradient —
    the canonical ZeRO-3 dataflow, with at-use gathering under the layer
    scan (weights gathered per step, not held resident).
    """
    has_pod = "pod" in mesh.axis_names
    data_axes = {"pod", "data"} if has_pod else {"data"}

    def strip(axes_spec):
        spec = rules.spec(axes_spec)
        out = []
        changed = False
        for entry in spec:
            parts = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in parts if a is not None and a not in data_axes)
            if len(kept) != len([a for a in parts if a is not None]):
                changed = True
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out) if changed else None

    strip_tree = jax.tree_util.tree_map(strip, param_logical, is_leaf=_is_axes)
    # P is a tuple subclass and None an empty pytree: flatten explicitly.
    strip_leaves = jax.tree_util.tree_leaves(
        strip_tree, is_leaf=lambda v: v is None or isinstance(v, P)
    )

    def hook(params):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        assert len(leaves) == len(strip_leaves), (len(leaves), len(strip_leaves))
        out = [
            w if s is None else jax.lax.with_sharding_constraint(w, s)
            for w, s in zip(leaves, strip_leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)

    return hook
