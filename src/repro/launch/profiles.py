"""Named sharding profiles — the knobs the §Perf hillclimb turns.

`base` is the paper-faithful default (logical_rules). Each profile mutates
the rules table; dryrun --sharding <name> lowers the same cell under the
variant so before/after roofline terms are directly comparable.
"""
from __future__ import annotations


from repro.launch.mesh import Rules


def apply(name: str, cfg, mesh, cell, rules: Rules) -> Rules:
    if name == "base":
        return rules
    table = dict(rules.table)
    if name == "no_fsdp":  # replicate params over data (memory for collectives)
        table["embed"] = None
    elif name == "fsdp":  # force FSDP even when cfg.fsdp is False
        has_pod = "pod" in mesh.axis_names
        table["embed"] = ("pod", "data") if has_pod else ("data",)
    elif name == "seq_model":  # cache sequence over model only
        table["cache_seq"] = ("model",)
    elif name == "seq_data_model":  # cache sequence over data+model
        has_pod = "pod" in mesh.axis_names
        d = ("pod", "data") if has_pod else ("data",)
        table["cache_seq"] = d + ("model",)
        table["batch"] = None
    elif name == "expert_tp":  # force per-expert d_ff sharding
        table["experts"] = None
        table["expert_ff"] = "model"
    elif name == "vocab_data":  # shard vocab over data instead of model
        table["vocab"] = "data"
    elif name == "replicated_vocab":
        table["vocab"] = None
    else:
        raise ValueError(f"unknown sharding profile {name!r}")
    return Rules(table)


PROFILES = (
    "base", "no_fsdp", "fsdp", "seq_model", "seq_data_model",
    "expert_tp", "vocab_data", "replicated_vocab",
)


# ---------------------------------------------------------------------------
# Execution-platform wiring (lazily merged by repro.core.platform).
#
# Each entry overlays the core platform registry with launch-layer defaults:
# which sharding profile a backend should lower under, plus capability flags
# tasks can branch on. This is where a future real-DPU target (e.g. a
# BlueField profile driving remote execution) plugs in without the core
# layer learning about meshes or jax.
EXECUTION_PROFILES: dict[str, dict] = {
    "cpu-host": {
        "kind": "host",
        "flags": {"sharding": "base"},
    },
    "dpu-sim": {
        "kind": "sim",
        # Wimpy-core dilation: BlueField-2 characterizations put the DPU Arm
        # complex ~3-4x behind the host for general-purpose compute.
        "time_scale": 3.5,
        "flags": {
            "sharding": "seq_model",
            "wimpy_cores": True,
            "accelerators": ["compression", "crypto"],
        },
    },
}
