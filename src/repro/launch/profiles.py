"""Named sharding profiles — the knobs the §Perf hillclimb turns.

`base` is the paper-faithful default (logical_rules). Each profile mutates
the rules table; dryrun --sharding <name> lowers the same cell under the
variant so before/after roofline terms are directly comparable.
"""
from __future__ import annotations

import dataclasses

from repro.launch.mesh import Rules, logical_rules


def apply(name: str, cfg, mesh, cell, rules: Rules) -> Rules:
    if name == "base":
        return rules
    table = dict(rules.table)
    if name == "no_fsdp":  # replicate params over data (memory for collectives)
        table["embed"] = None
    elif name == "fsdp":  # force FSDP even when cfg.fsdp is False
        has_pod = "pod" in mesh.axis_names
        table["embed"] = ("pod", "data") if has_pod else ("data",)
    elif name == "seq_model":  # cache sequence over model only
        table["cache_seq"] = ("model",)
    elif name == "seq_data_model":  # cache sequence over data+model
        has_pod = "pod" in mesh.axis_names
        d = ("pod", "data") if has_pod else ("data",)
        table["cache_seq"] = d + ("model",)
        table["batch"] = None
    elif name == "expert_tp":  # force per-expert d_ff sharding
        table["experts"] = None
        table["expert_ff"] = "model"
    elif name == "vocab_data":  # shard vocab over data instead of model
        table["vocab"] = "data"
    elif name == "replicated_vocab":
        table["vocab"] = None
    else:
        raise ValueError(f"unknown sharding profile {name!r}")
    return Rules(table)


PROFILES = (
    "base", "no_fsdp", "fsdp", "seq_model", "seq_data_model",
    "expert_tp", "vocab_data", "replicated_vocab",
)
