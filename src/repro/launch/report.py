"""Assemble the roofline table from results/dryrun/*.json.

Per (arch × cell × mesh × profile) row:
  compute_s / memory_s / collective_s  — the three roofline terms (§Roofline)
  bottleneck                            — the dominant term
  mfu_bound — MODEL_FLOPS/(chips·peak) / max(term): the MFU the step would
              achieve if it ran exactly at its roofline-limiting term; this
              is the "roofline fraction" the perf loop drives up.
  useful    — MODEL_FLOPS / (HLO_FLOPs·chips): compiled-compute efficiency
              (catches remat/recompute waste).

Usage:
  PYTHONPATH=src python -m repro.launch.report [--mesh pod] [--format md|csv]
  PYTHONPATH=src python -m repro.launch.report --profiles   # perf iterations
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch import roofline as rf

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load_rows(root: Path = RESULTS, mesh: str | None = None) -> list[dict]:
    rows = []
    for p in sorted(root.glob("*/*/*.json")):
        d = json.loads(p.read_text())
        if mesh and d["mesh"] != mesh:
            continue
        r = d["roofline"]
        chips = d["n_chips"]
        ideal_s = r["model_flops_total"] / (chips * rf.PEAK_FLOPS)
        worst = max(r["compute_s"], r["memory_s"], r["collective_s"])
        variant = d.get("sharding_profile", "base")
        if d.get("overrides"):
            variant += "+" + ",".join(f"{k}={v}" for k, v in sorted(d["overrides"].items()))
        if not d.get("unrolled", True) and d["mesh"] != "multipod":
            variant += " (scan)"
        rows.append(
            {
                "arch": d["arch"],
                "cell": d["cell"],
                "mesh": d["mesh"],
                "profile": variant,
                "chips": chips,
                "compute_ms": r["compute_s"] * 1e3,
                "memory_ms": r["memory_s"] * 1e3,
                "collective_ms": r["collective_s"] * 1e3,
                "bottleneck": r["bottleneck"],
                "mfu_bound": (ideal_s / worst) if worst > 0 else 0.0,
                "useful": r["useful_flops_ratio"],
                "model_tflops": r["model_flops_total"] / 1e12,
                "hbm_gb_per_dev": r["bytes_per_device"] / 1e9,
                "wire_gb_per_dev": r["wire_bytes_per_device"] / 1e9,
                "compile_s": d.get("compile_s", 0.0),
            }
        )
    return rows


_CELL_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def to_markdown(rows: list[dict]) -> str:
    rows = sorted(rows, key=lambda r: (r["arch"], _CELL_ORDER.get(r["cell"], 9), r["profile"]))
    hdr = (
        "| arch | cell | profile | compute ms | memory ms | collective ms | "
        "bottleneck | MFU-bound | useful |"
    )
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['profile']} | "
            f"{r['compute_ms']:.2f} | {r['memory_ms']:.2f} | {r['collective_ms']:.2f} | "
            f"{r['bottleneck']} | {r['mfu_bound']:.3f} | {r['useful']:.2f} |"
        )
    return "\n".join(lines)


def to_csv(rows: list[dict]) -> str:
    if not rows:
        return ""
    keys = list(rows[0])
    out = [",".join(keys)]
    for r in rows:
        out.append(",".join(f"{r[k]:.4f}" if isinstance(r[k], float) else str(r[k]) for k in keys))
    return "\n".join(out)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.launch.report")
    p.add_argument("--mesh", default=None, choices=(None, "pod", "multipod"))
    p.add_argument("--format", default="md", choices=("md", "csv"))
    p.add_argument("--profiles", action="store_true", help="only non-base profiles + their base")
    p.add_argument("--baseline-only", action="store_true", help="only unrolled base cells")
    p.add_argument("--root", default=str(RESULTS))
    args = p.parse_args(argv)

    rows = load_rows(Path(args.root), mesh=args.mesh)
    if args.baseline_only:
        rows = [r for r in rows if r["profile"] == "base"]
    if args.profiles:
        keyed = {(r["arch"], r["cell"], r["mesh"]) for r in rows if r["profile"] != "base"}
        rows = [r for r in rows if (r["arch"], r["cell"], r["mesh"]) in keyed]
    print(to_markdown(rows) if args.format == "md" else to_csv(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
