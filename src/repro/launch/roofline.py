"""Roofline-term extraction from compiled (dry-run) artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the
TARGET hardware (TPU v5e):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_BW

HLO_FLOPs/bytes come from compiled.cost_analysis() (the partitioned
per-device module). Collective bytes are NOT in cost_analysis: we parse the
optimized HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute and convert each to ring-algorithm wire bytes:

  all-reduce      2*(n-1)/n * |buf|     (reduce-scatter + all-gather phases)
  all-gather      (n-1)/n  * |result|
  reduce-scatter  (n-1)    * |result|   (operand = n*|result| through links)
  all-to-all      (n-1)/n  * |buf|
  collective-permute       |buf|

where n is the replica-group size parsed from the op's replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# -- TPU v5e target constants (per chip) -------------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(?P<shape>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
    re.MULTILINE,
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[dims] occurrence in a shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default when groups elided


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    result_bytes: int = 0
    wire_bytes: float = 0.0


def parse_collectives(hlo_text: str) -> dict[str, CollectiveStats]:
    """Per-op-kind totals over the (per-device) HLO module."""
    out: dict[str, CollectiveStats] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        # `^\s*` can consume the preceding newline, so locate the end of the
        # op line from m.end() (inside the line), not m.start().
        eol = hlo_text.find("\n", m.end())
        line = hlo_text[m.start() : eol if eol != -1 else len(hlo_text)]
        rb = _shape_bytes(m.group("shape"))
        if op == "all-reduce" and m.group("start"):
            pass  # -start carries the shape; -done lines don't match (no "(" pattern on result)
        n = _group_size(line)
        if op == "all-reduce":
            wire = 2 * (n - 1) / n * rb
        elif op == "all-gather":
            wire = (n - 1) / n * rb
        elif op == "reduce-scatter":
            wire = (n - 1) * rb
        elif op == "all-to-all":
            wire = (n - 1) / n * rb
        else:  # collective-permute
            wire = float(rb)
        s = out.setdefault(op, CollectiveStats())
        s.count += 1
        s.result_bytes += rb
        s.wire_bytes += wire
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: dict[str, Any]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float = 0.0
    useful_flops_ratio: float = 0.0  # MODEL_FLOPS / (HLO_FLOPs * chips)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(
    cost: dict[str, float],
    hlo_text: str,
    *,
    n_chips: int,
    model_flops_total: float = 0.0,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    wire = sum(s.wire_bytes for s in colls.values())
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = model_flops_total / (flops * n_chips) if flops > 0 else 0.0
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        wire_bytes_per_device=wire,
        collectives={k: dataclasses.asdict(v) for k, v in colls.items()},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_flops_ratio=ratio,
    )


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    n_active = cfg.n_active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
