"""Serving driver: batched requests through the SlotServer.

Loads a (tiny or full) arch, submits a synthetic request batch with mixed
prompt lengths and budgets, and reports throughput + per-request latency —
the end-to-end "full system" tier of the benchmark suite, serving edition.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tiny \
      --requests 16 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch, tiny
from repro.models.model import Model
from repro.runtime.serve_loop import Request, SlotServer


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.launch.serve")
    p.add_argument("--arch", default="olmo-1b")
    p.add_argument("--tiny", action="store_true")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.tiny:
        cfg = tiny(cfg)
    if cfg.encoder_decoder:
        print(f"{cfg.name} is encoder-decoder; serve driver targets decoder-only LMs")
        return 2
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    server = SlotServer(model, n_slots=args.slots, max_len=args.max_len)
    server.load(params)

    key = jax.random.PRNGKey(args.seed + 1)
    for uid in range(args.requests):
        k = jax.random.fold_in(key, uid)
        plen = int(jax.random.randint(k, (), 4, 32))
        prompt = jax.random.randint(jax.random.fold_in(k, 1), (plen,), 0, cfg.vocab_size)
        server.submit(Request(uid=uid, prompt=prompt.astype(jnp.int32), max_new_tokens=args.max_new))

    t0 = time.time()
    completions = server.run()
    dt = time.time() - t0
    new_tokens = sum(len(c.tokens) for c in completions)
    print(
        f"arch={cfg.name} slots={args.slots} requests={args.requests} "
        f"completed={len(completions)} decode_calls={server.decode_calls} "
        f"new_tokens={new_tokens} ({dt:.1f}s, {new_tokens/dt:,.0f} tok/s)"
    )
    ok = len(completions) == args.requests and all(len(c.tokens) > 0 for c in completions)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
