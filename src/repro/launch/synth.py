"""Synthesize dry-run result fixtures without compiling anything.

``repro.launch.report`` consumes ``results/dryrun/<mesh>/<arch>/<cell>.json``
files that normally come out of the (slow, jax-compiling) dry-run in
:mod:`repro.launch.dryrun`.  Tests and fresh checkouts should not depend on
multi-minute compiles or checked-in artifacts, so this module produces the
same JSON schema *analytically*: roofline terms derived from the arch
config's parameter/activation byte counts, run through the real
:func:`repro.launch.roofline.analyze` code path (a synthetic one-op HLO
supplies the collective), so the fixture structure can never drift from
what the report loader expects.

Numbers are deterministic, positive, and roofline-plausible — good enough
for loaders, table formatting, and plumbing tests; they are NOT
measurements.  Every file carries ``"status": "synthetic"`` so a real
dry-run (which writes ``"status": "ok"``) is distinguishable and simply
overwrites them with ``--force``.

Only stdlib + repro.configs + repro.launch.roofline are imported — no jax.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import SHAPES, all_archs, cells_for, get_arch
from repro.launch import roofline as rf

# Chips in the production single-pod mesh (16 x 16); mirrors
# repro.launch.mesh.make_production_mesh without importing jax.
POD_CHIPS = 256
_RING = 16  # per-axis ring size used for the synthetic collective

# Fraction of HLO FLOPs that are "useful" model FLOPs in a reasonably
# lowered module (remat/recompute overheads put real numbers in this band).
_USEFUL = 0.62


def synthesize_cell(arch: str, cell_name: str, mesh_kind: str = "pod") -> dict:
    cfg = get_arch(arch)
    cell = SHAPES[cell_name]
    mf = rf.model_flops(cfg, cell)
    flops_per_device = mf / (POD_CHIPS * _USEFUL)

    param_bytes = cfg.n_params() * 2  # bf16 residency
    if cell.kind == "train":
        tokens_per_device = cell.global_batch * cell.seq_len / POD_CHIPS
    else:
        tokens_per_device = max(cell.global_batch / POD_CHIPS, 1.0)
    act_bytes = tokens_per_device * cfg.d_model * cfg.n_layers * 2 * 4
    bytes_per_device = param_bytes / POD_CHIPS + act_bytes

    # One synthetic collective sized like the dominant wire mover: gradient
    # all-reduce for training, param all-gather for serving. Feeding it
    # through the real HLO parser keeps the schema honest.
    shard_elems = max(int(param_bytes / 2 / POD_CHIPS), 1)
    if cell.kind == "train":
        hlo = (
            f"  %ar = bf16[{shard_elems}] all-reduce(bf16[{shard_elems}] %g), "
            f"replica_groups=[{_RING},{_RING}]<=[{POD_CHIPS}], to_apply=%add\n"
        )
    else:
        hlo = (
            f"  %ag = bf16[{shard_elems}] all-gather(bf16[{shard_elems // _RING or 1}] %p), "
            f"replica_groups=[{_RING},{_RING}]<=[{POD_CHIPS}], dimensions={{0}}\n"
        )
    cost = {"flops": flops_per_device, "bytes accessed": bytes_per_device}
    roof = rf.analyze(cost, hlo, n_chips=POD_CHIPS, model_flops_total=mf)

    return {
        "arch": arch,
        "cell": cell_name,
        "mesh": mesh_kind,
        "n_chips": POD_CHIPS,
        "unrolled": True,
        "sharding_profile": "base",
        "overrides": {},
        "lower_s": 0.0,
        "compile_s": 0.0,
        "memory": {},
        "roofline": roof.to_dict(),
        "status": "synthetic",
    }


def ensure_dryrun_fixtures(out_dir: str | Path, mesh_kind: str = "pod") -> list[Path]:
    """Write any missing base-cell fixtures; returns the paths written.

    Existing files (synthetic or real dry-run output) are left untouched, so
    genuine measurements are never clobbered.
    """
    out_dir = Path(out_dir)
    written = []
    for arch in all_archs():
        cfg = get_arch(arch)
        for cell_name in cells_for(cfg):
            path = out_dir / mesh_kind / arch / f"{cell_name}.json"
            if path.exists():
                continue
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(synthesize_cell(arch, cell_name, mesh_kind), indent=1))
            written.append(path)
    return written


def main(argv=None) -> int:  # pragma: no cover - tiny CLI
    import argparse

    p = argparse.ArgumentParser(prog="repro.launch.synth")
    p.add_argument("--out", default=None, help="dryrun results root")
    p.add_argument("--mesh", default="pod")
    args = p.parse_args(argv)
    from repro.launch.report import RESULTS

    written = ensure_dryrun_fixtures(Path(args.out) if args.out else RESULTS, args.mesh)
    print(f"wrote {len(written)} synthetic dryrun fixtures")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
