"""End-to-end training driver.

Trains any ``--arch`` on synthetic LM data with the full production stack:
sharded params/optimizer via the mesh rules, fault-tolerant loop
(checkpoint/restart, straggler monitor), grad accumulation. On this CPU
container the mesh is the host mesh (``--data/--model`` over real devices);
on a pod the same flags target ``make_production_mesh``.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
      --seq-len 128 --batch 8 --tiny --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time


from repro.configs.base import get_arch, tiny
from repro.data.pipeline import for_model
from repro.launch.mesh import logical_rules, make_host_mesh, named
from repro.models.model import Model
from repro.runtime.train_loop import TrainConfig, run_with_restarts, train


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.launch.train")
    p.add_argument("--arch", default="olmo-1b")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--data", type=int, default=1, help="data-parallel mesh size")
    p.add_argument("--model", type=int, default=1, help="model-parallel mesh size")
    p.add_argument("--tiny", action="store_true", help="reduced config (CPU-runnable)")
    p.add_argument("--failure-at", type=int, default=None, help="inject a failure (restart drill)")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.tiny:
        cfg = tiny(cfg)
    model = Model(cfg)
    data = for_model(cfg, seq_len=args.seq_len, global_batch=args.batch)

    mesh = make_host_mesh(args.data, args.model)
    rules = logical_rules(cfg, mesh)
    tc = TrainConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        lr=args.lr,
        accum_steps=args.accum,
        log_every=args.log_every,
        failure_at=args.failure_at,
    )

    n_params = cfg.n_params()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"steps={tc.steps} batch={args.batch}x{args.seq_len}")
    t0 = time.time()
    with mesh:
        if args.failure_at is not None:
            res = run_with_restarts(model, data, tc)
        else:
            res = train(model, data, tc, mesh=mesh,
                        in_shardings=named(mesh, rules.tree_specs(model.param_specs())))
    dt = time.time() - t0
    tok_s = args.batch * args.seq_len * (res.final_step) / dt if dt > 0 else 0
    print(f"done: step={res.final_step} loss[0]={res.losses[0]:.4f} "
          f"loss[-1]={res.losses[-1]:.4f} restarts={res.restarts} "
          f"stragglers={res.stragglers} restored_from={res.restored_from} "
          f"({dt:.1f}s, {tok_s:,.0f} tok/s)")
    if len(res.losses) >= 2 and res.losses[-1] >= res.losses[0]:
        print("WARNING: loss did not decrease")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
