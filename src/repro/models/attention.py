"""Grouped-query attention: train/prefill (blockwise causal) + cached decode.

Layout conventions (TPU-friendly):
  activations  x        [B, S, d_model]
  projections  wq       [d_model, Hq, dh]     logical ("embed", "heads", "head_dim")
               wk, wv   [d_model, Hkv, dh]    logical ("embed", "kv_heads", "head_dim")
               wo       [Hq, dh, d_model]     logical ("heads", "head_dim", "embed")
  KV cache     k, v     [B, S_max, Hkv, dh]   logical ("batch", "cache_seq", "kv_heads", "head_dim")

Hq is sharded over the "model" mesh axis (tensor parallelism); Hkv is
replicated when Hkv < model-axis size (GQA kv=8 vs 16-way TP), so each TP
shard holds every KV head and its own slice of query heads — attention then
needs no cross-shard communication except the wo all-reduce.

Prefill uses a query-block scan (flash-attention memory behaviour in pure
jnp: O(block x S) live scores instead of O(S x S)). The Pallas flash kernel
(kernels/flash_attention.py) is a drop-in for the aligned-size fast path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_positional, truncated_normal

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free on fully masked rows

# Query-block length for the prefill scan. Sequences at or below this are
# done in one block (CPU smoke tests take that path).
DEFAULT_Q_BLOCK = 1024


def init_attention(cfg, key, dtype, cross: bool = False) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    scale = d**-0.5
    return {
        "wq": truncated_normal(kq, (d, hq, dh), scale, dtype),
        "wk": truncated_normal(kk, (d, hkv, dh), scale, dtype),
        "wv": truncated_normal(kv, (d, hkv, dh), scale, dtype),
        "wo": truncated_normal(ko, (hq, dh, d), (hq * dh) ** -0.5, dtype),
    }


def attention_specs(cfg, cross: bool = False) -> Params:
    """Mirror of init_attention: logical axis names per parameter."""
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


# ---------------------------------------------------------------------------
# Core softmax-attention over grouped heads.
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B, Sq, Hkv, G, dh], k [B, Sk, Hkv, dh] -> scores [B, Hkv, G, Sq, Sk] (f32)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def _gqa_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p [B, Hkv, G, Sq, Sk] (f32), v [B, Sk, Hkv, dh] -> [B, Sq, Hkv, G, dh]."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(p.dtype))


def _split_heads(x: jax.Array, hkv: int) -> jax.Array:
    """[B, S, Hq, dh] -> [B, S, Hkv, G, dh]."""
    b, s, hq, dh = x.shape
    return x.reshape(b, s, hkv, hq // hkv, dh)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-block reference attention.

    q [B, Sq, Hkv, G, dh]; k, v [B, Sk, Hkv, dh]. `q_offset` is the absolute
    position of q's first token (for causal masking against a longer k).
    `kv_len` masks out cache slots >= kv_len (decode with a ring/linear cache).
    """
    dh = q.shape[-1]
    scores = _gqa_scores(q, k) * (dh**-0.5)  # [B, Hkv, G, Sq, Sk] f32
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    sq, sk = scores.shape[-2], scores.shape[-1]
    # q_offset / kv_len may be scalars or per-sequence [B] vectors (slot serving)
    mask = None
    if causal:
        off = jnp.reshape(jnp.asarray(q_offset), (-1, 1, 1))  # [B or 1, 1, 1]
        qpos = jnp.arange(sq)[None, :, None] + off  # [B?, Sq, 1]
        kpos = jnp.arange(sk)[None, None, :]
        mask = qpos >= kpos  # [B?, Sq, Sk]
    if kv_len is not None:
        kl = jnp.reshape(jnp.asarray(kv_len), (-1, 1, 1))
        valid = jnp.arange(sk)[None, None, :] < kl  # [B?, 1, Sk]
        valid = jnp.broadcast_to(valid, (valid.shape[0], sq, sk))
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_values(p, v)


def blockwise_attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = DEFAULT_Q_BLOCK,
    softcap: float = 0.0,
) -> jax.Array:
    """Query-block scanned attention: memory O(q_block x Sk), not O(Sq x Sk).

    Equal results to `attend` (same masking); used for long prefill. The scan
    carries nothing — each block is independent — so XLA frees score buffers
    between iterations.
    """
    b, sq, hkv, g, dh = q.shape
    if sq <= q_block or sq % q_block != 0:
        return attend(q, k, v, causal=causal, softcap=softcap)
    nblk = sq // q_block
    qb = q.reshape(b, nblk, q_block, hkv, g, dh)

    def body(_, args):
        i, qi = args  # qi [B, q_block, Hkv, G, dh]
        out = attend(qi, k, v, causal=causal, q_offset=i * q_block, softcap=softcap)
        return None, out

    _, ob = jax.lax.scan(body, None, (jnp.arange(nblk), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(ob, 0, 1).reshape(b, sq, hkv, g, dh)


# ---------------------------------------------------------------------------
# Module-level apply: projections + rope + attention + output.
def apply_attention(
    cfg,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    kv_cache: dict[str, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
    q_block: int = DEFAULT_Q_BLOCK,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Attention sub-layer.

    Modes:
      * train/prefill: kv_cache None (or filled at positions 0..S) — blockwise causal.
      * decode: kv_cache given + cache_index (scalar int32, next slot) — S == 1
        (or a small chunk); new k/v written at cache_index, attends to cache.
      * cross-attention: kv_override = (k, v) precomputed from encoder output;
        kv_cache ignored; causal=False.

    Returns (output [B, S, d_model], updated kv_cache or None).
    """
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))  # [B,S,Hq,dh]

    if kv_override is not None:
        k, v = kv_override
        q = apply_positional(cfg, q, positions) if cfg.rope != "none" else q
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))  # [B,S,Hkv,dh]
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        q = apply_positional(cfg, q, positions)
        k = apply_positional(cfg, k, positions)

    new_cache = None
    if kv_cache is not None and kv_override is None:
        # Write new K/V into the cache, attend to the cache prefix. cache_index
        # may be a scalar (lockstep decode/prefill) or [B] (per-slot serving).
        ck, cv = kv_cache["k"], kv_cache["v"]
        idx = cache_index if cache_index is not None else jnp.int32(0)
        if jnp.ndim(idx) == 0:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, idx, 0, 0))
        else:
            upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))
            ck = upd(ck, k.astype(ck.dtype), idx)
            cv = upd(cv, v.astype(cv.dtype), idx)
        new_cache = {"k": ck, "v": cv}
        qg = _split_heads(q, hkv)
        out = attend(
            qg, ck.astype(x.dtype), cv.astype(x.dtype),
            causal=True, q_offset=idx, kv_len=idx + s, softcap=0.0,
        )
    else:
        qg = _split_heads(q, hkv)
        out = blockwise_attend(qg, k, v, causal=causal, q_block=q_block)

    out = out.reshape(b, s, hq, dh).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention K/V precompute (encoder-decoder): done once per request.
def cross_kv(cfg, p: Params, enc_out: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def attention_flops(cfg, batch: int, sq: int, sk: int, decode: bool = False) -> int:
    """Model FLOPs of one attention layer (projections + scores + values)."""
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * batch * sq * d * dh * (hq + 2 * hkv) + 2 * batch * sq * hq * dh * d
    qk = 2 * batch * hq * sq * sk * dh
    pv = 2 * batch * hq * sq * sk * dh
    if not decode:  # causal halves the realized score work
        qk //= 2
        pv //= 2
    return proj + qk + pv
