"""Encoder-decoder stack (SeamlessM4T-style backbone).

Encoder: bidirectional attention blocks over precomputed frontend embeddings
(the speech/vision frontend is a stub per the assignment; `input_specs`
provides [B, S_src, d_model] frames). Decoder: causal self-attention (KV
cached) + cross-attention over the encoder output (K/V computed once at
prefill and cached) + FFN. Both stacks scan over layers with stacked params.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.layers import Params, apply_mlp, apply_norm, init_mlp, init_norm, truncated_normal


def _norm_spec(cfg) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": ("embed",)}
    if cfg.norm == "nonparametric_ln":
        return {}
    return {"scale": ("embed",), "bias": ("embed",)}


# ---------------------------------------------------------------------------
def init_enc_block(cfg, key, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm1": init_norm(cfg, k1, cfg.d_model, dtype),
        "attn": attn_mod.init_attention(cfg, k2, dtype),
        "norm2": init_norm(cfg, k3, cfg.d_model, dtype),
        "mlp": init_mlp(cfg, k4, dtype),
    }


def init_dec_block(cfg, key, dtype) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "norm1": init_norm(cfg, k1, cfg.d_model, dtype),
        "attn": attn_mod.init_attention(cfg, k2, dtype),
        "norm_xa": init_norm(cfg, k3, cfg.d_model, dtype),
        "xattn": attn_mod.init_attention(cfg, k4, dtype, cross=True),
        "norm2": init_norm(cfg, k5, cfg.d_model, dtype),
        "mlp": init_mlp(cfg, k6, dtype),
    }


def _mlp_spec(cfg) -> Params:
    return {"wi": ("embed", None, "mlp") if cfg.act == "swiglu" else ("embed", "mlp"),
            "wo": ("mlp", "embed")}


def enc_block_specs(cfg) -> Params:
    return {
        "norm1": _norm_spec(cfg),
        "attn": attn_mod.attention_specs(cfg),
        "norm2": _norm_spec(cfg),
        "mlp": _mlp_spec(cfg),
    }


def dec_block_specs(cfg) -> Params:
    return {
        "norm1": _norm_spec(cfg),
        "attn": attn_mod.attention_specs(cfg),
        "norm_xa": _norm_spec(cfg),
        "xattn": attn_mod.attention_specs(cfg),
        "norm2": _norm_spec(cfg),
        "mlp": _mlp_spec(cfg),
    }


def init_encdec(cfg: ArchConfig, key, dtype) -> Params:
    keys = jax.random.split(key, 8)
    enc_keys = jax.random.split(keys[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "enc_body": jax.vmap(lambda k: init_enc_block(cfg, k, dtype))(enc_keys),
        "enc_norm": init_norm(cfg, keys[2], cfg.d_model, dtype),
        "dec_embed": truncated_normal(keys[3], (cfg.padded_vocab, cfg.d_model), cfg.d_model**-0.5, dtype),
        "dec_body": jax.vmap(lambda k: init_dec_block(cfg, k, dtype))(dec_keys),
        "dec_norm": init_norm(cfg, keys[4], cfg.d_model, dtype),
        "lm_head": truncated_normal(keys[5], (cfg.d_model, cfg.padded_vocab), cfg.d_model**-0.5, dtype),
    }


def encdec_specs(cfg: ArchConfig) -> Params:
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda axes: ("layers",) + tuple(axes), tree,
            is_leaf=lambda v: isinstance(v, tuple),
        )

    return {
        "enc_body": stack(enc_block_specs(cfg)),
        "enc_norm": _norm_spec(cfg),
        "dec_embed": ("vocab", "embed"),
        "dec_body": stack(dec_block_specs(cfg)),
        "dec_norm": _norm_spec(cfg),
        "lm_head": ("embed", "vocab"),
    }


# ---------------------------------------------------------------------------
def encode(cfg: ArchConfig, p: Params, frames: jax.Array, positions: jax.Array) -> jax.Array:
    """frames [B, S_src, d_model] -> encoder output [B, S_src, d_model]."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dtype)

    def unit(x, params_i):
        h = apply_norm(cfg, params_i["norm1"], x)
        y, _ = attn_mod.apply_attention(cfg, params_i["attn"], h, positions, causal=False)
        x = x + y
        h = apply_norm(cfg, params_i["norm2"], x)
        return x + apply_mlp(cfg, params_i["mlp"], h), None

    x, _ = jax.lax.scan(unit, x, p["enc_body"],
                       unroll=cfg.n_encoder_layers if cfg.unroll_layers else 1)
    return apply_norm(cfg, p["enc_norm"], x)


def build_cross_cache(cfg: ArchConfig, p: Params, enc_out: jax.Array) -> dict[str, jax.Array]:
    """Per-decoder-layer cross K/V, stacked [L, B, S_src, Hkv, dh]."""

    def one(params_i):
        k, v = attn_mod.cross_kv(cfg, params_i["xattn"], enc_out)
        return {"k": k, "v": v}

    return jax.vmap(one)(p["dec_body"])


def decode_step(
    cfg: ArchConfig,
    p: Params,
    tokens: jax.Array,  # [B, S_tgt] (prefill) or [B, 1] (decode)
    positions: jax.Array,
    cross: dict[str, jax.Array],  # stacked cross K/V
    cache: dict[str, Any] | None = None,
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict[str, Any] | None]:
    """Decoder pass. Returns (logits f32, updated self-attn cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(p["dec_embed"], tokens, axis=0).astype(dtype)
    # init_cache keys the (single-position) decoder pattern as body["l0"]
    cache_body = cache["body"]["l0"] if cache is not None else None

    def unit(x, xs):
        params_i, cross_i, cache_i = xs
        h = apply_norm(cfg, params_i["norm1"], x)
        y, nc = attn_mod.apply_attention(
            cfg, params_i["attn"], h, positions,
            causal=True, kv_cache=cache_i, cache_index=cache_index,
        )
        x = x + y
        h = apply_norm(cfg, params_i["norm_xa"], x)
        y, _ = attn_mod.apply_attention(
            cfg, params_i["xattn"], h, positions,
            causal=False, kv_override=(cross_i["k"].astype(dtype), cross_i["v"].astype(dtype)),
        )
        x = x + y
        h = apply_norm(cfg, params_i["norm2"], x)
        return x + apply_mlp(cfg, params_i["mlp"], h), nc

    x, new_body = jax.lax.scan(unit, x, (p["dec_body"], cross, cache_body),
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = apply_norm(cfg, p["dec_norm"], x)
    logits = jnp.einsum(
        "bsd,dv->bsv", x, p["lm_head"].astype(dtype), preferred_element_type=jnp.float32
    )
    new_cache = {"first": [], "body": {"l0": new_body}} if cache is not None else None
    return logits, new_cache


def encdec_loss(
    cfg: ArchConfig, p: Params, batch: dict[str, jax.Array]
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """batch: frames [B,S_src,d], tgt_tokens [B,S_tgt], labels [B,S_tgt]."""
    from repro.models.transformer import softmax_cross_entropy

    src_pos = jnp.arange(batch["frames"].shape[1])[None, :]
    tgt_pos = jnp.arange(batch["tgt_tokens"].shape[1])[None, :]
    enc_out = encode(cfg, p, batch["frames"], src_pos)
    cross = build_cross_cache(cfg, p, enc_out)
    logits, _ = decode_step(cfg, p, batch["tgt_tokens"], tgt_pos, cross)
    ce = softmax_cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}
