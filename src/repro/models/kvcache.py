"""Decode caches: per-layer KV (attention) and SSM/conv state (Mamba2).

The cache is a pytree mirroring the layer stack so it scans with the layers:
stacked leading dim [n_repeats, ...] for the scanned body plus a list for
the unscanned first_k_dense layers. `init_cache` builds zeros (or
ShapeDtypeStructs when `abstract=True`, which the dry-run uses — no
allocation), `cache_specs` mirrors logical sharding axes.

KV layout [B, S_max, Hkv, dh]: batch over ("pod","data"), S_max over
"model" ("cache_seq") — kv_heads (8) do not divide a 16-way model axis, so
sharding the sequence keeps the 16-way split collective-free on update
(dynamic_update_slice on a sharded dim lowers to a masked local update) and
turns decode attention into a flash-decoding-style partial softmax that the
SPMD partitioner completes with a tiny all-reduce of (max, sum) terms.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerKind


def layer_kinds(cfg: ArchConfig) -> list[LayerKind]:
    return [LayerKind("attn", "dense")] * cfg.first_k_dense + list(cfg.pattern)


def _attn_cache(cfg, batch: int, max_len: int, dtype, abstract: bool, stack: int | None):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if stack is not None:
        shape = (stack,) + shape
    mk = (lambda s: jax.ShapeDtypeStruct(s, dtype)) if abstract else (lambda s: jnp.zeros(s, dtype))
    return {"k": mk(shape), "v": mk(shape)}


def _ssm_cache(cfg, batch: int, dtype, abstract: bool, stack: int | None):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    s1 = (batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
    s2 = (batch, cfg.ssm_conv - 1, conv_ch)
    if stack is not None:
        s1, s2 = (stack,) + s1, (stack,) + s2
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (lambda s, d: jnp.zeros(s, d))
    return {"ssm": mk(s1, jnp.float32), "conv": mk(s2, dtype)}


def init_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    *,
    abstract: bool = False,
) -> dict[str, Any]:
    """Cache pytree: {"first": [per-layer dicts], "body": {pattern-pos: stacked}}."""
    reps = cfg.n_repeats
    first = [
        _attn_cache(cfg, batch, max_len, dtype, abstract, None) for _ in range(cfg.first_k_dense)
    ]
    body: dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        if kind.mixer == "attn":
            body[f"l{i}"] = _attn_cache(cfg, batch, max_len, dtype, abstract, reps)
        else:
            body[f"l{i}"] = _ssm_cache(cfg, batch, dtype, abstract, reps)
    cache: dict[str, Any] = {"first": first, "body": body}
    if cfg.encoder_decoder:
        # cross-attention K/V computed once from encoder output at prefill
        cross_shape = (reps, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        mk = (lambda s: jax.ShapeDtypeStruct(s, dtype)) if abstract else (lambda s: jnp.zeros(s, dtype))
        cache["cross"] = {"k": mk(cross_shape), "v": mk(cross_shape)}
    return cache


def cache_specs(cfg: ArchConfig) -> dict[str, Any]:
    """Logical axes per cache leaf, mirroring init_cache structure."""
    attn = {
        "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
    }
    attn_stacked = {
        "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    }
    ssm_stacked = {
        "ssm": ("layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "batch", None, "conv_ch"),
    }
    first = [attn for _ in range(cfg.first_k_dense)]
    body = {
        f"l{i}": (attn_stacked if kind.mixer == "attn" else ssm_stacked)
        for i, kind in enumerate(cfg.pattern)
    }
    out: dict[str, Any] = {"first": first, "body": body}
    if cfg.encoder_decoder:
        out["cross"] = {
            "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        }
    return out


def cache_bytes(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> int:
    """Total cache footprint (for capacity planning / roofline notes)."""
    leaves = jax.tree_util.tree_leaves(
        init_cache(cfg, batch, max_len, dtype, abstract=True),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return sum(
        int(jnp.prod(jnp.array(l.shape))) * jnp.dtype(l.dtype).itemsize for l in leaves
    )
