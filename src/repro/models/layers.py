"""Shared model layers: norms, rotary embeddings (RoPE / M-RoPE), MLPs, init."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def truncated_normal(key, shape, scale: float, dtype) -> jax.Array:
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Norms — accumulate in fp32, return in input dtype.
def init_norm(cfg, key, dim: int, dtype) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg, p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(dtype)
    # (non-)parametric LayerNorm
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


def gated_rmsnorm(scale: jax.Array, x: jax.Array, z: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Mamba2's norm: RMSNorm(x * silu(z)). fp32 accumulation."""
    dtype = x.dtype
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings.
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """[head_dim//2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, D]; positions [..., S] (int). Rotates pairs (x_i, x_{i+D/2})."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, int, int]
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions [3, ..., S] (t/h/w ids);
    `sections` partitions the D/2 frequency slots across the three id streams."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)  # [D/2]
    # sel[j] in {0,1,2}: which position stream drives frequency slot j.
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2)
    pos = jnp.moveaxis(jnp.take(positions, sel, axis=0), 0, -1)  # [..., S, D/2]
    ang = pos.astype(jnp.float32) * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_positional(cfg, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x  # "none"


# ---------------------------------------------------------------------------
# Dense FFN.
def init_mlp(cfg, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    if cfg.act == "swiglu":
        wi = truncated_normal(k1, (d, 2, f), d**-0.5, dtype)
    else:
        wi = truncated_normal(k1, (d, f), d**-0.5, dtype)
    wo = truncated_normal(k2, (f, d), f**-0.5, dtype)
    return {"wi": wi, "wo": wo}


def apply_mlp(cfg, p: Params, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jnp.einsum("...d,dcf->...cf", x, p["wi"].astype(x.dtype))
        gate, up = h[..., 0, :], h[..., 1, :]
        h = jax.nn.silu(gate) * up
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))
