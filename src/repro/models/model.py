"""Unified model facade: one API over decoder-only / hybrid / SSM / enc-dec.

    model = Model(cfg)
    params = model.init(rng)                  # or jax.eval_shape(model.init, rng)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch, cache)
    logits, cache = model.decode(params, batch, cache, index)

`input_specs(cfg, cell)` builds ShapeDtypeStruct stand-ins for every input of
the step function selected by the shape cell (train_step for train cells,
serve prefill/decode for inference cells) — the multi-pod dry-run lowers
against exactly these, no allocation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import encdec as encdec_mod
from repro.models import kvcache
from repro.models import transformer as tfm
from repro.models.layers import Params


def _positions(cfg: ArchConfig, batch: int, seq: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset  # [1, S] broadcasts over B
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))  # text-mode t/h/w ids coincide
    return pos


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- parameters ----------------------------------------------------------
    def init(self, key) -> Params:
        dtype = jnp.dtype(self.cfg.param_dtype)
        if self.cfg.encoder_decoder:
            return encdec_mod.init_encdec(self.cfg, key, dtype)
        return tfm.init_transformer(self.cfg, key, dtype)

    def param_specs(self) -> Params:
        if self.cfg.encoder_decoder:
            return encdec_mod.encdec_specs(self.cfg)
        return tfm.transformer_specs(self.cfg)

    def abstract_params(self) -> Params:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -- training ------------------------------------------------------------
    def loss(self, params: Params, batch: dict[str, jax.Array]):
        if self.cfg.encoder_decoder:
            return encdec_mod.encdec_loss(self.cfg, params, batch)
        return tfm.lm_loss(self.cfg, params, batch)

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, abstract: bool = False):
        return kvcache.init_cache(
            self.cfg, batch, max_len, jnp.dtype(self.cfg.compute_dtype), abstract=abstract
        )

    def cache_specs(self):
        return kvcache.cache_specs(self.cfg)

    def prefill(self, params: Params, batch: dict[str, jax.Array], cache: dict[str, Any]):
        """Fill the cache from a prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        if cfg.encoder_decoder:
            src_pos = jnp.arange(batch["frames"].shape[1], dtype=jnp.int32)[None]
            enc_out = encdec_mod.encode(cfg, params, batch["frames"], src_pos)
            cross = encdec_mod.build_cross_cache(cfg, params, enc_out)
            cache = dict(cache)
            cache["cross"] = cross
            tgt = batch["tgt_tokens"]
            tgt_pos = jnp.arange(tgt.shape[1], dtype=jnp.int32)[None]
            logits, new_cache = encdec_mod.decode_step(
                cfg, params, tgt, tgt_pos, cross, cache, jnp.int32(0)
            )
            new_cache = {**cache, **(new_cache or {}), "cross": cross}
            return logits[:, -1], new_cache
        inputs = batch["inputs"]
        bsz, seq = inputs.shape[0], inputs.shape[1]
        pos = batch.get("positions")
        if pos is None:
            pos = _positions(cfg, bsz, seq)
        logits, new_cache, _ = tfm.forward(
            cfg, params, inputs, pos, cache=cache, cache_index=jnp.int32(0), decode=False
        )
        return logits[:, -1], new_cache

    def decode(
        self,
        params: Params,
        batch: dict[str, jax.Array],
        cache: dict[str, Any],
        index: jax.Array,
    ):
        """One decode step at cache slot `index`; returns (logits [B, V], cache)."""
        cfg = self.cfg
        if cfg.encoder_decoder:
            tokens = batch["tokens"]
            pos = jnp.broadcast_to(index, (tokens.shape[0], 1)).astype(jnp.int32)
            logits, new_cache = encdec_mod.decode_step(
                cfg, params, tokens, pos, cache["cross"], cache, index
            )
            new_cache = {**cache, **(new_cache or {})}
            return logits[:, -1], new_cache
        inputs = batch["tokens"]
        bsz = inputs.shape[0]
        if jnp.ndim(index) == 0:
            pos = jnp.broadcast_to(index, (bsz, 1)).astype(jnp.int32)
        else:  # per-slot positions (continuous batching)
            pos = index[:, None].astype(jnp.int32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, bsz, 1))
        logits, new_cache, _ = tfm.forward(
            cfg, params, inputs, pos, cache=cache, cache_index=index, decode=True
        )
        return logits[:, -1], new_cache


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct, never allocated).
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, Any]:
    """Inputs of the step function the cell exercises.

    train  -> arguments of train_step's batch
    prefill-> batch for `prefill` (cache provided separately via cache specs)
    decode -> batch for `decode`
    """
    b, s = cell.global_batch, cell.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    if cell.kind == "train":
        if cfg.encoder_decoder:
            return {
                "frames": _sds((b, s, cfg.d_model), cdt),
                "tgt_tokens": _sds((b, s), i32),
                "labels": _sds((b, s), i32),
            }
        inp = (
            _sds((b, s), i32) if cfg.embed_inputs else _sds((b, s, cfg.d_model), cdt)
        )
        pos_shape = (3, b, s) if cfg.rope == "mrope" else (b, s)
        return {"inputs": inp, "labels": _sds((b, s), i32), "positions": _sds(pos_shape, i32)}
    if cell.kind == "prefill":
        if cfg.encoder_decoder:
            return {"frames": _sds((b, s, cfg.d_model), cdt), "tgt_tokens": _sds((b, s), i32)}
        inp = _sds((b, s), i32) if cfg.embed_inputs else _sds((b, s, cfg.d_model), cdt)
        pos_shape = (3, b, s) if cfg.rope == "mrope" else (b, s)
        return {"inputs": inp, "positions": _sds(pos_shape, i32)}
    # decode: one new token against a cache of length cell.seq_len
    if cfg.encoder_decoder or cfg.embed_inputs:
        return {"tokens": _sds((b, 1), i32)}
    return {"tokens": _sds((b, 1, cfg.d_model), cdt)}


def batch_like(specs: dict[str, Any], key=None) -> dict[str, jax.Array]:
    """Materialize small concrete inputs matching a spec tree (smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, sd in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sd.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sd.shape, 0, 128, sd.dtype)
        else:
            out[name] = jax.random.normal(sub, sd.shape, sd.dtype) * 0.02
    return out
