"""Top-k mixture-of-experts with sort-based capacity dispatch.

Dispatch strategy (TPU-native, no giant one-hot tensors):
  1. router logits -> top-k (expert_id, prob) per token;
  2. flatten (token, k) slots, stable-sort by expert id;
  3. position-within-expert = slot rank - expert segment start (from a
     bincount/cumsum), so each slot maps to a fixed buffer address
     expert_id * capacity + position; slots beyond capacity are DROPPED
     (scatter mode "drop"), matching capacity-factor routing semantics;
  4. scatter tokens into a contiguous buffer [E, C, d], run a dense
     per-expert einsum [E, C, d] x [E, d, f] (MXU-shaped), gather back and
     combine weighted by router probs.

Junk-FLOPs ratio is exactly the capacity factor (default 1.25): the buffer
is (cf x used slots) big. Sharding:
  * EP  (E % model-axis == 0): buffer + expert weights sharded on the
    expert dim over "model"; combine is a psum the SPMD partitioner inserts.
  * expert-TP (E < model-axis, e.g. grok-1 8e/16-way): expert weights
    sharded on d_ff instead; every shard processes all experts on its d_ff
    slice. Buffer is replicated over "model".

The choice is recorded per-arch by `expert_sharding(cfg, n_model_shards)`.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import Params, truncated_normal


def init_moe(cfg, key, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, ki, ko, ks = jax.random.split(key, 4)
    p = {
        "router": truncated_normal(kr, (d, e), d**-0.5, jnp.float32),  # router in f32
        "wi": truncated_normal(ki, (e, d, 2, f), d**-0.5, dtype),  # gate+up stacked
        "wo": truncated_normal(ko, (e, f, d), f**-0.5, dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff * cfg.n_shared_experts
        p["shared_wi"] = truncated_normal(ks, (d, 2, fs), d**-0.5, dtype)
        p["shared_wo"] = truncated_normal(ks, (fs, d), fs**-0.5, dtype)
    return p


def moe_specs(cfg) -> Params:
    p = {
        "router": ("embed", None),
        "wi": ("experts", "embed", None, "expert_ff"),
        "wo": ("experts", "expert_ff", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared_wi"] = ("embed", None, "mlp")
        p["shared_wo"] = ("mlp", "embed")
    return p


def expert_sharding(cfg, n_model_shards: int) -> str:
    """'ep' if the expert dim divides the model axis, else 'tp' (d_ff split)."""
    if cfg.n_experts and cfg.n_experts % n_model_shards == 0:
        return "ep"
    return "tp"


def capacity(n_tokens: int, cfg) -> int:
    """Per-expert buffer slots; multiple of 8 for clean TPU tiling."""
    c = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token / max(cfg.n_experts, 1))
    return max(8, -(-c // 8) * 8)


# ---------------------------------------------------------------------------
def route(cfg, router_w: jax.Array, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x [T, d] -> (expert_ids [T, k], probs [T, k], aux_loss scalar).

    Softmax-then-topk with probs renormalized over the chosen k. Aux loss is
    the standard load-balance term (mean_prob x mean_assignment x E).
    """
    logits = x.astype(jnp.float32) @ router_w  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.experts_per_token)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balance aux loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # [E] mean router prob
    assign = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    fe = assign / top_i.size  # fraction of slots per expert
    aux = e * jnp.sum(me * fe)
    return top_i, top_p, aux


def dispatch_indices(
    expert_ids: jax.Array, n_experts: int, cap: int
) -> tuple[jax.Array, jax.Array]:
    """expert_ids [T, k] -> (slot_addr [T*k], token_idx [T*k]) in sorted order.

    slot_addr = expert * cap + position-within-expert; addresses with
    position >= cap are mapped out-of-range so scatter/gather drop them.
    """
    t, k = expert_ids.shape
    flat = expert_ids.reshape(-1)  # [T*k]
    order = jnp.argsort(flat, stable=True)  # slots sorted by expert
    sorted_e = flat[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat].add(1)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    addr = jnp.where(pos < cap, sorted_e * cap + pos, n_experts * cap)  # OOB -> dropped
    token_idx = order // k
    return addr, token_idx


def apply_moe(cfg, p: Params, x: jax.Array, cap: int | None = None) -> tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (y [B, S, d], aux_loss). SwiGLU experts.

    With cfg.moe_groups > 1 the tokens are split into G groups along the
    (data-sharded) batch dim and dispatched independently (vmap), so the
    scatter/gather address a per-group buffer [G, E, C/G, d] whose leading
    dim inherits the batch sharding — routing stays shard-local.
    """
    b, s, d = x.shape
    g = max(cfg.moe_groups, 1)
    if g > 1 and b % g == 0:
        xg = x.reshape(g, (b // g) * s, d)
        if cfg.moe_group_axis:
            from jax.sharding import PartitionSpec as P

            xg = jax.lax.with_sharding_constraint(xg, P(cfg.moe_group_axis))
        yg, aux = jax.vmap(lambda xi: _moe_tokens(cfg, p, xi))(xg)
        if cfg.moe_group_axis:
            yg = jax.lax.with_sharding_constraint(yg, P(cfg.moe_group_axis))
        y = yg.reshape(b * s, d)
        aux = jnp.mean(aux)
    else:
        y, aux = _moe_tokens(cfg, p, x.reshape(b * s, d), cap)

    if cfg.n_shared_experts:
        xt = x.reshape(b * s, d)
        hs = jnp.einsum("td,dgf->tgf", xt, p["shared_wi"].astype(xt.dtype))
        hs = jax.nn.silu(hs[..., 0, :]) * hs[..., 1, :]
        y = y + jnp.einsum("tf,fd->td", hs, p["shared_wo"].astype(xt.dtype))

    return y.reshape(b, s, d), aux


def _moe_tokens(cfg, p: Params, xt: jax.Array, cap: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Routed-expert path over flat tokens xt [T, d] -> (y [T, d], aux)."""
    t, d = xt.shape
    cap = cap or capacity(t, cfg)

    ids, probs, aux = route(cfg, p["router"], xt)
    addr, token_idx = dispatch_indices(ids, cfg.n_experts, cap)

    # Scatter tokens into the expert buffer [E*C, d]; OOB addresses dropped.
    buf = jnp.zeros((cfg.n_experts * cap, d), xt.dtype)
    buf = buf.at[addr].set(xt[token_idx], mode="drop")
    buf = buf.reshape(cfg.n_experts, cap, d)

    # Dense per-expert SwiGLU: [E, C, d] x [E, d, 2, f] -> [E, C, 2, f]
    h = jnp.einsum("ecd,edgf->ecgf", buf, p["wi"].astype(xt.dtype))
    h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xt.dtype))  # [E, C, d]
    out = out.reshape(cfg.n_experts * cap, d)

    # Gather per-slot results and combine with router probs.
    y_slot = jnp.take(out, jnp.clip(addr, 0, out.shape[0] - 1), axis=0)
    y_slot = jnp.where((addr < out.shape[0])[:, None], y_slot, 0.0)
    w_slot = probs.reshape(-1)[jnp.argsort(ids.reshape(-1), stable=True)]  # same sorted order
    y = jnp.zeros((t, d), xt.dtype).at[token_idx].add(y_slot * w_slot[:, None].astype(xt.dtype))
    return y, aux


def moe_flops(cfg, n_tokens: int) -> int:
    """Active-parameter FLOPs per MoE layer (routed + shared)."""
    d, f = cfg.d_model, cfg.d_ff
    routed = 2 * n_tokens * cfg.experts_per_token * 3 * d * f
    shared = 2 * n_tokens * cfg.n_shared_experts * 3 * d * f
    router = 2 * n_tokens * d * cfg.n_experts
    return routed + shared + router
