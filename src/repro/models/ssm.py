"""Mamba2 / SSD (state-space duality) mixer.

TPU adaptation: the SSD *chunked* form is used for train/prefill — within a
chunk the recurrence is computed as a small causal attention-like matmul
(MXU-shaped), across chunks a [B, H, P, N] state is carried by lax.scan —
and the O(1)-state recurrent form is used for decode. ngroups = 1 (B and C
shared across heads), matching the Mamba2 defaults for these sizes.

Shapes:
  d_inner = expand * d_model,  H = d_inner / head_dim (P = head_dim), N = ssm_state
  wz, wx   [d_model, d_inner]          logical ("embed", "inner")
  wB, wC   [d_model, N]                logical ("embed", None)
  wdt      [d_model, H]                logical ("embed", "ssm_heads")
  conv_w   [K, d_inner + 2N]           depthwise causal conv, K = ssm_conv
  A_log, D, dt_bias [H]
  out_proj [d_inner, d_model]          logical ("inner", "embed")

The inner dim (H x P) is sharded over "model"; B/C (state dim N) are
replicated, so the chunk scan needs no cross-shard communication and the
out_proj all-reduce is the only collective — same pattern as attention.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import Params, gated_rmsnorm, truncated_normal


def init_ssm(cfg, key, dtype) -> Params:
    d, di, n, h, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_conv
    keys = jax.random.split(key, 7)
    conv_ch = di + 2 * n
    return {
        "wz": truncated_normal(keys[0], (d, di), d**-0.5, dtype),
        "wx": truncated_normal(keys[1], (d, di), d**-0.5, dtype),
        "wB": truncated_normal(keys[2], (d, n), d**-0.5, dtype),
        "wC": truncated_normal(keys[3], (d, n), d**-0.5, dtype),
        "wdt": truncated_normal(keys[4], (d, h), d**-0.5, dtype),
        "conv_w": truncated_normal(keys[5], (k, conv_ch), k**-0.5, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        # A in (-~16, -~0.5): init log-uniform as in the paper
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": truncated_normal(keys[6], (di, d), di**-0.5, dtype),
    }


def ssm_specs(cfg) -> Params:
    return {
        "wz": ("embed", "inner"),
        "wx": ("embed", "inner"),
        "wB": ("embed", None),
        "wC": ("embed", None),
        "wdt": ("embed", "ssm_heads"),
        "conv_w": (None, "conv_ch"),
        "conv_b": ("conv_ch",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm_scale": ("inner",),
        "out_proj": ("inner", "embed"),
    }


# ---------------------------------------------------------------------------
def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]. silu activation."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K is 4: unrolled taps beat a conv op for depthwise on TPU
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _project(cfg, p: Params, u: jax.Array):
    """u [B, S, d] -> z, xc, B, C, dt (conv applied to x/B/C jointly)."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = u @ p["wz"].astype(u.dtype)  # [B,S,di]
    x = u @ p["wx"].astype(u.dtype)
    bmat = u @ p["wB"].astype(u.dtype)  # [B,S,N]
    cmat = u @ p["wC"].astype(u.dtype)
    dt_raw = u @ p["wdt"].astype(u.dtype)  # [B,S,H]
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    return z, xbc, dt_raw


def _split_xbc(cfg, xbc: jax.Array):
    di, n = cfg.d_inner, cfg.ssm_state
    return xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]


def ssd_chunked(
    cfg,
    x: jax.Array,  # [B, S, H, P]
    bmat: jax.Array,  # [B, S, N]
    cmat: jax.Array,  # [B, S, N]
    dt: jax.Array,  # [B, S, H]  (post-softplus)
    a: jax.Array,  # [H]  (negative; A = -exp(A_log))
    init_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """SSD chunked scan. Returns (y [B, S, H, P], final_state [B, H, P, N]).

    All decay math in f32; matmuls take the input dtype on the B/C/x sides
    with f32 accumulation.
    """
    b, s, h, pdim = x.shape
    n = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:
        # Zero-pad to a chunk multiple. dt=0 at padded steps means decay
        # exp(dt*a)=1 and zero state/output contribution, so results over the
        # real prefix (and the carried state) are exact; padded rows are cut.
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, bmat, cmat, dt = zpad(x), zpad(bmat), zpad(cmat), zpad(dt)
    sp = s + pad
    nc = sp // q

    xc = x.reshape(b, nc, q, h, pdim)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)

    dta = dtc * a.astype(jnp.float32)  # [B,nc,Q,H] log-decay per step (<= 0)
    lcum = jnp.cumsum(dta, axis=2)  # inclusive within-chunk cumulative log decay
    l_last = lcum[:, :, -1]  # [B,nc,H]

    # intra-chunk: attention-like causal matmul
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc, preferred_element_type=jnp.float32)
    decay = jnp.exp(lcum[:, :, :, None, :] - lcum[:, :, None, :, :])  # [B,nc,Q,K,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    m = cb[..., None] * decay * dtc[:, :, None, :, :]  # [B,nc,Q,K,H]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xc.astype(jnp.float32))

    # per-chunk outgoing state: sum_k exp(l_last - l_k) dt_k B_k (x) x_k
    seg = jnp.exp(l_last[:, :, None, :] - lcum) * dtc  # [B,nc,Q,H]
    s_chunk = jnp.einsum("bckh,bckn,bckhp->bchpn", seg, bc.astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence over nc chunks
    state0 = (
        jnp.zeros((b, h, pdim, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(state, inp):
        s_c, ll = inp  # [B,H,P,N], [B,H]
        state_in = state
        state = jnp.exp(ll)[:, :, None, None] * state + s_c
        return state, state_in

    (final_state, states_in) = jax.lax.scan(
        step, state0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(l_last, 1, 0))
    )
    states_in = jnp.moveaxis(states_in, 0, 1)  # [B,nc,H,P,N] state entering each chunk

    # inter-chunk contribution: C_q . state_in, decayed to position q
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cc.astype(jnp.float32), states_in)
    y_inter = y_inter * jnp.exp(lcum)[..., None]  # [B,nc,Q,H,1]

    y = (y_intra + y_inter).reshape(b, sp, h, pdim)
    if pad:
        y = y[:, :s]
    return y, final_state


def apply_ssm(
    cfg,
    p: Params,
    u: jax.Array,  # [B, S, d_model]
    *,
    state: dict[str, jax.Array] | None = None,
    decode: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Mamba2 block. Train/prefill when decode=False (state optionally carried
    in/out for chunked prefill); single-token recurrent step when decode=True.

    state = {"ssm": [B,H,P,N] f32, "conv": [B,K-1,conv_ch]}
    """
    h, pdim, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    bsz, s, _ = u.shape
    z, xbc_raw, dt_raw = _project(cfg, p, u)
    a = -jnp.exp(p["A_log"])  # [H]

    new_state = None
    if decode:
        assert s == 1, "decode expects one token"
        conv_st = state["conv"]  # [B, K-1, C]
        window = jnp.concatenate([conv_st, xbc_raw], axis=1)  # [B,K,C]
        xbc = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32)
        ).astype(u.dtype)[:, None]
        x, bmat, cmat = _split_xbc(cfg, xbc)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
        xh = x[:, 0].reshape(bsz, h, pdim).astype(jnp.float32)
        ssm_st = state["ssm"].astype(jnp.float32)  # [B,H,P,N]
        decay = jnp.exp(dt * a)  # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt, bmat[:, 0].astype(jnp.float32), xh)
        ssm_st = decay[:, :, None, None] * ssm_st + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), ssm_st)
        y = y + p["D"][None, :, None] * xh
        y = y.reshape(bsz, 1, cfg.d_inner).astype(u.dtype)
        new_state = {"ssm": ssm_st, "conv": window[:, 1:]}
    else:
        xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
        x, bmat, cmat = _split_xbc(cfg, xbc)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
        xh = x.reshape(bsz, s, h, pdim)
        init = state["ssm"] if state is not None else None
        y, fin = ssd_chunked(cfg, xh, bmat, cmat, dt, a, init)
        y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, s, cfg.d_inner).astype(u.dtype)
        if state is not None:
            k = cfg.ssm_conv
            new_state = {"ssm": fin, "conv": xbc_raw[:, s - (k - 1) :, :]}

    y = gated_rmsnorm(p["norm_scale"], y, z)
    return y @ p["out_proj"].astype(u.dtype), new_state


def ssm_flops(cfg, batch: int, s: int, decode: bool = False) -> int:
    """Model FLOPs of one SSD layer."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    proj = 2 * batch * s * d * (2 * di + 2 * n + h) + 2 * batch * s * di * d
    if decode:
        scan = 2 * batch * s * (di * n * 3)  # state update + readout
    else:
        q = min(cfg.ssm_chunk, s)
        intra = 2 * batch * s * q * (n + di)  # CB^T + M.x per position
        inter = 2 * batch * s * di * n * 2  # state build + readout
        scan = intra + inter
    return proj + scan
