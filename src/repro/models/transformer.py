"""Decoder-only transformer stack: scan-over-layers, hybrid patterns, MoE.

The repeating layer unit (cfg.pattern) is scanned with stacked parameters
[n_repeats, ...] — one XLA compilation of the body regardless of depth
(80-layer qwen2-vl compiles the same body once). `first_k_dense` leading
layers (Kimi-K2 style) run unscanned. Remat policy per cfg.remat:
  none — store all; dots — save matmul outputs, recompute elementwise;
  full — recompute the whole block on the backward pass.

Blocks are pre-norm residual: x += mixer(norm(x)); x += ffn(norm(x)).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerKind
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    apply_mlp,
    apply_norm,
    init_mlp,
    init_norm,
    truncated_normal,
)


# ---------------------------------------------------------------------------
# One block = mixer + ffn with pre-norms.
def init_block(cfg: ArchConfig, kind: LayerKind, key, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg, k1, cfg.d_model, dtype)}
    if kind.mixer == "attn":
        p["attn"] = attn_mod.init_attention(cfg, k2, dtype)
    else:
        p["ssm"] = ssm_mod.init_ssm(cfg, k2, dtype)
    if kind.ffn != "none":
        p["norm2"] = init_norm(cfg, k3, cfg.d_model, dtype)
        if kind.ffn == "moe":
            p["moe"] = moe_mod.init_moe(cfg, k4, dtype)
        else:
            p["mlp"] = init_mlp(cfg, k4, dtype)
    return p


def block_specs(cfg: ArchConfig, kind: LayerKind) -> Params:
    norm = {"scale": ("embed",)} if cfg.norm == "rmsnorm" else (
        {} if cfg.norm == "nonparametric_ln" else {"scale": ("embed",), "bias": ("embed",)}
    )
    p: Params = {"norm1": dict(norm)}
    if kind.mixer == "attn":
        p["attn"] = attn_mod.attention_specs(cfg)
    else:
        p["ssm"] = ssm_mod.ssm_specs(cfg)
    if kind.ffn != "none":
        p["norm2"] = dict(norm)
        if kind.ffn == "moe":
            p["moe"] = moe_mod.moe_specs(cfg)
        else:
            p["mlp"] = {"wi": ("embed", None, "mlp") if cfg.act == "swiglu" else ("embed", "mlp"),
                        "wo": ("mlp", "embed")}
    return p


def apply_block(
    cfg: ArchConfig,
    kind: LayerKind,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: dict[str, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    decode: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array] | None, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)
    if kind.mixer == "attn":
        y, new_cache = attn_mod.apply_attention(
            cfg, p["attn"], h, positions, causal=True, kv_cache=cache, cache_index=cache_index
        )
    else:
        y, new_cache = ssm_mod.apply_ssm(cfg, p["ssm"], h, state=cache, decode=decode)
    x = x + y
    if kind.ffn != "none":
        h = apply_norm(cfg, p["norm2"], x)
        if kind.ffn == "moe":
            y, aux = moe_mod.apply_moe(cfg, p["moe"], h)
        else:
            y = apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full stack.
def init_transformer(cfg: ArchConfig, key, dtype) -> Params:
    keys = jax.random.split(key, 6)
    p: Params = {}
    if cfg.embed_inputs:
        p["embed"] = truncated_normal(keys[0], (cfg.padded_vocab, cfg.d_model), cfg.d_model**-0.5, dtype)
    p["first"] = [
        init_block(cfg, LayerKind("attn", "dense"), k, dtype)
        for k in jax.random.split(keys[1], max(cfg.first_k_dense, 1))[: cfg.first_k_dense]
    ]
    reps = cfg.n_repeats
    body: Params = {}
    for i, kind in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[2], i), reps)
        body[f"l{i}"] = jax.vmap(lambda k: init_block(cfg, kind, k, dtype))(ks)
    p["body"] = body
    p["final_norm"] = init_norm(cfg, keys[3], cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = truncated_normal(keys[4], (cfg.d_model, cfg.padded_vocab), cfg.d_model**-0.5, dtype)
    return p


def transformer_specs(cfg: ArchConfig) -> Params:
    def stack(spec_tree):
        return jax.tree_util.tree_map(
            lambda axes: ("layers",) + tuple(axes), spec_tree,
            is_leaf=lambda v: isinstance(v, tuple),
        )

    p: Params = {}
    if cfg.embed_inputs:
        p["embed"] = ("vocab", "embed")
    p["first"] = [block_specs(cfg, LayerKind("attn", "dense")) for _ in range(cfg.first_k_dense)]
    p["body"] = {f"l{i}": stack(block_specs(cfg, kind)) for i, kind in enumerate(cfg.pattern)}
    norm = {"scale": ("embed",)} if cfg.norm == "rmsnorm" else (
        {} if cfg.norm == "nonparametric_ln" else {"scale": ("embed",), "bias": ("embed",)}
    )
    p["final_norm"] = norm
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    return p


def embed_tokens(cfg: ArchConfig, p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embed"], tokens, axis=0).astype(dtype)


def logits_from_hidden(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype), preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# Per-layer parameter transform applied inside the scan body (explicit
# ZeRO-3 at-use weight gathering). Set via `layer_param_hook`; None = off.
_LAYER_PARAM_HOOK = None


class layer_param_hook:
    """Context manager installing a per-layer param transform for tracing."""

    def __init__(self, hook):
        self.hook = hook

    def __enter__(self):
        global _LAYER_PARAM_HOOK
        self._prev = _LAYER_PARAM_HOOK
        _LAYER_PARAM_HOOK = self.hook
        return self

    def __exit__(self, *exc):
        global _LAYER_PARAM_HOOK
        _LAYER_PARAM_HOOK = self._prev
        return False


def _body_scan(cfg, body_params, x, positions, cache_body, cache_index, decode):
    """Scan the repeating unit. cache_body threads through as scan xs/ys."""
    npos = len(cfg.pattern)

    def unit(carry, xs):
        x, aux = carry
        params_i, cache_i = xs
        if _LAYER_PARAM_HOOK is not None:
            params_i = _LAYER_PARAM_HOOK(params_i)
        new_caches = {}
        for j, kind in enumerate(cfg.pattern):
            cj = cache_i[f"l{j}"] if cache_i is not None else None
            x, nc, a = apply_block(
                cfg, kind, params_i[f"l{j}"], x, positions,
                cache=cj, cache_index=cache_index, decode=decode,
            )
            aux = aux + a
            if nc is not None:
                new_caches[f"l{j}"] = nc
        return (x, aux), (new_caches if new_caches else None)

    if cfg.remat == "full":
        unit = jax.checkpoint(unit, prevent_cse=False)
    elif cfg.remat == "dots":
        unit = jax.checkpoint(
            unit, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False
        )

    (x, aux), new_cache_body = jax.lax.scan(
        unit, (x, jnp.zeros((), jnp.float32)), (body_params, cache_body),
        unroll=cfg.n_repeats if cfg.unroll_layers else 1,
    )
    return x, aux, new_cache_body


def forward(
    cfg: ArchConfig,
    p: Params,
    inputs: jax.Array,
    positions: jax.Array,
    *,
    cache: dict[str, Any] | None = None,
    cache_index: jax.Array | None = None,
    decode: bool = False,
    compute_dtype=None,
) -> tuple[jax.Array, dict[str, Any] | None, jax.Array]:
    """inputs: int tokens [B, S] (embed_inputs) or embeddings [B, S, d].

    Returns (logits [B, S, V] f32, new_cache, aux_loss).
    """
    dtype = compute_dtype or jnp.dtype(cfg.compute_dtype)
    if cfg.embed_inputs:
        x = embed_tokens(cfg, p, inputs, dtype)
    else:
        x = inputs.astype(dtype)

    new_cache: dict[str, Any] | None = {"first": [], "body": None} if cache is not None else None
    for i in range(cfg.first_k_dense):
        ci = cache["first"][i] if cache is not None else None
        x, nc, _ = apply_block(
            cfg, LayerKind("attn", "dense"), p["first"][i], x, positions,
            cache=ci, cache_index=cache_index, decode=decode,
        )
        if new_cache is not None:
            new_cache["first"].append(nc)

    cache_body = cache["body"] if cache is not None else None
    x, aux, ncb = _body_scan(cfg, p["body"], x, positions, cache_body, cache_index, decode)
    if new_cache is not None:
        new_cache["body"] = ncb

    x = apply_norm(cfg, p["final_norm"], x)
    logits = logits_from_hidden(cfg, p, x)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [B, S, V] (f32), labels [B, S] int. Mean over all tokens.

    Works with vocab sharded over "model": the max/sum reductions lower to
    small all-reduces under SPMD.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    # m must be stop-gradient on BOTH uses: d lse/d logits == softmax(logits)
    # comes entirely from the log-sum-exp term (adding raw m back would leak
    # an extra onehot(argmax) into every gradient).
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_cross_entropy(
    cfg: ArchConfig, p: Params, x: jax.Array, labels: jax.Array, chunk: int
) -> jax.Array:
    """CE over vocab chunks: the [B,S,V] f32 logits are never materialized.

    Online logsumexp over chunks of the lm_head: each scan step computes
    logits for `chunk` vocab columns, folds them into running (max, sumexp)
    and picks up the gold logit where the label falls in the chunk. The
    body is rematerialized on the backward pass (memory O(B·S·chunk)).
    Exactly equals softmax_cross_entropy(logits_from_hidden(x), labels)
    when logit_softcap == 0.
    """
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]  # [d, V]
    v = head.shape[-1]
    assert v % chunk == 0, (v, chunk)
    nc = v // chunk
    hc = head.reshape(head.shape[0], nc, chunk)
    b, s, _ = x.shape

    def body(carry, args):
        m, se, gold = carry
        ci, hslice = args  # hslice [d, chunk]
        lg = jnp.einsum("bsd,dv->bsv", x, hslice.astype(x.dtype),
                        preferred_element_type=jnp.float32)
        if cfg.logit_softcap > 0.0:
            lg = jnp.tanh(lg / cfg.logit_softcap) * cfg.logit_softcap
        cm = jnp.maximum(m, jnp.max(lg, axis=-1))  # [B,S]
        se = se * jnp.exp(m - cm) + jnp.sum(jnp.exp(lg - cm[..., None]), axis=-1)
        local = labels - ci * chunk
        in_chunk = (local >= 0) & (local < chunk)
        g = jnp.take_along_axis(lg, jnp.clip(local, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, g, gold)
        return (cm, se, gold), None

    body = jax.checkpoint(body, prevent_cse=False)
    init = (
        jnp.full((b, s), -jnp.inf, jnp.float32),
        jnp.zeros((b, s), jnp.float32),
        jnp.zeros((b, s), jnp.float32),
    )
    (m, se, gold), _ = jax.lax.scan(
        body, init, (jnp.arange(nc), jnp.moveaxis(hc, 1, 0))
    )
    lse = jnp.log(se) + m
    return jnp.mean(lse - gold)


def lm_loss(
    cfg: ArchConfig, p: Params, batch: dict[str, jax.Array], aux_weight: float = 0.01
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """batch: {"inputs": [B,S] or [B,S,d], "labels": [B,S], "positions": ...}."""
    if cfg.ce_vocab_chunk > 0:
        dtype = jnp.dtype(cfg.compute_dtype)
        if cfg.embed_inputs:
            x = embed_tokens(cfg, p, batch["inputs"], dtype)
        else:
            x = batch["inputs"].astype(dtype)
        new_cache: Any = None
        for i in range(cfg.first_k_dense):
            x, _, _ = apply_block(
                cfg, LayerKind("attn", "dense"), p["first"][i], x, batch["positions"],
            )
        x, aux, _ = _body_scan(cfg, p["body"], x, batch["positions"], None, None, False)
        x = apply_norm(cfg, p["final_norm"], x)
        ce = chunked_cross_entropy(cfg, p, x, batch["labels"], cfg.ce_vocab_chunk)
    else:
        logits, _, aux = forward(cfg, p, batch["inputs"], batch["positions"])
        ce = softmax_cross_entropy(logits, batch["labels"])
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
