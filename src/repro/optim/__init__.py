from repro.optim.optimizer import Optimizer, make_optimizer, make_schedule, state_logical_specs

__all__ = ["Optimizer", "make_optimizer", "make_schedule", "state_logical_specs"]
