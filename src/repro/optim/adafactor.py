"""Adafactor (factored second moments) for the >=300B archs.

For params with >= 2 dims, the second moment is stored as row/col factors
(O(n+m) instead of O(nm)); 1-D params keep a full accumulator. No first
moment (beta1=0 variant), relative step sizing off — the train loop passes
the schedule's lr. This is what makes kimi-k2 (1T params) state fit:
AdamW fp32 m+v would be ~8 TB; factored state is ~2 GB + the bf16 params.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    vr: Any  # row factors (or full v for 1-D)
    vc: Any  # col factors (zeros() placeholder for 1-D)
    count: jax.Array


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    def vr_like(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros(p.shape, jnp.float32)

    def vc_like(p):
        return (
            jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            if _factored(p)
            else jnp.zeros((1,), jnp.float32)
        )

    return AdafactorState(
        vr=jax.tree_util.tree_map(vr_like, params),
        vc=jax.tree_util.tree_map(vc_like, params),
        count=jnp.zeros((), jnp.int32),
    )


def abstract_init(params) -> AdafactorState:
    return jax.eval_shape(init, params)


def update(
    grads,
    state: AdafactorState,
    params,
    lr,
    *,
    decay: float = 0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    count = state.count + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)  # schedule from the paper

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps1
        if _factored(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps1)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g * jax.lax.rsqrt(vhat + eps1)
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(vr + eps1)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        # relative step size: scale by RMS of the parameter (floored at eps2)
        p_rms = jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32))) + eps1)
        newp = p.astype(jnp.float32) - lr * jnp.maximum(eps2, p_rms) * u
        if weight_decay:
            newp = newp - lr * weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), vr, vc

    out = jax.tree_util.tree_map(upd, params, grads, state.vr, state.vc)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return pick(0), AdafactorState(pick(1), pick(2), count), {}
