"""AdamW with ZeRO-1-ready state layout.

State m/v mirror the param pytree. Under pjit, `state_specs` shards each
moment over the "data" axis on the largest dimension the param spec leaves
free (ZeRO-1): the moment update computes shard-local, and the SPMD
partitioner emits the param all-gather after the update — exactly the
ZeRO-1 schedule, derived from sharding annotations instead of hand-written
collectives. Moments are f32 regardless of param dtype; updates are applied
in f32 and cast back.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> AdamWState:
    f32_like = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree_util.tree_map(f32_like, params),
        v=jax.tree_util.tree_map(f32_like, params),
        count=jnp.zeros((), jnp.int32),
    )


def abstract_init(params) -> AdamWState:
    return jax.eval_shape(init, params)


def update(
    grads,
    state: AdamWState,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    count = state.count + 1
    # global-norm clip in f32
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) if grad_clip else 1.0

    bc1 = 1 - b1**count.astype(jnp.float32)
    bc2 = 1 - b2**count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}
