"""Optimizer facade: name -> (init, update) with per-arch selection and
ZeRO-1 state sharding specs derived from parameter specs."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax

from repro.optim import adafactor, adamw
from repro.optim.schedule import SCHEDULES


class Optimizer(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    abstract_init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any, dict]]


def make_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return Optimizer("adamw", adamw.init, adamw.abstract_init, adamw.update)
    if name == "adafactor":
        return Optimizer("adafactor", adafactor.init, adafactor.abstract_init, adafactor.update)
    raise ValueError(f"unknown optimizer {name!r}")


def state_logical_specs(opt: Optimizer, param_specs, params_abstract):
    """Logical axes for optimizer state, mirroring param specs.

    AdamW: m/v inherit the param's axes, and `zero1` (applied by the rules
    engine in launch/mesh.py) additionally shards the first free axis over
    "data". Adafactor: row factor drops the last axis, col factor drops the
    second-to-last.
    """
    is_axes = lambda v: isinstance(v, tuple) and all(a is None or isinstance(a, str) for a in v)
    if opt.name == "adamw":
        return adamw.AdamWState(m=param_specs, v=param_specs, count=())
    # adafactor
    def vr_spec(axes):
        return tuple(axes[:-1]) if len(axes) >= 2 else tuple(axes)

    def vc_spec(axes):
        return tuple(axes[:-2]) + tuple(axes[-1:]) if len(axes) >= 2 else (None,)

    vr = jax.tree_util.tree_map(vr_spec, param_specs, is_leaf=is_axes)
    vc = jax.tree_util.tree_map(vc_spec, param_specs, is_leaf=is_axes)
    return adafactor.AdafactorState(vr=vr, vc=vc, count=())


def make_schedule(name: str, **kw) -> Callable:
    fn = SCHEDULES[name]
    return lambda step: fn(step, **kw)
