from repro.runtime.train_loop import (
    SimulatedFailure,
    TrainConfig,
    TrainResult,
    make_train_step,
    run_with_restarts,
    train,
)
from repro.runtime.requests import (
    Completion,
    QueryCompletion,
    QueryRequest,
    Request,
    RequestQueue,
)
from repro.runtime.serve_loop import SlotServer
from repro.runtime.loadgen import arrival_times, generate_trace, sample_params
from repro.runtime.serve_query import (
    QueryServer,
    ServeReport,
    measure_saturation,
    run_open_loop,
)

__all__ = [
    "SimulatedFailure", "TrainConfig", "TrainResult", "make_train_step",
    "run_with_restarts", "train", "Completion", "Request", "SlotServer",
    "QueryCompletion", "QueryRequest", "RequestQueue",
    "arrival_times", "generate_trace", "sample_params",
    "QueryServer", "ServeReport", "measure_saturation", "run_open_loop",
]
