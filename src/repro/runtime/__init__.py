from repro.runtime.train_loop import (
    SimulatedFailure,
    TrainConfig,
    TrainResult,
    make_train_step,
    run_with_restarts,
    train,
)
from repro.runtime.serve_loop import Completion, Request, SlotServer

__all__ = [
    "SimulatedFailure", "TrainConfig", "TrainResult", "make_train_step",
    "run_with_restarts", "train", "Completion", "Request", "SlotServer",
]
