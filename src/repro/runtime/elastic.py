"""Elastic scaling: react to a changed execution-resource set, live.

Two faces of the same problem live here:

* **Device elasticity** (jax meshes): when a pod loses (or regains) hosts,
  the controller rebuilds the mesh over the surviving devices and
  ``reshard``s params/optimizer state onto it — ``device_put`` with the new
  NamedShardings performs the minimal movement (a resharding collective on
  real hardware).  The shape cells keep working as long as the new data
  axis still divides the global batch; otherwise ``fit_batch`` computes the
  largest divisible batch (documented drop).  ``plan_mesh`` picks the
  largest (data, model) grid that (a) fits the device count and (b) keeps
  ``model`` a divisor of the previous model-axis size, so TP-sharded dims
  stay divisible after shrinking.

* **Fleet elasticity** (sweep workers): :class:`FleetWatcher` follows a
  :mod:`repro.runtime.membership` registry while a
  :class:`repro.core.scheduler.FleetScheduler` run is in flight — a newly
  registered worker becomes a pull sink mid-sweep (``add_sink``), and a
  worker whose heartbeats stop is marked dead within the registry's
  suspicion bound (``mark_dead``), re-enqueueing its queued AND in-flight
  units on the survivors.  Merged reports stay byte-identical to
  sequential runs throughout: membership only changes WHERE units execute,
  never what rows they produce.

jax imports are lazy (inside the mesh functions) so the fleet half is
importable from :mod:`repro.core` paths without dragging an accelerator
runtime into transport code.
"""
from __future__ import annotations

import logging
import threading
from typing import Any, Callable

from repro.core.remote import HEARTBEAT_INTERVAL_S, fleet_view, parse_fleet
from repro.core.scheduler import FleetScheduler, Sink

logger = logging.getLogger(__name__)

#: Consecutive all-replica poll failures before the watcher logs a warning
#: (one warning per dark spell, not one per tick).
DARK_POLLS_WARN = 5


# -- device elasticity (jax mesh) ---------------------------------------------
def plan_mesh(n_devices: int, prev_model: int = 1) -> tuple[int, int]:
    """(data, model) for a degraded device count."""
    model = prev_model
    while model > 1 and (n_devices % model != 0):
        model //= 2
    data = n_devices // model
    return data, model


def remesh(devices: list, data: int, model: int):
    import numpy as np
    from jax.sharding import Mesh

    arr = np.array(devices[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard(tree: Any, rules, spec_tree: Any, new_mesh) -> Any:
    """Move live arrays onto the new mesh (minimal-movement device_put)."""
    import jax
    from jax.sharding import NamedSharding

    shardings = jax.tree_util.tree_map(
        lambda axes: NamedSharding(new_mesh, rules.spec(axes)),
        spec_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(a is None or isinstance(a, str) for a in v),
    )
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shard_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda v: isinstance(v, NamedSharding)
    )
    return jax.tree_util.tree_unflatten(
        treedef, [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    )


def fit_batch(global_batch: int, n_data: int) -> int:
    """Largest batch <= global_batch divisible by the new data-parallel width."""
    return (global_batch // n_data) * n_data


# -- fleet elasticity (membership -> scheduler sinks) -------------------------
class FleetWatcher:
    """Mirror a membership registry's view into a running scheduler.

    ``registry_endpoint`` may name several replicas
    (``a:7170,b:7170,c:7170``): every poll queries ALL of them in one
    concurrent wave and computes the delta against the merged last-beat-wins
    quorum view, so losing replica 1 costs nothing — replica 2's answer was
    already in flight in the same tick.  Polls ``fleet`` every ``poll_s``
    and applies the delta:

    * an **alive** endpoint not yet in the sink set -> ``make_sink(ep)`` +
      ``scheduler.add_sink`` (dynamic-eligibility units become claimable
      by it immediately — the join half of elasticity);
    * a tracked endpoint now **suspect**/absent -> ``scheduler.mark_dead``
      (queued tickets re-home, in-flight units re-enqueue on survivors —
      the leave half, bounded by the registry's ``suspect_beats x
      heartbeat interval``, i.e. seconds).  A worker that re-registers
      later simply joins again as a fresh sink.

    A transient registry outage changes nothing: the last applied view
    stands until some replica answers again (no flapping the whole fleet
    dead on one lost poll).  Dark polls ARE counted though —
    ``poll_failures`` holds the consecutive all-replica failure streak
    (``dark_polls`` the lifetime total), a warning is logged once the
    streak hits :data:`DARK_POLLS_WARN`, and the executor copies the final
    streak into ``SweepStats.registry_poll_failures`` so a sweep that
    finished with a dark control plane says so in its stats.
    """

    def __init__(
        self,
        registry_endpoint: str,
        scheduler: FleetScheduler,
        make_sink: Callable[[str], Sink],
        poll_s: float = HEARTBEAT_INTERVAL_S / 2,
        observe: Callable[[list[dict]], None] | None = None,
    ):
        self.replicas = parse_fleet(registry_endpoint)
        # Canonical comma-joined form kept for callers that log/compare it.
        self.registry_endpoint = ",".join(self.replicas)
        self.scheduler = scheduler
        self.make_sink = make_sink
        self.poll_s = float(poll_s)
        self.poll_failures = 0  # consecutive polls with ZERO replicas answering
        self.dark_polls = 0  # lifetime total of such polls
        # Optional tap on every fetched fleet view (full member rows, before
        # the join/leave delta is applied).  The executor uses it to keep its
        # advertised capacity/throughput map fresh from heartbeat payloads so
        # joining workers never need a startup ping.
        self.observe = observe
        # Seed from the scheduler's initial sinks (built from the same
        # registry view moments ago); endpoints we've marked dead stay in
        # the map so a stale 'suspect' row doesn't re-kill them.
        self._tracked: dict[str, str] = {name: "alive" for name in scheduler.live_sinks()}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.joined: list[str] = []
        self.left: list[str] = []

    def poll_once(self) -> None:
        """Fetch the merged quorum view and apply one membership delta."""
        members, answered = fleet_view(self.replicas, timeout=max(2.0, self.poll_s))
        if not answered:
            # Transient outage of EVERY replica: keep the last applied view,
            # but count it — a sweep must be able to report that it finished
            # under a dark control plane.
            self.poll_failures += 1
            self.dark_polls += 1
            if self.poll_failures == DARK_POLLS_WARN:
                logger.warning(
                    "membership registry dark: %d consecutive polls with no "
                    "replica answering (%s); keeping the last fleet view",
                    self.poll_failures, self.registry_endpoint,
                )
            return
        self.poll_failures = 0
        if self.observe is not None:
            try:
                self.observe(members)
            except Exception:  # an observer bug must not stall membership
                pass
        status = {m["endpoint"]: m["status"] for m in members}
        for ep, st in status.items():
            if st != "alive":
                continue
            prev = self._tracked.get(ep)
            if prev is None or prev == "dead":
                # New worker (or a re-registered one): join as a fresh sink.
                self.scheduler.add_sink(self.make_sink(ep))
                self._tracked[ep] = "alive"
                self.joined.append(ep)
        for ep, prev in list(self._tracked.items()):
            if prev != "alive":
                continue
            st = status.get(ep)
            if st is None or st != "alive":
                # Beats stopped (suspect), declared dead+pruned, or cleanly
                # deregistered: stop sending, re-dispatch its units.
                self.scheduler.mark_dead(ep)
                self._tracked[ep] = "dead"
                self.left.append(ep)

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            self.poll_once()

    def start(self) -> "FleetWatcher":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="fleet-watcher"
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


__all__ = [
    "DARK_POLLS_WARN",
    "FleetWatcher",
    "fit_batch",
    "plan_mesh",
    "remesh",
    "reshard",
]
