"""Elastic scaling: re-mesh live state onto a changed device set.

When a pod loses (or regains) hosts, the controller rebuilds the mesh over
the surviving devices and `reshard`s params/optimizer state onto it —
device_put with the new NamedShardings performs the minimal movement (a
resharding collective on real hardware). The shape cells keep working as
long as the new data axis still divides the global batch; otherwise
`fit_batch` computes the largest divisible batch (documented drop).

`plan_mesh` picks the largest (data, model) grid that (a) fits the device
count and (b) keeps `model` a divisor of the previous model-axis size, so
TP-sharded dims stay divisible after shrinking.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import Rules


def plan_mesh(n_devices: int, prev_model: int = 1) -> tuple[int, int]:
    """(data, model) for a degraded device count."""
    model = prev_model
    while model > 1 and (n_devices % model != 0):
        model //= 2
    data = n_devices // model
    return data, model


def remesh(devices: list, data: int, model: int) -> Mesh:
    arr = np.array(devices[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard(tree: Any, rules: Rules, spec_tree: Any, new_mesh: Mesh) -> Any:
    """Move live arrays onto the new mesh (minimal-movement device_put)."""
    shardings = jax.tree_util.tree_map(
        lambda axes: NamedSharding(new_mesh, rules.spec(axes)),
        spec_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(a is None or isinstance(a, str) for a in v),
    )
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shard_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda v: isinstance(v, NamedSharding)
    )
    return jax.tree_util.tree_unflatten(
        treedef, [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    )


def fit_batch(global_batch: int, n_data: int) -> int:
    """Largest batch <= global_batch divisible by the new data-parallel width."""
    return (global_batch // n_data) * n_data
