"""Open-loop load generation for the query-serving front end.

Open-loop means arrival times are scheduled up front from the rate process
— they do NOT depend on when earlier requests finish, so a slow server
accumulates queueing delay instead of silently throttling the workload
(the coordinated-omission trap of closed-loop drivers).  Everything is
seeded through ``random.Random`` so a trace is a pure function of
``(seed, rate, duration, queries)``.
"""
from __future__ import annotations

import random
from typing import Any

from repro.runtime.requests import QueryRequest

ARRIVALS = ("poisson", "fixed")


def arrival_times(
    rate: float, duration_s: float, *, arrival: str = "poisson", seed: int = 0
) -> list[float]:
    """Scheduled arrival offsets (seconds) in ``[0, duration_s)``.

    ``poisson`` draws exponential inter-arrival gaps at ``rate`` req/s;
    ``fixed`` spaces requests exactly ``1/rate`` apart starting at t=0.
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    if arrival == "fixed":
        return [i / rate for i in range(int(rate * duration_s))]
    if arrival != "poisson":
        raise ValueError(f"unknown arrival process {arrival!r} (want one of {ARRIVALS})")
    rng = random.Random(seed)
    times: list[float] = []
    t = rng.expovariate(rate)
    while t < duration_s:
        times.append(t)
        t += rng.expovariate(rate)
    return times


def sample_params(query: str, rng: random.Random) -> dict[str, Any]:
    """Draw one request's constants for ``query``, uniform over the ranges
    the TPC-H spec randomizes (Q1 delta, Q6 year/discount/quantity, Q12
    year).  Every draw stays within the fused kernels' encodable domain."""
    if query == "q1":
        return {"delta_days": float(rng.randint(60, 120))}
    if query == "q6":
        return {
            "year": rng.randint(1993, 1997),
            "discount": round(rng.uniform(0.02, 0.09), 2),
            "qty": float(rng.randint(24, 25)),
        }
    if query == "q12":
        return {"year": rng.randint(1993, 1997)}
    raise ValueError(f"unknown query {query!r}")


def generate_trace(
    queries: list[str],
    rate: float,
    duration_s: float,
    *,
    arrival: str = "poisson",
    seed: int = 0,
) -> list[QueryRequest]:
    """A full request trace: seeded arrivals x seeded per-request constants.

    Query names round-robin over ``queries`` and constants come from a
    separate stream keyed off the same seed, so the trace is deterministic
    end to end (asserted in tests/test_serving.py).
    """
    if not queries:
        raise ValueError("need at least one query name")
    times = arrival_times(rate, duration_s, arrival=arrival, seed=seed)
    prng = random.Random(seed + 0x9E3779B9)  # distinct stream from arrivals
    return [
        QueryRequest(
            uid=i,
            query=queries[i % len(queries)],
            params=sample_params(queries[i % len(queries)], prng),
            arrival_s=t,
        )
        for i, t in enumerate(times)
    ]
