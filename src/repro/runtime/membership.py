"""Fleet membership: worker registration, heartbeats, bounded failure detection.

Before this service a ``--remote`` fleet was a hand-typed endpoint list and a
dead worker was only discovered when a request's socket timeout expired.
Here the fleet is *elastic*: workers announce themselves to a registry
(``register``), prove liveness every :data:`~repro.core.remote.
HEARTBEAT_INTERVAL_S` seconds (``heartbeat``), and are classified with a
bounded failure detector —

  ``alive``    last beat within ``suspect_beats x interval`` (default 3
               missed beats, i.e. seconds, not the 600 s request timeout);
  ``suspect``  beats stopped; schedulers must stop sending NEW work and
               re-dispatch the worker's in-flight units elsewhere;
  ``dead``     silent past ``dead_beats x interval``; pruned from the table.

The wire protocol is the same newline-JSON request/response the worker
transport speaks (:mod:`repro.core.remote` defines the ``register``/
``heartbeat`` op pair and the client helpers), so a registry is one more
``host:port`` and `wait_ready`/`ping` work against it unchanged.  Run one
standalone::

    python -m repro.runtime.membership serve --host 0.0.0.0 --port 7170

and point workers (``--register HOST:7170``) and sweep runners
(``--registry HOST:7170``) at it.  :class:`repro.runtime.elastic.
FleetWatcher` turns the registry's view into live scheduler sink set
changes mid-sweep.

A single registry is a single point of failure for the whole fleet view,
so the plane replicates: :class:`ReplicatedRegistry` peers N replicas that
anti-entropy-sync their worker tables (``sync`` op, last-beat-wins per
worker), workers fan heartbeats to every replica (``--register a,b,c``),
and consumers merge whatever subset of replicas answers
(:func:`repro.core.remote.fleet_view`).  Serve a loopback quorum with
``serve --replicas 3``, or peer standalone processes with ``--peers``.
"""
from __future__ import annotations

import argparse
import random
import socket
import socketserver
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from repro.core.remote import (
    HEARTBEAT_INTERVAL_S,
    JsonLineHandler,
    parse_endpoint,
    parse_fleet,
)

#: Missed beats before a worker is suspected (failure-detection bound).
SUSPECT_BEATS = 3
#: Missed beats before a suspect worker is declared dead and pruned.
DEAD_BEATS = 10


@dataclass
class WorkerRecord:
    """One registered worker as the registry sees it."""

    endpoint: str
    capacity: int = 1
    meta: dict[str, Any] = field(default_factory=dict)
    registered_unix: float = 0.0
    last_seen: float = 0.0  # monotonic, registry clock
    beats: int = 0
    # Ping-equivalent measured-throughput payload, refreshed on every beat:
    # discovery (FleetWatcher, --registry startup, @auto weights) reads it
    # from the fleet view instead of pinging each member.
    throughput: dict[str, Any] | None = None


class MembershipRegistry:
    """Thread-safe worker table with heartbeat-based failure detection.

    Pure state machine — servers feed it ``register``/``heartbeat``/
    ``deregister``/``fleet`` requests through :meth:`handle`; tests drive it
    with an injected clock.  A heartbeat from an unknown endpoint
    re-registers it (a restarted registry repopulates from the next beat
    wave instead of losing the fleet).
    """

    def __init__(
        self,
        heartbeat_interval_s: float = HEARTBEAT_INTERVAL_S,
        suspect_beats: int = SUSPECT_BEATS,
        dead_beats: int = DEAD_BEATS,
        now: Callable[[], float] = time.monotonic,
    ):
        if heartbeat_interval_s <= 0:
            raise ValueError(f"heartbeat interval must be > 0, got {heartbeat_interval_s}")
        if not 0 < suspect_beats < dead_beats:
            raise ValueError(
                f"need 0 < suspect_beats < dead_beats, got {suspect_beats}/{dead_beats}"
            )
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.suspect_beats = int(suspect_beats)
        self.dead_beats = int(dead_beats)
        self._now = now
        self._lock = threading.Lock()
        self._workers: dict[str, WorkerRecord] = {}

    # -- events --------------------------------------------------------------
    def register(
        self, endpoint: str, capacity: int = 1, meta: dict[str, Any] | None = None
    ) -> dict[str, Any]:
        parse_endpoint(endpoint)  # reject junk before it enters the table
        with self._lock:
            self._workers[endpoint] = WorkerRecord(
                endpoint=endpoint,
                capacity=max(1, int(capacity)),
                meta=dict(meta or {}),
                registered_unix=time.time(),
                last_seen=self._now(),
                beats=0,
            )
        return {
            "ok": True,
            "op": "register",
            "heartbeat_interval_s": self.heartbeat_interval_s,
            "suspect_beats": self.suspect_beats,
        }

    def heartbeat(
        self,
        endpoint: str,
        capacity: int | None = None,
        throughput: dict[str, Any] | None = None,
    ) -> dict[str, Any]:
        with self._lock:
            rec = self._workers.get(endpoint)
            known = rec is not None
        if rec is None:
            # Unknown endpoint (registry restarted, or beat raced ahead of
            # register): the beat carries enough to (re-)admit the worker.
            self.register(endpoint, capacity=capacity or 1)
            with self._lock:
                rec = self._workers[endpoint]
        with self._lock:
            rec.last_seen = self._now()
            rec.beats += 1
            if capacity is not None:
                rec.capacity = max(1, int(capacity))
            if throughput is not None:
                rec.throughput = dict(throughput)
        return {"ok": True, "op": "heartbeat", "known": known}

    def deregister(self, endpoint: str) -> dict[str, Any]:
        with self._lock:
            known = self._workers.pop(endpoint, None) is not None
        return {"ok": True, "op": "deregister", "known": known}

    # -- failure detection ---------------------------------------------------
    def status_of(self, rec: WorkerRecord, now: float | None = None) -> str:
        age = (self._now() if now is None else now) - rec.last_seen
        if age <= self.suspect_beats * self.heartbeat_interval_s:
            return "alive"
        if age <= self.dead_beats * self.heartbeat_interval_s:
            return "suspect"
        return "dead"

    def members(self) -> list[dict[str, Any]]:
        """Current fleet view, dead workers pruned; sorted for determinism."""
        now = self._now()
        out: list[dict[str, Any]] = []
        with self._lock:
            dead = [ep for ep, r in self._workers.items() if self.status_of(r, now) == "dead"]
            for ep in dead:
                del self._workers[ep]
            for ep in sorted(self._workers):
                r = self._workers[ep]
                out.append(
                    {
                        "endpoint": r.endpoint,
                        "capacity": r.capacity,
                        "status": self.status_of(r, now),
                        "age_s": now - r.last_seen,
                        "beats": r.beats,
                        "meta": dict(r.meta),
                        "throughput": dict(r.throughput) if r.throughput else None,
                    }
                )
        return out

    def alive(self) -> list[str]:
        return [m["endpoint"] for m in self.members() if m["status"] == "alive"]

    def __len__(self) -> int:
        with self._lock:
            return len(self._workers)

    # -- wire dispatch -------------------------------------------------------
    def handle(self, req: dict[str, Any]) -> dict[str, Any]:
        """Serve one registry op (shared by any JSON-line server front end)."""
        op = req.get("op")
        if op == "register":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "register needs an 'endpoint'"}
            try:
                return self.register(
                    str(ep), capacity=int(req.get("capacity", 1) or 1), meta=req.get("meta")
                )
            except ValueError as e:
                return {"ok": False, "error": str(e)}
        if op == "heartbeat":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "heartbeat needs an 'endpoint'"}
            cap = req.get("capacity")
            thr = req.get("throughput")
            try:
                return self.heartbeat(
                    str(ep),
                    capacity=int(cap) if cap is not None else None,
                    throughput=dict(thr) if isinstance(thr, dict) else None,
                )
            except ValueError as e:
                return {"ok": False, "error": str(e)}
        if op == "deregister":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "deregister needs an 'endpoint'"}
            return self.deregister(str(ep))
        if op == "fleet":
            return {"ok": True, "op": "fleet", "workers": self.members()}
        return {"ok": False, "error": f"unknown op {op!r}"}


class ReplicatedRegistry(MembershipRegistry):
    """One replica of a peered registry plane: same wire protocol, no SPOF.

    N replicas each serve the full worker protocol; workers fan heartbeats
    to all of them, and replicas exchange tables with push-pull anti-entropy
    (the ``sync`` op), so a restarted replica converges from ANY live peer
    within one round instead of waiting out the re-admission beat wave.

    Merge semantics — last-beat-wins per worker.  Records travel as
    ``(endpoint, age_s, beats, capacity, throughput, meta)`` where ``age_s``
    is seconds since the SENDER last heard the worker: relative ages, so
    replica clocks never need agreement and wire latency only makes a
    record look slightly staler (it can delay an adoption, never corrupt
    one).  The receiver adopts a record iff it is strictly fresher than its
    own, and never adopts one already past the dead bound (no resurrecting
    pruned workers).  After one push-pull round with no interleaving beats,
    two replicas hold identical tables and answer ``fleet`` byte-identically.

    Warm-up (``warmup=True``, the restart case): a replica that just came
    back has an empty-or-stale table, and answering ``fleet`` from it would
    tell a watcher the fleet vanished — so until it completes a sync
    exchange with a *ready* peer, or a full suspect window passes (by which
    every live worker has beaten it), ``fleet`` answers an error that
    consumers treat exactly like an unreachable replica: the merged quorum
    view comes from the others.  A brand-new plane (``warmup=False``) skips
    this — at cold boot there are no tracked sinks a partial view could
    flap dead, and ``wait_members`` gates on the expected worker count.
    """

    def __init__(
        self,
        peers: Sequence[str] = (),
        sync_interval_s: float | None = None,
        warmup: bool = True,
        **kwargs: Any,
    ):
        super().__init__(**kwargs)
        self.peers = [str(p) for p in peers]
        self.sync_interval_s = (
            float(sync_interval_s) if sync_interval_s else self.heartbeat_interval_s
        )
        if self.sync_interval_s <= 0:
            raise ValueError(f"sync interval must be > 0, got {self.sync_interval_s}")
        self._started = self._now()
        self._peer_ready = not warmup
        # Observability: completed peer exchanges / unreachable-peer rounds.
        self.syncs = 0
        self.sync_errors = 0
        self._sync_stop = threading.Event()
        self._sync_thread: threading.Thread | None = None

    @property
    def ready(self) -> bool:
        """Whether this replica's ``fleet`` answer is authoritative yet."""
        if not self.peers or self._peer_ready:
            return True
        if (self._now() - self._started) >= self.suspect_beats * self.heartbeat_interval_s:
            # A full suspect window has passed: every worker still alive has
            # beaten us by now, so the table is as complete as it gets.
            self._peer_ready = True
        return self._peer_ready

    # -- anti-entropy --------------------------------------------------------
    def export_records(self) -> list[dict[str, Any]]:
        """The worker table as merge items (ages relative to OUR clock)."""
        now = self._now()
        with self._lock:
            return [
                {
                    "endpoint": r.endpoint,
                    "age_s": max(0.0, now - r.last_seen),
                    "beats": r.beats,
                    "capacity": r.capacity,
                    "meta": dict(r.meta),
                    "registered_unix": r.registered_unix,
                    "throughput": dict(r.throughput) if r.throughput else None,
                }
                for ep in sorted(self._workers)
                for r in (self._workers[ep],)
            ]

    def merge_records(self, records: Sequence[dict[str, Any]]) -> int:
        """Last-beat-wins merge of a peer's export; returns adoptions."""
        now = self._now()
        dead_after = self.dead_beats * self.heartbeat_interval_s
        adopted = 0
        for rec in records or ():
            ep = str(rec.get("endpoint") or "")
            try:
                parse_endpoint(ep)
                age = max(0.0, float(rec.get("age_s", 0.0)))
                beats = int(rec.get("beats", 0) or 0)
                capacity = max(1, int(rec.get("capacity", 1) or 1))
            except (TypeError, ValueError):
                continue  # junk merge item: skip it, keep the round going
            if age > dead_after:
                continue  # the sender itself would prune this; never resurrect
            seen = now - age
            thr = rec.get("throughput")
            with self._lock:
                cur = self._workers.get(ep)
                if cur is not None and cur.last_seen >= seen:
                    continue  # our own evidence is as fresh or fresher
                self._workers[ep] = WorkerRecord(
                    endpoint=ep,
                    capacity=capacity,
                    meta=dict(rec.get("meta") or {}),
                    registered_unix=float(rec.get("registered_unix", 0.0) or 0.0),
                    last_seen=seen,
                    beats=beats,
                    throughput=dict(thr) if isinstance(thr, dict) else None,
                )
            adopted += 1
        return adopted

    def sync_once(self) -> int:
        """One push-pull round against every peer (best effort); returns the
        number of records adopted.  An unreachable peer costs nothing but
        the dial — the next round retries it."""
        from repro.core.remote import RemoteExecutionError, get_transport

        merged = 0
        for peer in list(self.peers):
            try:
                resp = get_transport(peer).request(
                    {"op": "sync", "workers": self.export_records(), "ready": self.ready},
                    timeout=max(2.0, 2.0 * self.heartbeat_interval_s),
                    connect_retries=1,
                )
            except RemoteExecutionError:
                self.sync_errors += 1
                continue
            if not resp.get("ok"):
                self.sync_errors += 1
                continue
            merged += self.merge_records(resp.get("workers") or [])
            if resp.get("ready"):
                self._peer_ready = True
            self.syncs += 1
        return merged

    def start_sync(self) -> threading.Thread | None:
        """Run anti-entropy rounds in the background until :meth:`stop_sync`.

        The first round fires immediately (a restarted replica converges
        before its first full interval elapses); later rounds are jittered
        so replicas de-phase instead of sync-storming each other."""
        if not self.peers or self._sync_thread is not None:
            return self._sync_thread

        def loop() -> None:
            while not self._sync_stop.is_set():
                try:
                    self.sync_once()
                except Exception:  # noqa: BLE001 - the plane must outlive one bad round
                    self.sync_errors += 1
                self._sync_stop.wait(
                    self.sync_interval_s + random.uniform(0.0, 0.25 * self.sync_interval_s)
                )

        self._sync_stop.clear()
        self._sync_thread = threading.Thread(target=loop, daemon=True, name="registry-sync")
        self._sync_thread.start()
        return self._sync_thread

    def stop_sync(self) -> None:
        self._sync_stop.set()
        if self._sync_thread is not None:
            self._sync_thread.join(timeout=2.0)
            self._sync_thread = None

    # -- wire dispatch -------------------------------------------------------
    def handle(self, req: dict[str, Any]) -> dict[str, Any]:
        op = req.get("op")
        if op == "sync":
            adopted = self.merge_records(req.get("workers") or [])
            if req.get("ready"):
                self._peer_ready = True
            self.syncs += 1
            return {
                "ok": True,
                "op": "sync",
                "adopted": adopted,
                "ready": self.ready,
                "workers": self.export_records(),
            }
        if op == "fleet" and not self.ready:
            return {
                "ok": False,
                "error": "registry replica warming up (restarted; no peer sync "
                "yet and the suspect window has not passed) — ask another replica",
            }
        return super().handle(req)


class MembershipServer(socketserver.ThreadingTCPServer):
    """Standalone registry endpoint speaking the worker wire protocol."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        registry: MembershipRegistry | None = None,
    ):
        self._conns: set[Any] = set()
        self._conns_lock = threading.Lock()
        super().__init__((host, port), JsonLineHandler)
        self.registry = registry if registry is not None else MembershipRegistry()

    @property
    def endpoint(self) -> str:
        from repro.core.remote import routable_host

        host, port = self.server_address[:2]
        return f"{routable_host(str(host))}:{port}"

    def dispatch(self, req: dict[str, Any]) -> dict[str, Any]:
        if req.get("op") == "ping":
            import os

            return {
                "ok": True,
                "op": "ping",
                "pid": os.getpid(),
                "service": "membership",
                "capacity": 1,
                "workers": len(self.registry),
                "peers": len(getattr(self.registry, "peers", ()) or ()),
                "ready": bool(getattr(self.registry, "ready", True)),
            }
        return self.registry.handle(req)

    def serve_in_thread(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        start_sync = getattr(self.registry, "start_sync", None)
        if start_sync is not None:
            start_sync()
        return t

    # Track accepted connections so server_close can sever them: clients
    # multiplex long-lived connections, and a "dead" registry that keeps
    # answering on established sockets after its listener closed would make
    # kill/partition faults (and real restarts) unobservable to them.
    def get_request(self):  # type: ignore[override]
        request, addr = super().get_request()
        with self._conns_lock:
            self._conns.add(request)
        return request, addr

    def shutdown_request(self, request) -> None:  # type: ignore[override]
        with self._conns_lock:
            self._conns.discard(request)
        super().shutdown_request(request)

    def server_close(self) -> None:  # type: ignore[override]
        stop_sync = getattr(self.registry, "stop_sync", None)
        if stop_sync is not None:
            stop_sync()
        super().server_close()
        with self._conns_lock:
            conns = list(self._conns)
            self._conns.clear()
        for sock in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass  # already gone


# -- CLI ---------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.runtime.membership", description="dpBento fleet membership registry"
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("serve", help="run the registration/heartbeat registry")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    s.add_argument(
        "--heartbeat-interval", type=float, default=HEARTBEAT_INTERVAL_S, metavar="SECONDS",
        help="expected worker beat period (suspect after 3 missed beats)",
    )
    s.add_argument(
        "--peers", default=None, metavar="HOST:PORT[,HOST:PORT...]",
        help="sibling registry replicas to anti-entropy-sync with; the "
        "replica warms up (answers 'fleet' with an error) until a peer "
        "exchange lands or a full suspect window passes",
    )
    s.add_argument(
        "--sync-interval", type=float, default=None, metavar="SECONDS",
        help="anti-entropy period between replicas (default: the heartbeat "
        "interval)",
    )
    s.add_argument(
        "--replicas", type=int, default=1, metavar="N",
        help="serve N mutually-peered replicas from THIS process on "
        "ephemeral ports (loopback quickstart); announces one comma-joined "
        "replica list usable as --register/--registry verbatim",
    )
    f = sub.add_parser("fleet", help="print the merged fleet view of registry replica(s)")
    f.add_argument("registry", metavar="HOST:PORT[,HOST:PORT...]")
    args = p.parse_args(argv)

    if args.cmd == "serve":
        if args.replicas < 1:
            p.error(f"--replicas must be >= 1, got {args.replicas}")
        if args.replicas > 1:
            if args.port:
                p.error("--replicas N binds ephemeral ports; drop --port")
            if args.peers:
                p.error("--replicas N wires its own peer lists; drop --peers")
            # Bind every replica first (the ephemeral ports become the stable
            # replica identities), then wire peers and start serving.  A
            # fresh plane skips warm-up: there is nothing to have missed.
            servers = [
                MembershipServer(
                    args.host, 0,
                    registry=ReplicatedRegistry(
                        heartbeat_interval_s=args.heartbeat_interval,
                        sync_interval_s=args.sync_interval,
                        warmup=False,
                    ),
                )
                for _ in range(args.replicas)
            ]
            endpoints = [srv.endpoint for srv in servers]
            for i, srv in enumerate(servers):
                srv.registry.peers = [ep for j, ep in enumerate(endpoints) if j != i]
            for srv in servers:
                srv.serve_in_thread()
            print("listening on " + ",".join(endpoints), flush=True)
            try:
                while True:
                    time.sleep(3600)
            except KeyboardInterrupt:
                pass
            finally:
                for srv in servers:
                    srv.shutdown()
                    srv.server_close()
            return 0
        if args.peers:
            registry: MembershipRegistry = ReplicatedRegistry(
                peers=parse_fleet(args.peers),
                sync_interval_s=args.sync_interval,
                heartbeat_interval_s=args.heartbeat_interval,
            )
        else:
            registry = MembershipRegistry(heartbeat_interval_s=args.heartbeat_interval)
        server = MembershipServer(args.host, args.port, registry=registry)
        print(f"listening on {server.endpoint}", flush=True)
        start_sync = getattr(registry, "start_sync", None)
        if start_sync is not None:
            start_sync()
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.cmd == "fleet":
        from repro.core.remote import fleet_view

        replicas = parse_fleet(args.registry)
        members, answered = fleet_view(replicas)
        if not answered:
            print(f"no registry replica answered among {','.join(replicas)}", file=sys.stderr)
            return 1
        if len(replicas) > 1:
            print(f"# merged view from {len(answered)}/{len(replicas)} replicas")
        for m in members:
            print(
                f"{m['endpoint']}  capacity={m['capacity']}  status={m['status']}  "
                f"age={m['age_s']:.1f}s  beats={m['beats']}"
            )
        return 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = [
    "DEAD_BEATS",
    "MembershipRegistry",
    "MembershipServer",
    "ReplicatedRegistry",
    "SUSPECT_BEATS",
    "WorkerRecord",
]
