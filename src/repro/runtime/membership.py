"""Fleet membership: worker registration, heartbeats, bounded failure detection.

Before this service a ``--remote`` fleet was a hand-typed endpoint list and a
dead worker was only discovered when a request's socket timeout expired.
Here the fleet is *elastic*: workers announce themselves to a registry
(``register``), prove liveness every :data:`~repro.core.remote.
HEARTBEAT_INTERVAL_S` seconds (``heartbeat``), and are classified with a
bounded failure detector —

  ``alive``    last beat within ``suspect_beats x interval`` (default 3
               missed beats, i.e. seconds, not the 600 s request timeout);
  ``suspect``  beats stopped; schedulers must stop sending NEW work and
               re-dispatch the worker's in-flight units elsewhere;
  ``dead``     silent past ``dead_beats x interval``; pruned from the table.

The wire protocol is the same newline-JSON request/response the worker
transport speaks (:mod:`repro.core.remote` defines the ``register``/
``heartbeat`` op pair and the client helpers), so a registry is one more
``host:port`` and `wait_ready`/`ping` work against it unchanged.  Run one
standalone::

    python -m repro.runtime.membership serve --host 0.0.0.0 --port 7170

and point workers (``--register HOST:7170``) and sweep runners
(``--registry HOST:7170``) at it.  :class:`repro.runtime.elastic.
FleetWatcher` turns the registry's view into live scheduler sink set
changes mid-sweep.
"""
from __future__ import annotations

import argparse
import socketserver
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.remote import (
    HEARTBEAT_INTERVAL_S,
    JsonLineHandler,
    parse_endpoint,
)

#: Missed beats before a worker is suspected (failure-detection bound).
SUSPECT_BEATS = 3
#: Missed beats before a suspect worker is declared dead and pruned.
DEAD_BEATS = 10


@dataclass
class WorkerRecord:
    """One registered worker as the registry sees it."""

    endpoint: str
    capacity: int = 1
    meta: dict[str, Any] = field(default_factory=dict)
    registered_unix: float = 0.0
    last_seen: float = 0.0  # monotonic, registry clock
    beats: int = 0
    # Ping-equivalent measured-throughput payload, refreshed on every beat:
    # discovery (FleetWatcher, --registry startup, @auto weights) reads it
    # from the fleet view instead of pinging each member.
    throughput: dict[str, Any] | None = None


class MembershipRegistry:
    """Thread-safe worker table with heartbeat-based failure detection.

    Pure state machine — servers feed it ``register``/``heartbeat``/
    ``deregister``/``fleet`` requests through :meth:`handle`; tests drive it
    with an injected clock.  A heartbeat from an unknown endpoint
    re-registers it (a restarted registry repopulates from the next beat
    wave instead of losing the fleet).
    """

    def __init__(
        self,
        heartbeat_interval_s: float = HEARTBEAT_INTERVAL_S,
        suspect_beats: int = SUSPECT_BEATS,
        dead_beats: int = DEAD_BEATS,
        now: Callable[[], float] = time.monotonic,
    ):
        if heartbeat_interval_s <= 0:
            raise ValueError(f"heartbeat interval must be > 0, got {heartbeat_interval_s}")
        if not 0 < suspect_beats < dead_beats:
            raise ValueError(
                f"need 0 < suspect_beats < dead_beats, got {suspect_beats}/{dead_beats}"
            )
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.suspect_beats = int(suspect_beats)
        self.dead_beats = int(dead_beats)
        self._now = now
        self._lock = threading.Lock()
        self._workers: dict[str, WorkerRecord] = {}

    # -- events --------------------------------------------------------------
    def register(
        self, endpoint: str, capacity: int = 1, meta: dict[str, Any] | None = None
    ) -> dict[str, Any]:
        parse_endpoint(endpoint)  # reject junk before it enters the table
        with self._lock:
            self._workers[endpoint] = WorkerRecord(
                endpoint=endpoint,
                capacity=max(1, int(capacity)),
                meta=dict(meta or {}),
                registered_unix=time.time(),
                last_seen=self._now(),
                beats=0,
            )
        return {
            "ok": True,
            "op": "register",
            "heartbeat_interval_s": self.heartbeat_interval_s,
            "suspect_beats": self.suspect_beats,
        }

    def heartbeat(
        self,
        endpoint: str,
        capacity: int | None = None,
        throughput: dict[str, Any] | None = None,
    ) -> dict[str, Any]:
        with self._lock:
            rec = self._workers.get(endpoint)
            known = rec is not None
        if rec is None:
            # Unknown endpoint (registry restarted, or beat raced ahead of
            # register): the beat carries enough to (re-)admit the worker.
            self.register(endpoint, capacity=capacity or 1)
            with self._lock:
                rec = self._workers[endpoint]
        with self._lock:
            rec.last_seen = self._now()
            rec.beats += 1
            if capacity is not None:
                rec.capacity = max(1, int(capacity))
            if throughput is not None:
                rec.throughput = dict(throughput)
        return {"ok": True, "op": "heartbeat", "known": known}

    def deregister(self, endpoint: str) -> dict[str, Any]:
        with self._lock:
            known = self._workers.pop(endpoint, None) is not None
        return {"ok": True, "op": "deregister", "known": known}

    # -- failure detection ---------------------------------------------------
    def status_of(self, rec: WorkerRecord, now: float | None = None) -> str:
        age = (self._now() if now is None else now) - rec.last_seen
        if age <= self.suspect_beats * self.heartbeat_interval_s:
            return "alive"
        if age <= self.dead_beats * self.heartbeat_interval_s:
            return "suspect"
        return "dead"

    def members(self) -> list[dict[str, Any]]:
        """Current fleet view, dead workers pruned; sorted for determinism."""
        now = self._now()
        out: list[dict[str, Any]] = []
        with self._lock:
            dead = [ep for ep, r in self._workers.items() if self.status_of(r, now) == "dead"]
            for ep in dead:
                del self._workers[ep]
            for ep in sorted(self._workers):
                r = self._workers[ep]
                out.append(
                    {
                        "endpoint": r.endpoint,
                        "capacity": r.capacity,
                        "status": self.status_of(r, now),
                        "age_s": now - r.last_seen,
                        "beats": r.beats,
                        "meta": dict(r.meta),
                        "throughput": dict(r.throughput) if r.throughput else None,
                    }
                )
        return out

    def alive(self) -> list[str]:
        return [m["endpoint"] for m in self.members() if m["status"] == "alive"]

    def __len__(self) -> int:
        with self._lock:
            return len(self._workers)

    # -- wire dispatch -------------------------------------------------------
    def handle(self, req: dict[str, Any]) -> dict[str, Any]:
        """Serve one registry op (shared by any JSON-line server front end)."""
        op = req.get("op")
        if op == "register":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "register needs an 'endpoint'"}
            try:
                return self.register(
                    str(ep), capacity=int(req.get("capacity", 1) or 1), meta=req.get("meta")
                )
            except ValueError as e:
                return {"ok": False, "error": str(e)}
        if op == "heartbeat":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "heartbeat needs an 'endpoint'"}
            cap = req.get("capacity")
            thr = req.get("throughput")
            try:
                return self.heartbeat(
                    str(ep),
                    capacity=int(cap) if cap is not None else None,
                    throughput=dict(thr) if isinstance(thr, dict) else None,
                )
            except ValueError as e:
                return {"ok": False, "error": str(e)}
        if op == "deregister":
            ep = req.get("endpoint")
            if not ep:
                return {"ok": False, "error": "deregister needs an 'endpoint'"}
            return self.deregister(str(ep))
        if op == "fleet":
            return {"ok": True, "op": "fleet", "workers": self.members()}
        return {"ok": False, "error": f"unknown op {op!r}"}


class MembershipServer(socketserver.ThreadingTCPServer):
    """Standalone registry endpoint speaking the worker wire protocol."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        registry: MembershipRegistry | None = None,
    ):
        super().__init__((host, port), JsonLineHandler)
        self.registry = registry if registry is not None else MembershipRegistry()

    @property
    def endpoint(self) -> str:
        from repro.core.remote import routable_host

        host, port = self.server_address[:2]
        return f"{routable_host(str(host))}:{port}"

    def dispatch(self, req: dict[str, Any]) -> dict[str, Any]:
        if req.get("op") == "ping":
            import os

            return {
                "ok": True,
                "op": "ping",
                "pid": os.getpid(),
                "service": "membership",
                "capacity": 1,
                "workers": len(self.registry),
            }
        return self.registry.handle(req)

    def serve_in_thread(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t


# -- CLI ---------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.runtime.membership", description="dpBento fleet membership registry"
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("serve", help="run the registration/heartbeat registry")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    s.add_argument(
        "--heartbeat-interval", type=float, default=HEARTBEAT_INTERVAL_S, metavar="SECONDS",
        help="expected worker beat period (suspect after 3 missed beats)",
    )
    f = sub.add_parser("fleet", help="print a registry's current fleet view")
    f.add_argument("registry", metavar="HOST:PORT")
    args = p.parse_args(argv)

    if args.cmd == "serve":
        server = MembershipServer(
            args.host, args.port,
            registry=MembershipRegistry(heartbeat_interval_s=args.heartbeat_interval),
        )
        print(f"listening on {server.endpoint}", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.cmd == "fleet":
        from repro.core.remote import fleet_members

        for m in fleet_members(args.registry):
            print(
                f"{m['endpoint']}  capacity={m['capacity']}  status={m['status']}  "
                f"age={m['age_s']:.1f}s  beats={m['beats']}"
            )
        return 0
    return 2


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = [
    "DEAD_BEATS",
    "MembershipRegistry",
    "MembershipServer",
    "SUSPECT_BEATS",
    "WorkerRecord",
]
