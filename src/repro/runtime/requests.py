"""Shared request/completion surface for the serving loops.

Both serving front ends — token decode (`serve_loop.SlotServer`) and query
serving (`serve_query.QueryServer`) — speak the same submit/complete
vocabulary: a `Request` enters through a queue, a `Completion` leaves with
its result.  `RequestQueue` is the admission-control half: a bounded FIFO
deque that sheds on overflow and accounts for every offered request, so
open-loop load generators can report rejection rates honestly.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Iterator

import jax


@dataclasses.dataclass
class Request:
    """A token-decode request (see serve_loop.SlotServer)."""

    uid: int
    prompt: jax.Array  # [S] int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Completion:
    """A finished token-decode request."""

    uid: int
    tokens: list[int]
    prompt_len: int


@dataclasses.dataclass
class QueryRequest:
    """One fused-query invocation: a query shape plus its run-time constants.

    ``arrival_s`` is the *scheduled* (open-loop) arrival time, so latency
    includes queueing delay — the coordinated-omission-correct measure.
    """

    uid: int
    query: str  # plan name: "q1" | "q6" | "q12"
    params: dict[str, Any]  # constants for queries.ServingPlan.program
    arrival_s: float = 0.0


@dataclasses.dataclass
class QueryCompletion:
    """A finished query request with its result and latency breakdown."""

    uid: int
    query: str
    result: dict[str, Any]
    latency_s: float  # arrival -> finish (includes queueing)
    service_s: float  # kernel execution only
    batch_size: int = 1  # how many requests shared the scan


class RequestQueue:
    """Bounded FIFO admission queue with load-shedding accounting.

    ``submit`` returns False (and counts a shed) when the queue is full;
    callers never block.  ``depth=None`` means unbounded.  The counters
    satisfy ``offered == admitted + shed`` at all times.

    Thread-safe: every queue/counter mutation happens under one internal
    lock, so an async front end can ``submit`` from transport threads
    while the serving tick drains with ``take_matching`` — the admission
    decision (full check + append + counter bump) is a single atomic step,
    never a check-then-act race.  ``pred`` is called WITH the lock held;
    keep it a pure, fast predicate.
    """

    def __init__(self, depth: int | None = None):
        if depth is not None and depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.depth = depth
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self.offered = 0
        self.admitted = 0
        self.shed = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def __iter__(self) -> Iterator:
        # Iterate a snapshot: callers must never observe (or pin) the live
        # deque while submitters mutate it.
        with self._lock:
            return iter(list(self._q))

    def submit(self, req) -> bool:
        with self._lock:
            self.offered += 1
            if self.depth is not None and len(self._q) >= self.depth:
                self.shed += 1
                return False
            self._q.append(req)
            self.admitted += 1
            return True

    def popleft(self):
        with self._lock:
            return self._q.popleft()

    def peek(self):
        with self._lock:
            return self._q[0] if self._q else None

    def take_matching(self, pred: Callable[[Any], bool], limit: int) -> list:
        """Dequeue up to ``limit`` requests satisfying ``pred``, preserving
        FIFO order among both the taken and the remaining requests.

        This is the scan-sharing coalescer: the query server takes every
        pending request of one query shape in one call and fuses them into
        a single kernel pass.  The whole scan is one atomic step: requests
        submitted concurrently either miss this scan entirely or are seen
        exactly once — never lost, never duplicated.
        """
        taken: list = []
        rest: collections.deque = collections.deque()
        with self._lock:
            while self._q:
                req = self._q.popleft()
                if len(taken) < limit and pred(req):
                    taken.append(req)
                else:
                    rest.append(req)
            self._q = rest
        return taken
