"""Batched serving with slot-based continuous batching.

A fixed pool of B decode slots (static shapes — TPU-friendly). Each slot
holds one request's KV state at its own write position: the decode step
takes a per-slot `lengths` vector, writes each slot's new K/V at its own
index (vmapped dynamic_update_slice -> scatter), and masks attention by
per-slot kv_len. One compiled decode graph serves heterogeneous request
lengths; finished slots (EOS / budget / max_len) are refilled from the
queue via single-request prefill spliced into the slot's cache row.

Caveat vs production: prefill is per-request (batch=1) rather than chunked
across slots; noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.runtime.requests import Completion, Request, RequestQueue

__all__ = ["Completion", "Request", "SlotServer"]


class SlotServer:
    """n_slots concurrent decode streams over one shared compiled step."""

    def __init__(self, model: Model, n_slots: int, max_len: int, eos_id: int = -1):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.params: Any = None
        self.cache: Any = None
        self.specs = model.cache_specs()
        # host-side slot table
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_done: list[list[int]] = [[] for _ in range(n_slots)]
        self.slot_budget = [0] * n_slots
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.queue = RequestQueue()  # unbounded: decode serving never sheds
        self.completed: list[Completion] = []
        self.decode_calls = 0

        def _decode(params, cache, tokens, lengths):
            logits, cache = self.model.decode(params, {"tokens": tokens}, cache, lengths)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy
            return next_tok, cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))

    # -- host scheduler --------------------------------------------------------
    def load(self, params) -> None:
        self.params = params
        self.cache = self.model.init_cache(self.n_slots, self.max_len)

    def submit(self, req: Request) -> None:
        self.queue.submit(req)

    def _fill_slot(self, slot: int, req: Request) -> None:
        """Prefill one request into `slot` (single-request batch), splice in."""
        prompt = req.prompt[None, :]  # [1, S]
        cache1 = self.model.init_cache(1, self.max_len)
        logits, cache1 = self.model.prefill(self.params, {"inputs": prompt}, cache1)

        def splice(c, c1, axes):
            ax = list(axes).index("batch")
            row = jnp.take(c1, 0, axis=ax).astype(c.dtype)
            return jax.lax.dynamic_update_index_in_dim(c, row, slot, ax)

        # specs leaves are axes-tuples; flatten both trees in lockstep
        is_axes = lambda v: isinstance(v, tuple) and all(
            a is None or isinstance(a, str) for a in v
        )
        leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        leaves1 = jax.tree_util.tree_leaves(cache1)
        spec_leaves = jax.tree_util.tree_leaves(self.specs, is_leaf=is_axes)
        assert len(leaves) == len(spec_leaves) == len(leaves1)
        self.cache = jax.tree_util.tree_unflatten(
            treedef, [splice(c, c1, s) for c, c1, s in zip(leaves, leaves1, spec_leaves)]
        )
        first = int(jnp.argmax(logits[0]))
        self.slot_req[slot] = req
        self.slot_done[slot] = [first]
        self.slot_budget[slot] = req.max_new_tokens - 1
        self.lengths = self.lengths.at[slot].set(req.prompt.shape[0])

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            self.completed.append(
                Completion(req.uid, self.slot_done[slot], int(req.prompt.shape[0]))
            )
        self.slot_req[slot] = None
        self.slot_done[slot] = []
        self.slot_budget[slot] = 0

    def step(self) -> int:
        """One scheduler tick: refill free slots, decode once. Returns #active."""
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                self._fill_slot(slot, self.queue.popleft())
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        last = jnp.array(
            [[self.slot_done[s][-1] if self.slot_req[s] else 0] for s in range(self.n_slots)],
            jnp.int32,
        )
        next_tok, self.cache = self._decode(self.params, self.cache, last, self.lengths)
        self.decode_calls += 1
        self.lengths = self.lengths + jnp.array(
            [1 if self.slot_req[s] else 0 for s in range(self.n_slots)], jnp.int32
        )
        for s in active:
            tok = int(next_tok[s])
            self.slot_done[s].append(tok)
            self.slot_budget[s] -= 1
            if tok == self.eos_id or self.slot_budget[s] <= 0 or int(self.lengths[s]) >= self.max_len - 1:
                self._retire(s)
        return len(active)

    def run(self, max_ticks: int = 10_000) -> list[Completion]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed
