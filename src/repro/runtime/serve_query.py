"""Query-serving front end: open-loop arrivals, admission control, and
scan-sharing micro-batches over the fused query kernels.

This generalizes the `SlotServer` host-scheduler pattern (serve_loop.py)
from token decode to query requests.  The scheduler tick is the same shape
— drain the admission queue, execute one batched device step, retire
completions — but the batching axis differs: where decode slots batch
*positions* of independent sequences, the query server batches *programs*
of one query shape.  N pending requests with different predicate constants
coalesce into one SMEM-program batch (`kernels.ops.group_filter_agg_multi`)
over a single pass through the column data; per-request results come back
de-multiplexed, bit-equal to serial execution (tests/test_serving.py).

Latency is measured from each request's *scheduled* open-loop arrival time
— queueing delay included — so an overloaded server shows up as tail
latency and shed requests, never as a silently throttled workload.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import random
import sys
import time
from typing import Any, Callable

from repro.core.timing import block
from repro.engine import queries as queries_mod
from repro.runtime.loadgen import sample_params
from repro.runtime.requests import QueryCompletion, QueryRequest, RequestQueue

_SATURATION_REQUESTS = 48


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class ServeReport:
    """Outcome of one serving run: completions plus admission accounting."""

    completed: list[QueryCompletion]
    offered: int
    admitted: int
    shed: int
    duration_s: float

    @property
    def latencies_s(self) -> list[float]:
        return [c.latency_s for c in self.completed]

    @property
    def qps(self) -> float:
        return len(self.completed) / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def offered_qps(self) -> float:
        return self.offered / self.duration_s if self.duration_s > 0 else 0.0


class QueryServer:
    """Long-lived serving loop over a set of compiled query plans.

    ``max_batch`` bounds the scan-sharing width; 1 serves strictly one
    request per kernel pass (the serial baseline).  Batch sizes > 1 are
    padded up to the next power of two (padding slots repeat the first
    request's constants and are discarded at demux) so the number of
    compiled executables stays logarithmic in ``max_batch``.
    """

    def __init__(
        self,
        plans: dict[str, queries_mod.ServingPlan],
        *,
        queue_depth: int | None = None,
        max_batch: int = 8,
        use_pallas: bool = True,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.plans = plans
        self.queue = RequestQueue(queue_depth)
        self.max_batch = max_batch
        self.use_pallas = use_pallas
        self.completed: list[QueryCompletion] = []
        self.kernel_calls = 0

    # -- host scheduler ----------------------------------------------------
    def submit(self, req: QueryRequest) -> bool:
        """Admit or shed one request (bounded queue, never blocks)."""
        if req.query not in self.plans:
            raise KeyError(f"no serving plan for query {req.query!r}")
        return self.queue.submit(req)

    def warmup(self, queries: list[str] | None = None) -> None:
        """Compile every (query, padded-batch-size) executable up front so
        serving latencies never include compile time (the task lifecycle's
        ``prepare`` phase)."""
        for name in queries or list(self.plans):
            plan = self.plans[name]
            params = sample_params(name, random.Random(0))
            size = 1
            while size <= self.max_batch:
                if size == 1:
                    block(queries_mod.fused_query_serial(plan, params, use_pallas=self.use_pallas))
                else:
                    block(
                        queries_mod.fused_query_batch(
                            plan, [params] * size, use_pallas=self.use_pallas
                        )
                    )
                size *= 2

    def _execute(self, batch: list[QueryRequest]) -> list[dict[str, Any]]:
        """One kernel pass for ``batch`` (padded to a power of two)."""
        plan = self.plans[batch[0].query]
        self.kernel_calls += 1
        if len(batch) == 1:
            result = queries_mod.fused_query_serial(
                plan, batch[0].params, use_pallas=self.use_pallas
            )
            block(result)
            return [result]
        padded = [r.params for r in batch]
        padded += [batch[0].params] * (_pow2_at_least(len(batch)) - len(batch))
        results = queries_mod.fused_query_batch(plan, padded, use_pallas=self.use_pallas)
        block(results)
        return results[: len(batch)]

    def step(self, now_fn: Callable[[], float] = time.perf_counter) -> list[QueryCompletion]:
        """One scheduler tick: coalesce the head-of-line query shape, run
        one fused pass, retire completions.  Returns the new completions.

        ``now_fn`` supplies the clock the trace's ``arrival_s`` offsets are
        on, so latency = finish - scheduled arrival (queueing included).
        """
        head = self.queue.peek()
        if head is None:
            return []
        batch = self.queue.take_matching(lambda r: r.query == head.query, self.max_batch)
        t0 = now_fn()
        results = self._execute(batch)
        t1 = now_fn()
        out = []
        for req, result in zip(batch, results):
            c = QueryCompletion(
                uid=req.uid,
                query=req.query,
                result=result,
                latency_s=t1 - min(req.arrival_s, t0),
                service_s=t1 - t0,
                batch_size=len(batch),
            )
            self.completed.append(c)
            out.append(c)
        return out


def run_open_loop(server: QueryServer, trace: list[QueryRequest]) -> ServeReport:
    """Drive ``server`` with an open-loop trace in real time.

    Requests are submitted when their scheduled arrival time passes,
    regardless of server progress; the server ticks whenever work is
    pending and sleeps to the next arrival otherwise.
    """
    base = len(server.completed)
    off0, adm0, shed0 = server.queue.offered, server.queue.admitted, server.queue.shed
    t_start = time.perf_counter()
    now = lambda: time.perf_counter() - t_start  # noqa: E731
    i, n = 0, len(trace)
    while i < n or len(server.queue):
        t = now()
        while i < n and trace[i].arrival_s <= t:
            server.submit(trace[i])
            i += 1
        if len(server.queue):
            server.step(now)
        elif i < n:
            time.sleep(min(max(trace[i].arrival_s - now(), 0.0), 0.05))
    end = now()
    duration = max(end, trace[-1].arrival_s if trace else 0.0)
    return ServeReport(
        completed=server.completed[base:],
        offered=server.queue.offered - off0,
        admitted=server.queue.admitted - adm0,
        shed=server.queue.shed - shed0,
        duration_s=duration,
    )


def measure_saturation(
    plans: dict[str, queries_mod.ServingPlan],
    queries: list[str],
    *,
    max_batch: int = 8,
    use_pallas: bool = True,
    n_requests: int = _SATURATION_REQUESTS,
    seed: int = 0,
) -> float:
    """Closed-loop saturation throughput (QPS) of this plan set.

    Keeps the server's queue full and measures completed/elapsed — the
    ceiling an open-loop rate can be compared against ("below saturation"
    means shed-free service is expected).
    """
    server = QueryServer(plans, queue_depth=None, max_batch=max_batch, use_pallas=use_pallas)
    server.warmup(queries)
    rng = random.Random(seed)
    reqs = [
        QueryRequest(
            uid=i, query=queries[i % len(queries)],
            params=sample_params(queries[i % len(queries)], rng), arrival_s=0.0,
        )
        for i in range(n_requests)
    ]
    t0 = time.perf_counter()
    for r in reqs:
        server.submit(r)
    while len(server.completed) < n_requests:
        server.step()
    elapsed = time.perf_counter() - t0
    return n_requests / elapsed if elapsed > 0 else 0.0


# ---------------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    """Serve an open-loop trace through the sweep executor and report
    latency percentiles per (query, platform).

    The serving knobs and the sweep surface both come from
    :mod:`repro.core.config` — this CLI shares every execution flag
    (--platforms/--workers/--cache/...) with the runner and the benchmark
    orchestrator.
    """
    from repro.core import config as config_mod
    from repro.core.box import Box

    p = argparse.ArgumentParser(
        prog="repro.runtime.serve_query",
        description="Open-loop query serving benchmark",
    )
    config_mod.add_serving_args(p)
    config_mod.add_sweep_args(p, iters=1, warmup=0, platforms=["cpu-host"])
    p.add_argument("--format", choices=("csv", "md", "json"), default="csv")
    p.add_argument("--out", default=None, help="write report here instead of stdout")
    args = p.parse_args(argv)

    serve_cfg = config_mod.ServeConfig.from_args(args)
    sweep_cfg = config_mod.SweepConfig.from_args(args)
    shard = config_mod.validate_sweep(sweep_cfg, p.error)
    executor = config_mod.make_executor(sweep_cfg)

    box = Box.from_dict(
        {
            "name": "serving",
            "platforms": sweep_cfg.platforms or ["cpu-host"],
            "tasks": [
                {
                    "task": "serving",
                    "params": {
                        "query": serve_cfg.queries,
                        "rate": serve_cfg.arrival_rate,
                        "arrival": serve_cfg.arrival,
                        "batching": serve_cfg.batching,
                        "scale": serve_cfg.scale,
                        "duration": serve_cfg.duration_s,
                        "queue_depth": serve_cfg.queue_depth or 0,
                        "seed": serve_cfg.seed,
                    },
                    "metrics": [
                        "p50_latency_us",
                        "p99_latency_us",
                        "qps",
                        "saturation_qps",
                        "shed_requests",
                    ],
                }
            ],
        }
    )
    res = executor.run_box(box, shard=shard)
    from repro.core import report as report_mod

    if args.format == "md":
        text = report_mod.to_markdown(res.rows)
    elif args.format == "json":
        text = json.dumps({"box": res.box, "rows": res.rows}, indent=1, default=str) + "\n"
    else:
        text = report_mod.to_csv(res.rows)
    if args.out:
        from pathlib import Path

        Path(args.out).write_text(text)
    else:
        sys.stdout.write(text)
    for err in res.errors:
        print(f"ERROR {err['task']} {err['params']}: {err['error']}", file=sys.stderr)
    return 1 if res.errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
