"""Fault-tolerant distributed training loop.

Production behaviours, all exercised by tests on a host mesh:
  * checkpoint/restart — atomic sharded checkpoints every `ckpt_every`
    steps (async writer overlaps with compute); on (re)start the loop
    restores the latest committed step, so any crash loses at most
    ckpt_every steps;
  * failure injection — `failure_at` raises SimulatedFailure inside the
    step loop; `run_with_restarts` shows the restart path end-to-end;
  * straggler mitigation — per-step wall time is tracked against a rolling
    median; steps slower than `straggler_factor` x median increment a
    counter and invoke `on_straggler` (on a real pod: re-shard away from the
    slow host / alert; here: hook + log);
  * grad accumulation — microbatch loop under jax.lax.scan when
    `accum_steps > 1`, so the global batch never materializes at once.

The step function is pjit'd with donated params/opt-state and explicit
shardings from launch.mesh rules.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.models.model import Model
from repro.optim import make_optimizer, make_schedule


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests and chaos drills)."""


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep: int = 3
    lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "warmup_cosine"
    accum_steps: int = 1
    log_every: int = 10
    failure_at: int | None = None  # inject SimulatedFailure at this step
    straggler_factor: float = 3.0
    straggler_window: int = 20


def make_train_step(
    model: Model, opt, schedule, accum_steps: int = 1, param_hook: Callable | None = None
) -> Callable:
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    `param_hook` (optional) transforms params inside the differentiated
    region — used for explicit ZeRO-3 weight gathering (sharding
    constraints whose transpose reduce-scatters the grads)."""

    def loss_fn(params, batch):
        if param_hook is not None:
            params = param_hook(params)
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            # microbatch scan: batch leaves are [accum, micro, ...]
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc_g = jax.tree_util.tree_map(lambda a, b: a + b, acc_g, g)
                return (acc_g, acc_l + l), None

            zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}
        lr = schedule(step)
        params, opt_state, om = opt.update(grads, opt_state, params, lr)
        out = {"loss": loss, "lr": lr, **metrics, **om}
        return params, opt_state, out

    return train_step


class StragglerMonitor:
    def __init__(self, factor: float, window: int, on_straggler: Callable | None = None):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.count = 0
        self.on_straggler = on_straggler

    def observe(self, dt: float, step: int) -> bool:
        is_straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window :])
            if dt > self.factor * med:
                self.count += 1
                is_straggler = True
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list[float]
    restarts: int = 0
    stragglers: int = 0
    restored_from: int | None = None


def train(
    model: Model,
    data,
    cfg: TrainConfig,
    *,
    mesh=None,
    in_shardings: Any = None,
    donate: bool = True,
    on_straggler: Callable | None = None,
) -> TrainResult:
    """Run the loop once (restores from ckpt_dir if checkpoints exist)."""
    opt = make_optimizer(model.cfg.optimizer)
    schedule = make_schedule(cfg.schedule, peak_lr=cfg.lr, warmup_steps=cfg.warmup_steps,
                             total_steps=cfg.steps)
    step_fn = make_train_step(model, opt, schedule, cfg.accum_steps)
    if mesh is not None:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    # ---- init or restore -------------------------------------------------
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start_step = 0
    restored_from = None
    if cfg.ckpt_dir and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
        like = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
        tree, start_step = ckpt_lib.restore(cfg.ckpt_dir, like=like)
        params, opt_state = tree["params"], tree["opt"]
        restored_from = start_step

    writer = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep) if cfg.ckpt_dir else None
    monitor = StragglerMonitor(cfg.straggler_factor, cfg.straggler_window, on_straggler)
    losses: list[float] = []

    step = start_step
    try:
        while step < cfg.steps:
            if cfg.failure_at is not None and step == cfg.failure_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = data.batch_at(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch, step)
            loss = float(metrics["loss"])
            monitor.observe(time.perf_counter() - t0, step)
            losses.append(loss)
            step += 1
            if writer and step % cfg.ckpt_every == 0:
                writer.save(step, {"params": params, "opt": opt_state})
    finally:
        if writer:
            writer.wait()
    if writer and step % cfg.ckpt_every != 0:
        ckpt_lib.save(cfg.ckpt_dir, step, {"params": params, "opt": opt_state}, keep=cfg.keep)
    return TrainResult(step, losses, stragglers=monitor.count, restored_from=restored_from)


def run_with_restarts(model: Model, data, cfg: TrainConfig, max_restarts: int = 3) -> TrainResult:
    """Supervise `train` across SimulatedFailures — the single-binary analogue
    of a cluster controller restarting a failed job from its checkpoint."""
    assert cfg.ckpt_dir, "restart supervision requires a checkpoint dir"
    restarts = 0
    while True:
        try:
            run_cfg = cfg if restarts == 0 else dataclasses.replace(cfg, failure_at=None)
            res = train(model, data, run_cfg)
            res.restarts = restarts
            return res
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
