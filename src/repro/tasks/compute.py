"""Compute microbenchmark (paper §3.4.1, Figs. 4-5).

dtype x op arithmetic throughput on the VPU (elementwise) and MXU (matmul),
plus the paper's string operations mapped to fixed-width byte tensors
(uint8 [n, width]): cmp (lexicographic compare), cat (concatenate), xfrm
(byte-wise transform — the strxfrm analogue).

To "rule out the effect of cache and main memory" as the paper does, the
arithmetic kernel iterates K dependent ops over a register-resident value
inside jax.lax.fori_loop, so steady-state throughput is ALU-bound, not
load/store-bound: ops/s = n_elements * K / time.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_DTYPES = {
    "int8": jnp.int8,
    "int32": jnp.int32,
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
}

_VEC = 1 << 16  # elements in flight (vector lanes' worth)
_CHAIN = 256  # dependent ops per element per iteration


def _arith_fn(op: str, dtype):
    one = jnp.asarray(3, dtype) if jnp.issubdtype(dtype, jnp.integer) else jnp.asarray(1.0009, dtype)

    def body(_, x):
        if op == "add":
            return x + one
        if op == "sub":
            return x - one
        if op == "mul":
            return x * one
        if op == "div":
            if jnp.issubdtype(dtype, jnp.integer):
                return x // one
            return x / one
        raise ValueError(op)

    @jax.jit
    def run(x):
        return jax.lax.fori_loop(0, _CHAIN, body, x)

    return run


def _matmul_fn(dtype, n: int = 512):
    @jax.jit
    def run(a, b):
        return a @ b

    return run


@register
class ComputeTask(Task):
    name = "compute"
    param_space = {
        "data_type": list(_DTYPES),
        "operation": ["add", "sub", "mul", "div", "matmul"],
    }
    default_metrics = ("ops_per_s",)

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(0)
        ctx.scratch["f32"] = jax.random.uniform(key, (_VEC,), jnp.float32, 1.0, 2.0)

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        dtype = _DTYPES[params.get("data_type", "float32")]
        op = params.get("operation", "add")
        if op == "matmul":
            n = 512
            key = jax.random.PRNGKey(2)
            a = jax.random.uniform(key, (n, n), jnp.float32, 1.0, 2.0).astype(dtype)
            b = a.T
            fn = _matmul_fn(dtype, n)
            times = measure(fn, a, b, iters=ctx.iters, warmup=ctx.warmup)
            return Samples(times_s=times, ops_per_iter=2 * n**3)
        x = ctx.scratch["f32"].astype(dtype)
        fn = _arith_fn(op, dtype)
        times = measure(fn, x, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(times_s=times, ops_per_iter=_VEC * _CHAIN)


# ---------------------------------------------------------------------------
_STR_WIDTHS = {"str10": 10, "str64": 64, "str256": 256, "str1024": 1024}
_N_STRINGS = 1 << 14


@register
class StringTask(Task):
    name = "strings"
    param_space = {
        "width": list(_STR_WIDTHS),
        "operation": ["cmp", "cat", "xfrm"],
    }
    default_metrics = ("ops_per_s",)

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(1)
        for name, w in _STR_WIDTHS.items():
            k1, k2, key = jax.random.split(key, 3)
            ctx.scratch[name] = (
                jax.random.randint(k1, (_N_STRINGS, w), 32, 127, jnp.uint8),
                jax.random.randint(k2, (_N_STRINGS, w), 32, 127, jnp.uint8),
            )

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        w = params.get("width", "str64")
        op = params.get("operation", "cmp")
        a, b = ctx.scratch[w]

        if op == "cmp":
            @jax.jit
            def fn(a, b):
                # lexicographic: first differing byte decides
                diff = (a.astype(jnp.int16) - b.astype(jnp.int16))
                idx = jnp.argmax(diff != 0, axis=1)
                return jnp.take_along_axis(diff, idx[:, None], axis=1)[:, 0]
        elif op == "cat":
            @jax.jit
            def fn(a, b):
                return jnp.concatenate([a, b], axis=1)
        else:  # xfrm: byte-wise case-fold + weighting (strxfrm-like transform)
            @jax.jit
            def fn(a, b):
                lower = jnp.where((a >= 65) & (a <= 90), a + 32, a)
                return (lower.astype(jnp.uint16) * 31 + 7).astype(jnp.uint8)

        times = measure(fn, a, b, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(
            times_s=times,
            ops_per_iter=_N_STRINGS,
            bytes_per_iter=float(a.size + b.size),
        )
