"""Full-system task (paper §3.6, Fig. 15): the mini columnar engine runs
TPC-H-pattern queries end-to-end.

Execution modes mirror the paper exactly:
  cold — includes compilation (the paper's cold run pays disk I/O; ours
         pays XLA compile + first-touch staging, the TPU-pod equivalent);
  hot  — steady-state, executable and data resident.

Params: scale x query x mode x impl. `impl` picks the execution plan:
`unfused` is the plain jnp graph (one HBM pass per mask/derived-column/
aggregate), `fused` routes through the single-pass `group_filter_agg`
Pallas plan (engine.queries.FUSED_QUERIES). Metric: query latency
(avg/p99) and rows/s. A second workload axis runs the LM train/serve step
of any configured architecture as the "full system" (the paper's DBMS
stands in for whole-application offload; ours is the end-to-end model
step) — see param `app`.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import block, measure
from repro.engine import datagen, queries

_SCALES = {"0.001": 6_000, "0.01": 60_000, "0.1": 600_000}


@register
class DBMSTask(Task):
    name = "dbms"
    param_space = {
        "scale": list(_SCALES),
        "query": ["q1", "q6", "q12"],
        "mode": ["cold", "hot"],
        "impl": ["unfused", "fused"],
    }
    default_metrics = ("avg_latency_us", "p99_latency_us", "items_per_s")

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(3)
        for name, rows in _SCALES.items():
            ctx.scratch[f"li_{name}"] = datagen.lineitem(key, rows=rows)
            ctx.scratch[f"od_{name}"] = datagen.orders(key, rows=max(rows // 4, 256))

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        scale = params.get("scale", "0.01")
        qname = params.get("query", "q6")
        mode = params.get("mode", "hot")
        impl = params.get("impl", "unfused")
        li = ctx.scratch[f"li_{scale}"]
        od = ctx.scratch[f"od_{scale}"]
        table = queries.QUERIES if impl == "unfused" else queries.FUSED_QUERIES
        qfn = table[qname]

        def call(f):
            return f(li, od) if qname == "q12" else f(li)

        if mode == "cold":
            # fresh jit each iteration: compile + execute (the paper's cold run)
            times = []
            for _ in range(max(2, ctx.iters // 2)):
                f = jax.jit(qfn)
                t0 = time.perf_counter()
                block(call(f))
                times.append(time.perf_counter() - t0)
                f.clear_cache()
        else:
            f = jax.jit(qfn)
            # Tiny scales finish in microseconds: min_time_s keeps sampling
            # until the measurement is long enough to mean something.
            times = measure(
                lambda: call(f),
                iters=ctx.iters,
                warmup=ctx.warmup,
                min_time_s=ctx.min_time_s,
            )

        return Samples(times_s=times, items_per_iter=float(li.num_rows))


@register
class AppStepTask(Task):
    """LM train/serve step as the end-to-end application (reduced config)."""

    name = "app_step"
    param_space = {
        "arch": ["olmo-1b", "mamba2-2.7b", "kimi-k2-1t-a32b"],
        "kind": ["train", "decode"],
        "mode": ["cold", "hot"],
    }
    default_metrics = ("avg_latency_us", "items_per_s")

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        from repro.configs.base import ShapeCell, get_arch, tiny
        from repro.models.model import Model, batch_like, input_specs

        cfg = tiny(get_arch(params.get("arch", "olmo-1b")))
        kind = params.get("kind", "train")
        model = Model(cfg)
        pkey = jax.random.PRNGKey(0)
        mparams = model.init(pkey)
        if kind == "train":
            cell = ShapeCell("t", 64, 2, "train")
            batch = batch_like(input_specs(cfg, cell))
            fn = jax.jit(lambda p, b: model.loss(p, b)[0])
            args = (mparams, batch)
            items = 2 * 64
        else:
            cell = ShapeCell("d", 64, 2, "decode")
            cache = model.init_cache(2, 64)
            batch = batch_like(input_specs(cfg, cell))
            fn = jax.jit(lambda p, b, c: model.decode(p, b, c, jnp.int32(8))[0])
            args = (mparams, batch, cache)
            items = 2

        if params.get("mode", "hot") == "cold":
            t0 = time.perf_counter()
            block(fn(*args))
            times = [time.perf_counter() - t0]
        else:
            times = measure(fn, *args, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(times_s=times, items_per_iter=float(items))
