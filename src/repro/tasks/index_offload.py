"""Index offloading module task (paper §3.5.2, Fig. 14).

The paper range-partitions a B+ tree between host and DPU at a split ratio
and serves reads from both. Here: a sorted-array index (searchsorted = the
B+ tree's log-n descent, TPU-native) range-partitioned between a primary
partition and a coprocessor partition at `split_ratio`. Lookups route by
key range; both partitions execute their batch per tick, and because JAX
dispatch is async the two jitted lookups overlap — the coprocessor genuinely
augments throughput rather than being serialized.

Params mirror the paper: index scale x op x access pattern x split ratio x
lanes. Metric: completed lookups per second.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_SCALES = {"1M": 1 << 20, "16M": 1 << 24}
_BATCH = 1 << 14  # lookups per lane per tick


def _make_index(key, n: int):
    keys = jnp.sort(jax.random.randint(key, (n,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))
    values = jnp.arange(n, dtype=jnp.int32) * 7
    return keys, values


def _queries(key, n_keys: jax.Array, count: int, pattern: str):
    if pattern == "uniform":
        idx = jax.random.randint(key, (count,), 0, n_keys.shape[0], jnp.int32)
    else:  # zipf-ish skew: quadratic concentration on the low range
        u = jax.random.uniform(key, (count,))
        idx = (u * u * n_keys.shape[0]).astype(jnp.int32)
    return jnp.take(n_keys, idx)


@register
class IndexOffloadTask(Task):
    name = "index_offload"
    param_space = {
        "scale": list(_SCALES),
        "operation": ["read", "write"],
        "pattern": ["uniform", "skewed"],
        "split_ratio": [0.0, 0.1, 0.3],  # fraction served by the coprocessor
        "lanes": [1, 4],
    }
    default_metrics = ("ops_per_s",)

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(11)
        for name, n in _SCALES.items():
            ctx.scratch[name] = _make_index(jax.random.fold_in(key, n), n)

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        keys, values = ctx.scratch[params.get("scale", "1M")]
        n = keys.shape[0]
        ratio = float(params.get("split_ratio", 0.1))
        lanes = int(params.get("lanes", 1))
        pattern = params.get("pattern", "uniform")
        op = params.get("operation", "read")
        cut = int(n * (1.0 - ratio))  # [0, cut) primary, [cut, n) coprocessor

        pk, pv = keys[:cut], values[:cut]
        ck, cv = keys[cut:], values[cut:]
        qkey = jax.random.PRNGKey(13)
        queries = _queries(qkey, keys, lanes * _BATCH, pattern)
        boundary = keys[cut] if ratio > 0 else jnp.iinfo(jnp.int32).max
        q_primary = jnp.where(queries < boundary, queries, keys[0])
        q_co = jnp.where(queries >= boundary, queries, keys[n - 1])

        if op == "read":
            @jax.jit
            def lookup_p(q):
                pos = jnp.clip(jnp.searchsorted(pk, q), 0, cut - 1)
                return jnp.sum(jnp.take(pv, pos))

            @jax.jit
            def lookup_c(q):
                if ck.shape[0] == 0:
                    return jnp.int32(0)
                pos = jnp.clip(jnp.searchsorted(ck, q), 0, max(n - cut - 1, 0))
                return jnp.sum(jnp.take(cv, pos))
        else:  # write: update values at looked-up slots
            @jax.jit
            def lookup_p(q):
                pos = jnp.clip(jnp.searchsorted(pk, q), 0, cut - 1)
                return pv.at[pos].add(1)

            @jax.jit
            def lookup_c(q):
                if ck.shape[0] == 0:
                    return cv
                pos = jnp.clip(jnp.searchsorted(ck, q), 0, max(n - cut - 1, 0))
                return cv.at[pos].add(1)

        def fn():
            a = lookup_p(q_primary)  # dispatched async:
            b = lookup_c(q_co)  # the two partitions overlap
            return a, b

        times = measure(fn, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(
            times_s=times,
            ops_per_iter=float(lanes * _BATCH),
            extra={"split_ratio": ratio},
        )
