"""Memory microbenchmark (paper §3.4.2, Figs. 7-8).

HBM access throughput/bandwidth: object size x pattern x op x lanes.
  sequential read  — full-buffer reduction (streams at HBM bandwidth)
  random read      — gather of pointer-size (4 B) elements at random indices
  sequential write — full-buffer fill (iota + scale, no read traffic)
  random write     — scatter of elements to random indices
`lanes` maps the paper's #threads to parallel access streams (a batched
gather issues `lanes` independent streams per iteration).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_SIZES = {"16KB": 1 << 12, "4MB": 1 << 20, "1GB": 1 << 28}  # element counts (f32)
_ACCESSES = 1 << 16  # random accesses per lane per iteration


@register
class MemoryTask(Task):
    name = "memory"
    param_space = {
        "object_size": list(_SIZES),
        "pattern": ["sequential", "random"],
        "operation": ["read", "write"],
        "lanes": [1, 4, 16],
    }
    default_metrics = ("ops_per_s", "bandwidth_gb_s")

    def prepare(self, ctx: TaskContext) -> None:
        # allocate largest buffer once; smaller sizes are views
        ctx.scratch["buf"] = jnp.arange(_SIZES["1GB"], dtype=jnp.float32)

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        n = _SIZES[params.get("object_size", "4MB")]
        pattern = params.get("pattern", "sequential")
        op = params.get("operation", "read")
        lanes = int(params.get("lanes", 1))
        buf = jax.lax.slice(ctx.scratch["buf"], (0,), (n,))
        key = jax.random.PRNGKey(42)
        idx = jax.random.randint(key, (lanes, _ACCESSES), 0, n, jnp.int32)

        if pattern == "sequential" and op == "read":
            fn = jax.jit(lambda b: jnp.sum(b, dtype=jnp.float32))
            args = (buf,)
            ops = n
            byts = 4 * n
        elif pattern == "sequential" and op == "write":
            fn = jax.jit(lambda s: jnp.full((n,), s, jnp.float32))
            args = (jnp.float32(1.5),)
            ops = n
            byts = 4 * n
        elif pattern == "random" and op == "read":
            fn = jax.jit(lambda b, i: jnp.sum(jnp.take(b, i, axis=0), axis=1))
            args = (buf, idx)
            ops = lanes * _ACCESSES
            byts = 4 * ops
        else:  # random write
            vals = jnp.ones((lanes * _ACCESSES,), jnp.float32)
            flat = idx.reshape(-1)
            fn = jax.jit(lambda b, i, v: b.at[i].set(v, mode="drop"))
            args = (buf, flat, vals)
            ops = lanes * _ACCESSES
            byts = 4 * ops

        times = measure(fn, *args, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(times_s=times, ops_per_iter=float(ops), bytes_per_iter=float(byts))
