"""Network microbenchmark (paper §3.4.4, Figs. 11-12).

DPU TCP/RDMA maps to ICI/DCN collectives. Parameters: collective kind x
payload bytes x mesh axis. Two schedule families mirror the paper's
TCP-vs-RDMA contrast:
  xla      — jnp ops under jit; the XLA SPMD partitioner schedules the
             collective (the "kernel TCP stack": convenient, generic);
  shardmap — explicit jax.lax.p* inside shard_map (the "kernel-bypass"
             path: the schedule is exactly what you wrote).

On this CPU container jax.devices() is 1, so collectives degenerate to
copies — wall-times are only meaningful relatively; the REAL evaluation of
this task is the dry-run roofline's collective term (launch/roofline.py).
benchmarks/bench_network.py re-execs itself with forced host devices to get
a real multi-device mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_SIZES = {"32KB": 1 << 13, "1MB": 1 << 18, "32MB": 1 << 23, "256MB": 1 << 26}  # f32 counts


def _mesh_1d() -> Mesh:
    import numpy as np

    devs = jax.devices()
    return Mesh(np.array(devs).reshape(len(devs)), ("x",))


@register
class NetworkTask(Task):
    name = "network"
    param_space = {
        "collective": ["all_reduce", "all_gather", "reduce_scatter", "all_to_all", "ppermute"],
        "payload": list(_SIZES),
        "schedule": ["xla", "shardmap"],
    }
    default_metrics = ("bandwidth_gb_s", "avg_latency_us", "p99_latency_us")

    def prepare(self, ctx: TaskContext) -> None:
        ctx.scratch["mesh"] = _mesh_1d()

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        mesh = ctx.scratch["mesh"]
        n_dev = mesh.size
        n = _SIZES[params.get("payload", "1MB")]
        n = max(n, n_dev)  # at least one element per shard
        n -= n % n_dev
        kind = params.get("collective", "all_reduce")
        schedule = params.get("schedule", "xla")
        x = jnp.arange(n, dtype=jnp.float32)
        sharded = jax.device_put(x, NamedSharding(mesh, P("x")))

        if schedule == "xla":
            if kind in ("all_reduce", "reduce_scatter"):
                fn = jax.jit(lambda v: jnp.sum(v) * jnp.ones_like(v),
                             in_shardings=NamedSharding(mesh, P("x")),
                             out_shardings=NamedSharding(mesh, P("x") if kind == "reduce_scatter" else P()))
            elif kind == "all_gather":
                fn = jax.jit(lambda v: v + 1.0,
                             in_shardings=NamedSharding(mesh, P("x")),
                             out_shardings=NamedSharding(mesh, P()))
            else:  # all_to_all / ppermute approximated by a resharding transpose
                m2 = x.reshape(n_dev, n // n_dev)
                sharded = jax.device_put(m2, NamedSharding(mesh, P("x", None)))
                fn = jax.jit(lambda v: v.T,
                             in_shardings=NamedSharding(mesh, P("x", None)),
                             out_shardings=NamedSharding(mesh, P(None, "x")))
        else:  # shardmap: explicit collectives; outputs flattened, out_specs P("x")
            from jax.experimental.shard_map import shard_map

            def body(v):
                if kind == "all_reduce":
                    return jax.lax.psum(v, "x")
                if kind == "all_gather":
                    return jax.lax.all_gather(v, "x", tiled=True).reshape(-1)
                if kind == "reduce_scatter":
                    return jax.lax.psum_scatter(v, "x", tiled=True)
                if kind == "all_to_all":
                    vv = v.reshape(n_dev, -1)
                    out = jax.lax.all_to_all(vv, "x", split_axis=0, concat_axis=0, tiled=False)
                    return out.reshape(-1)
                # ppermute: ring shift
                perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
                return jax.lax.ppermute(v, "x", perm)

            fn = jax.jit(
                shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
            )

        times = measure(fn, sharded, iters=ctx.iters, warmup=ctx.warmup)
        nbytes = 4.0 * n
        wire = {
            "all_reduce": 2 * (n_dev - 1) / max(n_dev, 1) * nbytes,
            "all_gather": (n_dev - 1) / max(n_dev, 1) * nbytes,
            "reduce_scatter": (n_dev - 1) / max(n_dev, 1) * nbytes,
            "all_to_all": (n_dev - 1) / max(n_dev, 1) * nbytes,
            "ppermute": nbytes,
        }[kind]
        return Samples(
            times_s=times,
            bytes_per_iter=nbytes,
            ops_per_iter=1.0,
            extra={"wire_bytes": wire, "n_devices": float(n_dev)},
        )
