"""Accelerator plugin (paper §5.2, Fig. 6): Pallas kernels as the "ASIC".

The paper probes DPU compression/RegEx engines against CPU SIMD and
multithreading. The TPU analogue: a hand-tiled Pallas kernel (the hardened
unit) vs the XLA-compiled jnp implementation (the general-purpose path)
for three data-path hot-spots: attention, grouped expert matmul, fused
filter+aggregate. Like the paper's accelerators, the kernel has a fixed
launch overhead — small payloads favor the jnp path, large payloads the
kernel (the crossover is the Fig. 6 story).

Plugin-typical caveat: works where Pallas works (TPU, or interpret mode on
CPU); interpret-mode wall-clock is NOT kernel speed — relative numbers
across payload sizes still expose the overhead-vs-throughput shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure
from repro.kernels import ops as kops

_SIZES = {"small": 128, "medium": 512, "large": 2048}


@register
class PallasAccelTask(Task):
    name = "pallas_accel"
    param_space = {
        "workload": ["attention", "gmm", "filter_agg"],
        "size": list(_SIZES),
        "impl": ["kernel", "jnp"],
    }
    default_metrics = ("ops_per_s", "avg_latency_us")

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        wl = params.get("workload", "filter_agg")
        s = _SIZES[params.get("size", "medium")]
        use_pallas = params.get("impl", "kernel") == "kernel"
        key = jax.random.PRNGKey(0)

        if wl == "attention":
            b, h, hkv, dh = 1, 4, 2, 64
            q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
            k = jax.random.normal(key, (b, s, hkv, dh), jnp.float32)
            v = jax.random.normal(key, (b, s, hkv, dh), jnp.float32)
            fn = lambda: kops.flash_attention(q, k, v, causal=True, block_q=128,
                                              block_k=128, use_pallas=use_pallas)
            flops = 2.0 * b * h * s * s * dh  # qk + pv, causal halves twice
        elif wl == "gmm":
            e, c, d, f = 4, s, 256, 256
            lhs = jax.random.normal(key, (e, c, d), jnp.float32)
            rhs = jax.random.normal(key, (e, d, f), jnp.float32)
            fn = lambda: kops.gmm(lhs, rhs, block_c=128, block_f=128, block_d=128,
                                  use_pallas=use_pallas)
            flops = 2.0 * e * c * d * f
        else:  # filter_agg
            n = s * 1024
            cols = jax.random.uniform(key, (4, n), jnp.float32)
            fn = lambda: kops.filter_agg(cols, 0.2, 0.8, 0.1, 0.9, block_n=16384,
                                         use_pallas=use_pallas)
            flops = 6.0 * n  # 4 compares + mul + add

        times = measure(fn, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(times_s=times, ops_per_iter=flops)
