"""Quantization plugin — the DEFLATE compression analogue (paper Fig. 6a/6b).

Data systems compress to cut storage/wire bytes; on TPU the equivalent
data-path transform is int8 quantization (4x size cut for f32, 2x for
bf16). Tasks: quantize (compress), dequantize (decompress), roundtrip.
Like the paper's engines, throughput is measured across payload sizes to
expose fixed overhead vs asymptotic bandwidth; the "ratio" metric reports
the size reduction (the compression-ratio analogue).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_SIZES = {"64KB": 1 << 14, "1MB": 1 << 18, "16MB": 1 << 22, "256MB": 1 << 26}  # f32 counts


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block (1024) absmax int8 quantization."""
    blocks = x.reshape(-1, 1024)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


@register
class QuantizeTask(Task):
    name = "quantize"
    param_space = {
        "operation": ["quantize", "dequantize", "roundtrip"],
        "payload": list(_SIZES),
    }
    default_metrics = ("bandwidth_gb_s", "avg_latency_us")

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        n = _SIZES[params.get("payload", "1MB")]
        op = params.get("operation", "roundtrip")
        key = jax.random.PRNGKey(5)
        x = jax.random.normal(key, (n,), jnp.float32)

        if op == "quantize":
            fn = jax.jit(quantize)
            args = (x,)
        elif op == "dequantize":
            q, s = jax.jit(quantize)(x)
            fn = jax.jit(dequantize)
            args = (q, s)
        else:
            fn = jax.jit(lambda v: dequantize(*quantize(v)))
            args = (x,)

        times = measure(fn, *args, iters=ctx.iters, warmup=ctx.warmup)
        return Samples(
            times_s=times,
            bytes_per_iter=4.0 * n,
            ops_per_iter=float(n),
            extra={"ratio": 4.0 * n / (n + 4.0 * (n // 1024))},
        )
