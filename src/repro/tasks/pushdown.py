"""Predicate pushdown module task (paper §3.5.1, Fig. 13).

Disaggregated-storage scan mapped to the pod: table rows live sharded
across "storage owner" devices. Two plans for `SELECT ... WHERE pred`:

  baseline — fetch-then-filter: all rows move to the consumer (a full
             all-gather of every scanned column), predicate evaluated after
             the move. Bytes on the wire = full table.
  pushdown — filter at the data owners (shard_map local predicate +
             fixed-capacity compact), only qualifying rows move. Bytes on
             the wire ~ selectivity x table (+ capacity padding).
             `impl=kernel` swaps the nonzero+gather compaction for the
             fused `block_compact` Pallas kernel (one pass: per-block mask
             count + prefix-offset scatter); `impl=jnp` keeps the unfused
             plan. `impl` is ignored by the other plans.  Capacity is
             HBM-bounded, not VMEM-bounded: past the resident kernel's
             VMEM budget the wrapper streams compacted tiles to an HBM
             output with double-buffered DMA, so the kernel rows run at
             scale 1.0 / selectivity 0.5 (cap 4.5M rows) too.
  pushdown_kernel — fully fused filter+aggregate at the owners (the Q6
             filter_agg kernel): zero row movement, only the aggregate
             travels.

On >1 device both plans execute their real collectives; on one device the
data movement collapses but the compute asymmetry (and the dry-run's wire
bytes, which benchmarks/bench_pushdown.py reports) still distinguishes the
plans. Params mirror the paper: scale x selectivity x lanes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure
from repro.engine import datagen, ops

_SCALES = {"0.01": 60_000, "0.1": 600_000, "1.0": 6_000_000}


def _pred_bounds(selectivity: float) -> tuple[float, float]:
    """shipdate window whose width hits the requested selectivity."""
    lo = datagen.DATE_EPOCH_DAYS
    width = selectivity * datagen.DATE_RANGE_DAYS
    return float(lo), float(lo + width)


def kernel_scan_columns(table) -> jax.Array:
    """[4, N] column matrix for the fused filter_agg plan: shipdate and
    discount as the two filter columns, extendedprice x 1.0 as the value
    product.  The single source for the plan's column layout — the CI smoke
    and tests reuse it so they validate the exact plan the task measures."""
    n = table.num_rows
    return jnp.stack(
        [table["l_shipdate"], table["l_discount"],
         table["l_extendedprice"], jnp.ones((n,), jnp.float32)]
    )


@register
class PushdownTask(Task):
    name = "pushdown"
    param_space = {
        "scale": list(_SCALES),
        "selectivity": [0.01, 0.1, 0.5],
        "plan": ["baseline", "pushdown", "pushdown_kernel"],
        "impl": ["jnp", "kernel"],
    }
    default_metrics = ("items_per_s",)

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(7)
        for name, rows in _SCALES.items():
            ctx.scratch[name] = datagen.lineitem(key, rows=rows)

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        table = ctx.scratch[params.get("scale", "0.01")]
        sel = float(params.get("selectivity", 0.1))
        plan = params.get("plan", "pushdown")
        use_kernel = params.get("impl", "jnp") == "kernel"
        lo, hi = _pred_bounds(sel)
        n = table.num_rows
        cap = max(1024, int(1.5 * sel * n))
        cols = ("l_shipdate", "l_extendedprice", "l_discount", "l_quantity")
        scanned = table.select(*cols)

        if plan == "baseline":
            # fetch-then-filter: force a copy of every column (the wire move),
            # then evaluate the predicate on the consumer.
            @jax.jit
            def fn(t):
                moved = jax.tree_util.tree_map(lambda c: c + 0.0, t)  # materialized move
                mask = ops.pred_between(moved["l_shipdate"], lo, hi)
                return ops.masked_sum(moved["l_extendedprice"], mask), ops.masked_count(mask)

            times = measure(fn, scanned, iters=ctx.iters, warmup=ctx.warmup)
            moved_bytes = scanned.nbytes()
            moved_bytes_exact = moved_bytes  # every row moves, no padding
        elif plan == "pushdown":
            # filter at the owners, move only qualifying rows (capacity-bounded)
            @jax.jit
            def fn(t):
                mask = ops.pred_between(t["l_shipdate"], lo, hi)
                out, cnt = ops.compact(t, mask, cap, use_pallas=use_kernel)
                # compact already returns the true count; slots < cnt are the
                # qualifying rows (masking on value != 0 would silently drop
                # genuine zero-valued qualifying rows).
                valid = jnp.arange(cap) < cnt
                return ops.masked_sum(out["l_extendedprice"], valid), cnt

            times = measure(fn, scanned, iters=ctx.iters, warmup=ctx.warmup)
            # Provisioned wire traffic: the capacity-bounded buffer always
            # travels whole.  The exact column below charges only rows that
            # actually qualified, so Fig. 13 can show both.
            moved_bytes = cap * 16  # 4 cols x 4 B per provisioned slot
            qualifying = int(
                ops.masked_count(ops.pred_between(scanned["l_shipdate"], lo, hi))
            )
            moved_bytes_exact = min(qualifying, cap) * 16
        else:  # pushdown_kernel: fused Pallas filter+aggregate, zero row movement
            from repro.kernels import ops as kops

            colmat = kernel_scan_columns(table)

            def fn(c):
                return kops.filter_agg(c, lo, hi, -1.0, 1.0)

            times = measure(fn, colmat, iters=ctx.iters, warmup=ctx.warmup)
            moved_bytes = 8  # one (sum, count) pair
            moved_bytes_exact = moved_bytes

        return Samples(
            times_s=times,
            items_per_iter=float(n),
            bytes_per_iter=float(moved_bytes),
            extra={
                "selectivity": sel,
                "moved_bytes": float(moved_bytes),
                "moved_bytes_exact": float(moved_bytes_exact),
            },
        )
