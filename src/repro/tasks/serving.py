"""Query-serving task (ROADMAP open item 1): tail latency under open-loop
load, per platform.

One test = one (query, rate, arrival, batching) point: generate a seeded
open-loop trace, drive the long-lived QueryServer against it, and report
the per-request latency distribution (p50/p99 — queueing included),
delivered QPS, closed-loop saturation QPS, and admission-control sheds.

``times_s`` carries per-request latencies, so platform time dilation
(e.g. dpu-sim's 3.5x) applies to them through the normal
``transform_samples`` path; rate extras (qps/saturation_qps/offered_qps)
are divided by the platform's time_scale here, keeping latency x
throughput coherent on simulated platforms.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.engine import datagen, queries
from repro.runtime.loadgen import generate_trace
from repro.runtime.serve_query import QueryServer, measure_saturation, run_open_loop

_SCALES = {"0.001": 6_000, "0.01": 60_000, "0.1": 600_000}


@register
class ServingTask(Task):
    name = "serving"
    param_space = {
        "scale": list(_SCALES),
        "query": ["q1", "q6", "q12"],
        "rate": [50.0],  # offered load, requests/second
        "arrival": ["poisson", "fixed"],
        "batching": [True, False],  # scan sharing on/off
        "duration": [2.0],  # open-loop run length, seconds
        "queue_depth": [64],  # admission bound; 0 = unbounded
        "seed": [0],
    }
    default_metrics = ("p50_latency_us", "p99_latency_us", "qps")

    def prepare(self, ctx: TaskContext) -> None:
        key = jax.random.PRNGKey(3)
        for name, rows in _SCALES.items():
            li = datagen.lineitem(key, rows=rows)
            od = datagen.orders(key, rows=max(rows // 4, 256))
            ctx.scratch[f"plans_{name}"] = queries.make_serving_plans(li, od)

    def _time_scale(self, ctx: TaskContext) -> float:
        from repro.core.platform import get_platform

        try:
            return float(get_platform(ctx.platform.get("name", "default")).time_scale)
        except KeyError:
            return 1.0

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        scale = params.get("scale", "0.001")
        query = params.get("query", "q6")
        rate = float(params.get("rate", 50.0))
        arrival = params.get("arrival", "poisson")
        batching = bool(params.get("batching", True))
        duration = float(params.get("duration", 2.0))
        depth = int(params.get("queue_depth", 64)) or None
        seed = int(params.get("seed", 0))

        plans = ctx.scratch[f"plans_{scale}"]
        max_batch = 8 if batching else 1

        # Saturation is a property of (scale, query, batching), not of the
        # offered rate — measure once per such point and share across units.
        sat_key = f"sat_{scale}_{query}_{max_batch}"
        sat = ctx.scratch.get(sat_key)
        if sat is None:
            sat = measure_saturation(plans, [query], max_batch=max_batch, seed=seed)
            ctx.scratch[sat_key] = sat

        server = QueryServer(plans, queue_depth=depth, max_batch=max_batch)
        server.warmup([query])
        trace = generate_trace([query], rate, duration, arrival=arrival, seed=seed)
        report = run_open_loop(server, trace)

        # Rates dilate inversely with platform time_scale; times_s dilates
        # through transform_samples, so only the extras are adjusted here.
        ts = self._time_scale(ctx)
        return Samples(
            times_s=report.latencies_s,
            items_per_iter=1.0,  # one request per sample
            extra={
                "qps": report.qps / ts,
                "offered_qps": report.offered_qps / ts,
                "saturation_qps": sat / ts,
                "shed_requests": float(report.shed),
                "completed_requests": float(len(report.completed)),
                "kernel_calls": float(server.kernel_calls),
            },
        )
