"""Storage microbenchmark (paper §3.4.3, Figs. 9-10).

The TPU-pod analogue of DPU-local disks is the host<->device staging path
plus checkpoint I/O:
  h2d / d2h    — device_put / device_get of `access_size` buffers,
                 `depth` transfers in flight (JAX dispatch is async, so
                 depth>1 genuinely pipelines);
  ckpt_write / ckpt_read — sharded checkpoint save/restore roundtrip
                 (the data path fault tolerance actually exercises).
Metrics: bandwidth + latency percentiles, as in the paper's fio-style tool.
"""
from __future__ import annotations

import tempfile
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.core.metrics import Samples
from repro.core.registry import register
from repro.core.task import Task, TaskContext
from repro.core.timing import measure

_SIZES = {"8KB": 1 << 13, "256KB": 1 << 18, "4MB": 1 << 22, "64MB": 1 << 26}  # bytes


@register
class StorageTask(Task):
    name = "storage"
    param_space = {
        "io_type": ["h2d", "d2h", "ckpt_write", "ckpt_read"],
        "access_size": list(_SIZES),
        "depth": [1, 4, 16],
    }
    default_metrics = ("bandwidth_gb_s", "avg_latency_us", "p99_latency_us")

    def prepare(self, ctx: TaskContext) -> None:
        ctx.scratch["tmp"] = tempfile.mkdtemp(prefix="dpbento_storage_")

    def clean(self, ctx: TaskContext) -> None:
        import shutil

        tmp = ctx.scratch.get("tmp")
        if tmp:
            shutil.rmtree(tmp, ignore_errors=True)
        super().clean(ctx)

    def run(self, ctx: TaskContext, params: dict[str, Any]) -> Samples:
        nbytes = _SIZES[params.get("access_size", "4MB")]
        depth = int(params.get("depth", 1))
        io = params.get("io_type", "h2d")
        n = nbytes // 4

        if io == "h2d":
            host = [np.random.default_rng(i).random(n, np.float32) for i in range(depth)]

            def fn():
                return [jax.device_put(h) for h in host]

            times = measure(fn, iters=ctx.iters, warmup=ctx.warmup)
        elif io == "d2h":
            dev = [jnp.arange(n, dtype=jnp.float32) + i for i in range(depth)]

            def fn():
                return [np.asarray(jax.device_get(d)) for d in dev]

            times = measure(fn, iters=ctx.iters, warmup=ctx.warmup)
        elif io == "ckpt_write":
            tree = {f"b{i}": jnp.arange(n, dtype=jnp.float32) for i in range(depth)}
            d = Path(ctx.scratch["tmp"]) / f"w{nbytes}_{depth}"

            def fn():
                ckpt_lib.save(d, 0, tree, keep=1)

            times = measure(fn, iters=ctx.iters, warmup=1)
        else:  # ckpt_read
            tree = {f"b{i}": jnp.arange(n, dtype=jnp.float32) for i in range(depth)}
            d = Path(ctx.scratch["tmp"]) / f"r{nbytes}_{depth}"
            ckpt_lib.save(d, 0, tree, keep=1)
            like = jax.eval_shape(lambda: tree)

            def fn():
                return ckpt_lib.restore(d, like=like)

            times = measure(fn, iters=ctx.iters, warmup=1)

        total = float(nbytes * depth)
        return Samples(times_s=times, bytes_per_iter=total, ops_per_iter=depth)
