"""Hypothesis shim: property tests degrade gracefully without hypothesis.

When ``hypothesis`` is installed the real ``given``/``settings``/``st`` are
re-exported and behaviour is identical.  When it is NOT installed (the CPU
CI image and some sandboxes cannot pip-install it), this module provides a
miniature deterministic stand-in implementing exactly the strategy surface
our tests use — ``integers``, ``floats``, ``lists``, ``dictionaries``,
``sampled_from`` — so the four property-based test modules still *collect*
and their ``@given`` tests run against a seeded pseudo-random sample set
(first example biased to the minimal corner) instead of erroring at import.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A draw rule: minimal() for the shrink-corner, draw(rng) for the rest."""

        def __init__(self, draw, minimal):
            self._draw = draw
            self._minimal = minimal

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def minimal(self):
            return self._minimal()

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value), lambda: min_value
            )

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value), lambda: min_value
            )

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq), lambda: seq[0])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            def minimal():
                return [elements.minimal() for _ in range(min_size)]

            return _Strategy(draw, minimal)

        @staticmethod
        def dictionaries(
            keys: _Strategy, values: _Strategy, min_size: int = 0, max_size: int = 8
        ) -> _Strategy:
            def draw(rng):
                target = rng.randint(min_size, max_size)
                out = {}
                for _ in range(20 * max(target, 1)):  # keys may collide; retry
                    if len(out) >= target:
                        break
                    out[keys.draw(rng)] = values.draw(rng)
                while len(out) < min_size:  # keyspace may be tiny
                    out[keys.draw(rng)] = values.draw(rng)
                return out

            def minimal():
                out = {}
                rng = random.Random(0)
                while len(out) < min_size:
                    out[keys.draw(rng)] = values.draw(rng)
                return out

            return _Strategy(draw, minimal)

    st = _Strategies()

    def settings(**kwargs):
        """Record run options on the function; consumed by @given below."""

        def deco(fn):
            fn._compat_settings = kwargs
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            opts = getattr(fn, "_compat_settings", {})
            n_examples = min(int(opts.get("max_examples", _DEFAULT_EXAMPLES)), 50)

            # Like hypothesis: positional strategies bind the RIGHTMOST
            # unbound parameters; whatever is left over (pytest fixtures)
            # stays in the wrapper's visible signature.
            sig = inspect.signature(fn)
            unbound = [n for n in sig.parameters if n not in kw_strategies]
            pos_names = unbound[len(unbound) - len(arg_strategies):] if arg_strategies else []
            fixture_names = [n for n in unbound if n not in pos_names]
            strategies = dict(zip(pos_names, arg_strategies), **kw_strategies)

            @functools.wraps(fn)
            def wrapper(**fixture_kwargs):
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for i in range(max(n_examples, 1)):
                    if i == 0:
                        drawn = {k: s.minimal() for k, s in strategies.items()}
                    else:
                        drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**fixture_kwargs, **drawn)
                    except Exception:
                        print(
                            f"_hypothesis_compat falsifying example ({fn.__name__}): "
                            f"{drawn!r}"
                        )
                        raise

            # Hide the strategy-bound parameters from pytest's fixture
            # resolution; expose only genuine fixture parameters.
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[n] for n in fixture_names]
            )
            return wrapper

        return deco
