import jax
import pytest

# Tests run on the real device set (1 CPU device) — the dry-run alone forces
# 512 host devices, in its own process. Keep x64 off (TPU parity).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
