"""Cost-aware scheduling suite: CostModel, weighted partitions, LPT
dispatch, worker capacity, and cache eviction.

Contract pillars, mirroring the scheduling layer's claims:

  1. *Partition laws* — cost-balanced weighted partitions are a disjoint
     cover (duplicates included), keep max weight-normalized load within
     the slack bound under 100:1 skewed costs, and the weighted rendezvous
     hash keeps the movers-only-to-the-new-shard resize law (property
     tests via _hypothesis_compat).
  2. *Schedule-invariance* — LPT pool dispatch and a ``capacity=4`` worker
     produce report rows bit-identical to sequential / serialized
     execution (deterministic plugin tasks make equality exact).
  3. *Evidence plumbing* — every executor path records ``elapsed_s`` into
     the cache, CostModel consumes it tier by tier, and eviction bounds
     the cache without touching fresh entries.
"""
from __future__ import annotations

import json
import time

import pytest
from _hypothesis_compat import given, settings, st
from test_shard import _keys, make_plugin, plugin_box

from repro.core import (
    CostModel,
    ResultCache,
    ShardSpec,
    SweepExecutor,
    cost_partition,
    cost_shard_map,
    merge_shard_reports,
    partition,
    shard_of,
)
from repro.core import registry as reg
from repro.core import runner as runner_mod
from repro.core.box import Box
from repro.core.platform import get_platform
from repro.core.report import to_csv


# -- ShardSpec weights -------------------------------------------------------
def test_shard_spec_weight_parse():
    s = ShardSpec.parse("0/2@0.25")
    assert s.weights == (0.25, 0.75) and s.weight == 0.25
    # The complementary runner reconstructs the SAME vector from its own w.
    assert ShardSpec.parse("1/2@0.75").weights == (0.25, 0.75)
    v = ShardSpec.parse("2/3@0.5:0.25:0.25")
    assert v.weights == (0.5, 0.25, 0.25) and v.weight == 0.25
    # str round-trips through parse.
    assert ShardSpec.parse(str(s)) == s
    assert ShardSpec.parse("0/2") == ShardSpec(0, 2)  # unweighted unchanged
    for bad in ("0/2@0", "0/2@1.5", "0/3@0.2:0.8", "0/2@a", "0/2@-1:2", "0/2@"):
        with pytest.raises(ValueError):
            ShardSpec.parse(bad)
    with pytest.raises(ValueError):
        ShardSpec(0, 2, (1.0,))  # wrong vector length
    with pytest.raises(ValueError):
        ShardSpec(0, 2, (1.0, 0.0))  # non-positive weight


def test_weighted_shard_of_uniform_matches_legacy():
    keys = _keys(5, 80)
    for n in (2, 5):
        for k in keys:
            assert shard_of(k, n, (1.0,) * n) == shard_of(k, n)
            assert shard_of(k, n, (2.5,) * n) == shard_of(k, n)


# -- partition laws ----------------------------------------------------------
@settings(max_examples=20)
@given(st.integers(min_value=1, max_value=8), st.integers(min_value=0, max_value=10**6))
def test_cost_partition_is_disjoint_cover(n, seed):
    keys = _keys(seed, 50)
    weights = tuple(1.0 + (i % 3) for i in range(n))
    costs = {k: 1.0 + (int(k[:4], 16) % 100) for k in keys}
    parts = cost_partition(keys, n, weights, costs)
    assert len(parts) == n
    union = [k for part in parts for k in part]
    assert sorted(union) == sorted(keys)
    owner = cost_shard_map(keys, n, weights, costs)
    for i, part in enumerate(parts):
        assert all(owner[k] == i for k in part)


def test_cost_partition_keeps_duplicates_together():
    keys = _keys(9, 30)
    dup = keys + keys[:7]  # overlapping task specs emit duplicate grid keys
    parts = cost_partition(dup, 3, costs={k: 2.0 for k in keys})
    union = [k for part in parts for k in part]
    assert sorted(union) == sorted(dup)  # every occurrence covered once
    owner = cost_shard_map(dup, 3, costs={k: 2.0 for k in keys})
    for k in keys[:7]:  # both occurrences share one owner
        assert sum(k in part for part in parts) == 1


def test_cost_partition_balances_100_to_1_skew():
    """Acceptance: cost-balanced 4-way stays <= 1.5x mean where the
    count-balanced hash exceeds 3x (heavy keys chosen adversarially on one
    hash shard, as a slow-DPU fleet's cache would pin them)."""
    keys = _keys(3, 160)
    hash_parts = partition(keys, 4)
    heavy = set(hash_parts[0])
    assert len(heavy) >= 20  # sanity: the hash bucket is populated
    costs = {k: (100.0 if k in heavy else 1.0) for k in keys}
    total = sum(costs.values())
    mean = total / 4
    hash_loads = [sum(costs[k] for k in part) for part in hash_parts]
    assert max(hash_loads) > 3 * mean  # count-balanced overloads one shard
    parts = cost_partition(keys, 4, costs=costs)
    loads = [sum(costs[k] for k in part) for part in parts]
    assert max(loads) <= 1.5 * mean  # cost-balanced respects the slack bound
    assert sorted(k for p in parts for k in p) == sorted(keys)


@settings(max_examples=15)
@given(st.integers(min_value=2, max_value=10))
def test_weighted_resize_moves_only_to_new_shard(n):
    """Appending a shard to the weight vector: every mover goes TO it."""
    keys = _keys(11)
    w = tuple(1.0 + (i % 3) * 0.5 for i in range(n))
    moved = 0
    for k in keys:
        before = shard_of(k, n, w)
        after = shard_of(k, n + 1, w + (1.25,))
        if before != after:
            moved += 1
            assert after == n
    assert moved < len(keys)  # and most keys stay put


# -- CostModel tiers ---------------------------------------------------------
def test_cost_model_estimate_tiers(tmp_path):
    cache = ResultCache(tmp_path / "c.json")
    cache.put("k1", {"m": 1.0}, task="t", platform="cpu-host", elapsed_s=2.0)
    cache.put("k2", {"m": 1.0}, task="t", platform="cpu-host", elapsed_s=4.0)
    cache.put("k3", {"m": 1.0}, task="t", platform="dpu-sim", elapsed_s=30.0)
    cache.put("k4", {"m": 1.0}, task="t", platform="cpu-host")  # no elapsed
    m = CostModel(cache)
    assert m.measured_points == 3
    host, sim = get_platform("cpu-host"), get_platform("dpu-sim")
    assert m.explain("k1", task="t", platform=host) == (2.0, "measured")
    assert m.explain("new", task="t", platform=host) == (3.0, "task-platform-mean")
    cost, src = m.explain("new", task="t", platform=get_platform("default"))
    assert src == "task-mean" and cost == pytest.approx(12.0)  # (2+4+30)/3 x 1.0
    assert m.explain("new", task="other", platform=sim) == (3.5, "heuristic")
    assert m.explain("new", task="other", platform=host) == (1.0, "uniform")
    assert CostModel(None).explain(None) == (1.0, "uniform")


def test_platform_cost_scale():
    assert get_platform("cpu-host").cost_scale() == 1.0
    assert get_platform("dpu-sim").cost_scale() == 3.5  # time_scale heuristic
    from repro.core.platform import Platform

    assert Platform(name="bf2", flags={"cost_scale": 0.3}).cost_scale() == 0.3


def test_executor_records_elapsed(tmp_path):
    make_plugin(tmp_path, "elplug")
    reg.load_plugin_dir(tmp_path / "elplug")
    path = tmp_path / "cache.json"
    res = SweepExecutor(cache=ResultCache(path)).run_box(plugin_box("elplug"))
    assert not res.errors
    entries = ResultCache(path).snapshot()
    assert len(entries) == 6
    assert all(e.get("elapsed_s", 0) > 0 for e in entries.values())
    # ...and the process pool records the child-measured wall cost too.
    make_plugin(tmp_path, "elplug2")
    reg.load_plugin_dir(tmp_path / "elplug2")
    path2 = tmp_path / "cache2.json"
    res2 = SweepExecutor(cache=ResultCache(path2), pool="process", workers=2).run_box(
        plugin_box("elplug2")
    )
    assert not res2.errors
    assert all(e.get("elapsed_s", 0) > 0 for e in ResultCache(path2).snapshot().values())


# -- LPT dispatch ------------------------------------------------------------
def test_lpt_dispatch_rows_bit_identical(tmp_path):
    """Skewed cost evidence reorders pool submission; the CSV must not move."""
    make_plugin(tmp_path, "slowplug", factor=4.0)
    make_plugin(tmp_path, "fastplug", factor=1.0)
    reg.load_plugin_dir(tmp_path / "slowplug")
    reg.load_plugin_dir(tmp_path / "fastplug")
    box = Box.from_dict(
        {
            "name": "lpt_box",
            "tasks": [
                {"task": "fastplug", "params": {"a": [1, 2, 3], "b": ["x", "y"]}},
                {"task": "slowplug", "params": {"a": [1, 2, 3], "b": ["x", "y"]}},
            ],
        }
    )
    # Task-mean evidence: slowplug units estimate 100x fastplug units, so
    # LPT submits them first even though the grid declares them last.
    cache = ResultCache(tmp_path / "ev.json")
    cache.put("ev1", {"m": 1.0}, task="slowplug", platform="default", elapsed_s=10.0)
    cache.put("ev2", {"m": 1.0}, task="fastplug", platform="default", elapsed_s=0.1)
    seq = SweepExecutor(workers=1).run_box(box)
    lpt = SweepExecutor(workers=4, cache=cache).run_box(box)
    assert not seq.errors and not lpt.errors
    assert lpt.stats.cached == 0  # evidence keys are not unit keys
    assert lpt.rows == seq.rows
    assert to_csv(lpt.rows) == to_csv(seq.rows)  # byte-identical CSV


def test_dispatch_order_is_heaviest_first(tmp_path):
    make_plugin(tmp_path, "ordercost")
    reg.load_plugin_dir(tmp_path / "ordercost")
    cache = ResultCache(tmp_path / "c.json")
    ex = SweepExecutor(cache=cache)
    units = ex._expand_units(plugin_box("ordercost"), ex.platforms)
    for i, u in enumerate(units):
        cache.put(u.ckey, {"m": 1.0}, task=u.task_name, platform="default",
                  elapsed_s=float(i + 1))
    order = ex._dispatch_order(units)
    assert [u.index for u in order] == [u.index for u in units][::-1]
    # No evidence -> stable: grid order preserved.
    assert [u.index for u in SweepExecutor()._dispatch_order(units)] == [
        u.index for u in units
    ]


# -- weighted sharding through the executor ----------------------------------
def test_weighted_shard_union_matches_unsharded(tmp_path):
    make_plugin(tmp_path, "wplug")
    reg.load_plugin_dir(tmp_path / "wplug")
    box = plugin_box("wplug")
    path = tmp_path / "cache.json"
    full = SweepExecutor(cache=ResultCache(path)).run_box(box)  # seeds costs
    specs = [ShardSpec.parse("0/2@0.25"), ShardSpec.parse("1/2@0.75")]
    shards = [SweepExecutor(cache=ResultCache(path)).run_box(box, shard=s) for s in specs]
    assert all(not s.errors for s in shards)
    assert sum(s.stats.total for s in shards) == full.stats.total  # disjoint cover
    assert all(s.stats.cached == s.stats.total for s in shards)  # shared cache
    merged = merge_shard_reports([s.rows for s in shards], box=box)
    assert merged == full.rows  # bit-for-bit, canonical order


def test_weighted_shard_flag_without_weights(tmp_path):
    make_plugin(tmp_path, "wfplug")
    reg.load_plugin_dir(tmp_path / "wfplug")
    box = plugin_box("wfplug")
    path = tmp_path / "cache.json"
    full = SweepExecutor(cache=ResultCache(path)).run_box(box)
    shards = [
        SweepExecutor(cache=ResultCache(path), weighted_shard=True).run_box(
            box, shard=ShardSpec(i, 3)
        )
        for i in range(3)
    ]
    assert sum(s.stats.total for s in shards) == full.stats.total
    merged = merge_shard_reports([s.rows for s in shards], box=box)
    assert merged == full.rows


def test_weighted_partition_agrees_across_remote_settings(tmp_path):
    """Cost lookups key off skey (endpoint-free): a runner pointing its
    shard at a --remote worker must compute the SAME weighted partition as
    a local runner, or the grid loses coverage between them."""
    make_plugin(tmp_path, "rcplug")
    reg.load_plugin_dir(tmp_path / "rcplug")
    box = plugin_box("rcplug")
    path = tmp_path / "cache.json"
    SweepExecutor(cache=ResultCache(path)).run_box(box)  # local seed run
    spec = ShardSpec.parse("0/2@0.25")

    def kept_skeys(**kw):
        ex = SweepExecutor(cache=ResultCache(path), **kw)
        return {u.skey for u in ex._expand_units(box, ex.platforms, spec)}

    # No worker is contacted: expansion/partitioning is a local computation.
    assert kept_skeys() == kept_skeys(remote="10.0.0.2:7177")


def test_shard_plan_covers_box(tmp_path):
    make_plugin(tmp_path, "planplug")
    reg.load_plugin_dir(tmp_path / "planplug")
    box = plugin_box("planplug")
    ex = SweepExecutor()
    plan = ex.shard_plan(box, ShardSpec.parse("0/2@0.25"))
    assert len(plan) == 2
    assert sum(r["units"] for r in plan) == box.total_tests()
    assert sum(r["cost_share"] for r in plan) == pytest.approx(1.0)
    assert [r["weight"] for r in plan] == [0.25, 0.75]
    # Legacy (unweighted) plans preview the pure hash partition.
    legacy = ex.shard_plan(box, ShardSpec(0, 2))
    assert sum(r["units"] for r in legacy) == box.total_tests()


# -- worker capacity ---------------------------------------------------------
@pytest.fixture()
def capacity_worker():
    from repro.core.remote import WorkerServer

    server = WorkerServer(capacity=4)
    server.serve_in_thread()
    yield server
    server.shutdown()
    server.server_close()


def test_worker_capacity_rows_bit_identical(tmp_path, capacity_worker):
    """Acceptance: a --capacity 4 worker returns rows bit-identical to the
    serialized (capacity=1) worker, with disjoint tasks in flight at once."""
    from repro.core.remote import WorkerServer

    make_plugin(tmp_path, "capa")
    make_plugin(tmp_path, "capb", factor=2.0)
    reg.load_plugin_dir(tmp_path / "capa")
    reg.load_plugin_dir(tmp_path / "capb")
    box = Box.from_dict(
        {
            "name": "cap_box",
            "tasks": [
                {"task": "capa", "params": {"a": [1, 2, 3], "b": ["x", "y"]}},
                {"task": "capb", "params": {"a": [1, 2, 3], "b": ["x", "y"]}},
            ],
        }
    )
    serial = WorkerServer()  # capacity defaults to 1: the old behaviour
    serial.serve_in_thread()
    try:
        r1 = SweepExecutor(workers=4, remote=serial.endpoint).run_box(box)
    finally:
        serial.shutdown()
        serial.server_close()
    assert capacity_worker.capacity == 4
    r4 = SweepExecutor(workers=4, remote=capacity_worker.endpoint).run_box(box)
    assert not r1.errors and not r4.errors
    assert r4.rows == r1.rows
    assert to_csv(r4.rows) == to_csv(r1.rows)


def test_worker_ping_reports_capacity(capacity_worker):
    from repro.core.remote import get_transport

    resp = get_transport(capacity_worker.endpoint).request({"op": "ping"})
    assert resp["ok"] and resp["capacity"] == 4


def test_local_worker_capacity_flag(tmp_path):
    """--capacity rides the real `python -m repro.core.remote worker` CLI."""
    from repro.core.remote import LocalWorker, get_transport

    d = make_plugin(tmp_path, "capcli")
    reg.load_plugin_dir(d)
    box = plugin_box("capcli")
    local = SweepExecutor().run_box(box)
    with LocalWorker(plugin_dirs=[d], capacity=4) as w:
        assert get_transport(w.endpoint).request({"op": "ping"})["capacity"] == 4
        rem = SweepExecutor(workers=4, remote=w.endpoint).run_box(box)
    assert not rem.errors
    assert rem.rows == local.rows


# -- cache eviction + clear --------------------------------------------------
def test_cache_eviction_max_entries(tmp_path):
    path = tmp_path / "c.json"
    c = ResultCache(path, max_entries=3)
    for i in range(5):
        c.put(f"k{i}", {"m": float(i)})
    assert len(c) == 5  # eviction happens on flush, not on put
    c.flush()
    assert len(c) == 3 and c.evicted == 2
    assert len(ResultCache(path)) == 3  # the trimmed set is what persisted
    # An unbounded reader of the same file sees the same 3 entries.
    c2 = ResultCache(path, max_entries=3)
    c2.flush()  # nothing dirty, nothing to trim -> no-op
    assert len(c2) == 3 and c2.evicted == 0


def test_cache_eviction_max_age(tmp_path):
    path = tmp_path / "c.json"
    c = ResultCache(path, max_age_s=60.0)
    c.put("fresh", {"m": 1.0})
    c.put("stale", {"m": 2.0})
    c._entries["stale"]["saved_unix"] = time.time() - 3600  # age it out
    c.flush()
    assert len(c) == 1 and c.get("fresh") is not None and c.evicted == 1
    # Age eviction also trims entries that went stale since the last write.
    c._entries["fresh"]["saved_unix"] = time.time() - 3600
    c.flush()
    assert len(c) == 0


def test_cache_eviction_validates_args(tmp_path):
    with pytest.raises(ValueError):
        ResultCache(tmp_path / "c.json", max_entries=-1)
    with pytest.raises(ValueError):
        ResultCache(tmp_path / "c.json", max_age_s=-0.5)


def test_clear_does_not_create_cache_file(tmp_path):
    path = tmp_path / "never.json"
    c = ResultCache(path)
    c.clear()
    assert not path.exists()  # clearing nothing must not touch disk
    c.put("k", {"m": 1.0})
    c.clear()
    assert path.exists()  # there WAS something to erase -> file reflects it
    assert json.loads(path.read_text())["entries"] == {}
    c.clear()  # idempotent on an existing (empty) file
    assert json.loads(path.read_text())["entries"] == {}


# -- CLI ---------------------------------------------------------------------
def test_runner_cli_weighted_shard_merge_matches_full(tmp_path):
    d = make_plugin(tmp_path, "wcli")
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "wcli_box",
                "tasks": [{"task": "wcli", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
            }
        )
    )
    cache = tmp_path / "cache.json"
    common = [
        "--box", str(bf), "--plugin-dir", str(d), "--iters", "2", "--warmup", "0",
        "--cache", str(cache),
    ]
    full, s0, s1, merged = (
        tmp_path / n for n in ("full.csv", "s0.csv", "s1.csv", "merged.csv")
    )
    assert runner_mod.main([*common, "--out", str(full)]) == 0  # seeds costs
    assert runner_mod.main([*common, "--shard", "0/2@0.25", "--out", str(s0)]) == 0
    assert runner_mod.main([*common, "--shard", "1/2@0.75", "--out", str(s1)]) == 0
    assert runner_mod.main([*common, "--merge", str(s0), str(s1), "--out", str(merged)]) == 0
    assert merged.read_text() == full.read_text()


def test_runner_cli_shard_plan(tmp_path, capsys):
    d = make_plugin(tmp_path, "plancli")
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "plan_box",
                "tasks": [{"task": "plancli", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
            }
        )
    )
    out = tmp_path / "should_not_exist.csv"
    rc = runner_mod.main(
        [
            "--box", str(bf), "--plugin-dir", str(d),
            "--shard", "0/2@0.25", "--shard-plan", "--out", str(out),
        ]
    )
    assert rc == 0
    captured = capsys.readouterr().out
    assert "shard 0/2@0.25:0.75" in captured and "shard 1/2@0.25:0.75" in captured
    assert "units" in captured and "share" in captured
    assert not out.exists()  # dry run: nothing executed, nothing written
    # --shard-plan without --shard is a usage error.
    with pytest.raises(SystemExit):
        runner_mod.main(["--box", str(bf), "--shard-plan"])


def test_runner_cli_cache_eviction_flags(tmp_path):
    d = make_plugin(tmp_path, "evcli")
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "ev_box",
                "tasks": [{"task": "evcli", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
            }
        )
    )
    cache = tmp_path / "cache.json"
    rc = runner_mod.main(
        [
            "--box", str(bf), "--plugin-dir", str(d), "--iters", "1", "--warmup", "0",
            "--cache", str(cache), "--cache-max-entries", "2",
            "--out", str(tmp_path / "r.csv"),
        ]
    )
    assert rc == 0
    assert len(json.loads(cache.read_text())["entries"]) == 2  # trimmed on flush
