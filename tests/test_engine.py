"""Mini query engine: operator correctness vs numpy, query properties."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.engine import datagen, ops, queries
from repro.engine.table import Table, concat

KEY = jax.random.PRNGKey(21)


@pytest.fixture(scope="module")
def li():
    return datagen.lineitem(KEY, rows=20_000)


@pytest.fixture(scope="module")
def od():
    return datagen.orders(KEY, rows=5_000)  # matches lineitem(rows=20_000) FK range


def test_table_invariants(li):
    assert li.num_rows == 20_000
    with pytest.raises(ValueError, match="ragged"):
        Table({"a": jnp.zeros(3), "b": jnp.zeros(4)})
    t2 = li.select("l_quantity", "l_discount")
    assert t2.names == ["l_discount", "l_quantity"]
    taken = li.take(jnp.array([0, 5, 9]))
    assert taken.num_rows == 3
    cc = concat([t2, t2])
    assert cc.num_rows == 40_000


def test_filter_and_compact_vs_numpy(li):
    mask = ops.filter_mask(
        li,
        lambda t: t["l_quantity"] < 25.0,
        lambda t: ops.pred_between(t["l_discount"], 0.02, 0.08),
    )
    c = {k: np.asarray(v) for k, v in li.columns.items()}
    expect = (c["l_quantity"] < 25.0) & (c["l_discount"] >= 0.02) & (c["l_discount"] < 0.08)
    np.testing.assert_array_equal(np.asarray(mask), expect)

    out, cnt = ops.compact(li, mask, max_rows=int(expect.sum()) + 64)
    assert int(cnt) == int(expect.sum())
    got = np.sort(np.asarray(out["l_extendedprice"])[: int(cnt)])
    exp = np.sort(c["l_extendedprice"][expect])
    np.testing.assert_allclose(got, exp)


def test_group_aggregate_vs_numpy(li):
    keys = li["l_returnflag"]
    mask = li["l_quantity"] > 10
    agg = ops.group_aggregate(keys, {"qty": li["l_quantity"]}, mask, num_groups=3)
    k = np.asarray(keys)
    m = np.asarray(mask)
    q = np.asarray(li["l_quantity"])
    for g in range(3):
        sel = (k == g) & m
        np.testing.assert_allclose(float(agg["qty"][g]), q[sel].sum(), rtol=1e-5)
        assert float(agg["count"][g]) == sel.sum()


def test_fk_join_vs_numpy(li, od):
    joined = ops.fk_index_join(li, "l_orderkey", od, "o_orderkey", ("o_totalprice",))
    lk = np.asarray(li["l_orderkey"])
    tp = np.asarray(od["o_totalprice"])
    np.testing.assert_allclose(np.asarray(joined["o_totalprice"]), tp[lk], rtol=1e-6)


def test_sort_merge_join_matches_fk_join(li, od):
    j1 = ops.fk_index_join(li, "l_orderkey", od, "o_orderkey", ("o_totalprice",))
    j2, matched = ops.sort_merge_join(li, "l_orderkey", od, "o_orderkey", ("o_totalprice",))
    assert bool(jnp.all(matched))
    np.testing.assert_allclose(
        np.asarray(j1["o_totalprice"]), np.asarray(j2["o_totalprice"]), rtol=1e-6
    )


def test_q1_group_totals(li):
    res = jax.jit(queries.q1)(li)
    # counts over the 6 groups equal the number of rows passing the date filter
    c = np.asarray(li["l_shipdate"])
    cutoff = datagen.date(1998, 12, 1) - 90.0
    assert int(np.asarray(res["count"]).sum()) == int((c <= cutoff).sum())
    assert np.all(np.asarray(res["avg_disc"]) <= 0.11)


def test_q6_matches_numpy(li):
    res = jax.jit(queries.q6)(li)
    c = {k: np.asarray(v) for k, v in li.columns.items()}
    lo, hi = datagen.date(1994), datagen.date(1995)
    mask = (
        (c["l_shipdate"] >= lo) & (c["l_shipdate"] < hi)
        & (c["l_discount"] >= 0.049) & (c["l_discount"] < 0.071)
        & (c["l_quantity"] < 24)
    )
    expect = (c["l_extendedprice"][mask] * c["l_discount"][mask]).sum()
    np.testing.assert_allclose(float(res["revenue"]), expect, rtol=1e-4)
    assert int(res["rows"]) == int(mask.sum())


def test_q6_kernel_equals_engine(li):
    from repro.kernels import ops as kops

    res = jax.jit(queries.q6)(li)
    cols, bounds = queries.q6_columns(li)
    out = kops.filter_agg(cols, *bounds, block_n=8192)
    np.testing.assert_allclose(float(out[0]), float(res["revenue"]), rtol=1e-5)


def test_q12_runs_and_counts_bounded(li, od):
    res = jax.jit(queries.q12)(li, od)
    total = np.asarray(res["count"]).sum()
    high = np.asarray(res["high_line_count"]).sum()
    low = np.asarray(res["low_line_count"]).sum()
    assert high + low == pytest.approx(total)
    # only shipmodes MAIL(2) and SHIP(5) have nonzero counts
    cnt = np.asarray(res["count"])
    assert cnt[[0, 1, 3, 4, 6]].sum() == 0


# -- properties ---------------------------------------------------------------
@given(
    rows=st.integers(128, 2048),
    sel=st.floats(0.05, 0.95),
)
@settings(max_examples=10, deadline=None)
def test_compact_count_scales_with_selectivity(rows, sel):
    t = datagen.lineitem(jax.random.fold_in(KEY, rows), rows=rows)
    lo = datagen.DATE_EPOCH_DAYS
    hi = lo + sel * datagen.DATE_RANGE_DAYS
    mask = ops.pred_between(t["l_shipdate"], float(lo), float(hi))
    cnt = int(ops.masked_count(mask))
    assert 0 <= cnt <= rows
    # selectivity should land near `sel` (uniform dates) — loose bound
    assert abs(cnt / rows - sel) < 0.25


def test_datagen_deterministic():
    a = datagen.lineitem(jax.random.PRNGKey(5), rows=512)
    b = datagen.lineitem(jax.random.PRNGKey(5), rows=512)
    for n in a.names:
        np.testing.assert_array_equal(np.asarray(a[n]), np.asarray(b[n]))
