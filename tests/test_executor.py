"""Sweep execution subsystem: concurrent executor correctness, result
caching, platform backends. Pure-framework tests — no jax involved."""
from __future__ import annotations

import json
import threading
import time

import pytest

from repro.core import Box, ResultCache, Runner, Samples, SweepExecutor
from repro.core import registry as reg
from repro.core.cache import cache_key
from repro.core.platform import get_platform, known_platforms, resolve
from repro.core.report import speedup_table
from repro.core.task import Task


class _SweepTask(Task):
    """Deterministic task with observable lifecycle, safe under threads."""

    name = "sweep"
    param_space = {"a": [1, 2, 3, 4], "b": ["x", "y"]}
    default_metrics = ("avg_latency_us", "ops_per_s")

    def __init__(self):
        self.prepare_calls = 0
        self.run_calls = 0
        self._lock = threading.Lock()

    def prepare(self, ctx):
        time.sleep(0.01)  # widen the race window for the barrier test
        with self._lock:
            self.prepare_calls += 1
        ctx.scratch["ready"] = True

    def run(self, ctx, params):
        assert ctx.scratch.get("ready"), "run before prepare"
        with self._lock:
            self.run_calls += 1
        t = 1e-4 * params["a"] * (1 + (params["b"] == "y"))
        return Samples(times_s=[t, 2 * t], ops_per_iter=100.0)


@pytest.fixture()
def sweep_task():
    t = _SweepTask()
    reg._register_for_tests(t)
    return t


def _box(n_a=4):
    return Box.from_dict(
        {
            "name": "b",
            "tasks": [
                {"task": "sweep", "params": {"a": list(range(1, n_a + 1)), "b": ["x", "y"]}}
            ],
        }
    )


# -- concurrent correctness --------------------------------------------------
def test_parallel_rows_identical_to_sequential(sweep_task):
    seq = SweepExecutor(workers=1).run_box(_box())
    par = SweepExecutor(workers=4).run_box(_box())
    assert par.rows == seq.rows  # same order, same keys, same values
    assert not par.errors and par.stats.total == 8


def test_prepare_runs_once_under_contention(sweep_task):
    res = SweepExecutor(workers=8).run_box(_box())
    assert sweep_task.prepare_calls == 1
    assert sweep_task.run_calls == 8
    assert len(res.results) == 8


def test_prepare_failure_fails_all_waiters():
    class _BadPrep(Task):
        name = "badprep"
        param_space = {"n": [1, 2, 3, 4]}

        def prepare(self, ctx):
            raise RuntimeError("no disk")

        def run(self, ctx, params):
            return Samples(times_s=[1e-3])

    reg._register_for_tests(_BadPrep())
    box = Box.from_dict({"name": "b", "tasks": [{"task": "badprep", "params": {"n": [1, 2, 3, 4]}}]})
    res = SweepExecutor(workers=4).run_box(box)
    assert len(res.errors) == 4
    assert all("no disk" in e["error"] for e in res.errors)
    assert not res.results


def test_error_isolation_under_concurrency(sweep_task):
    class _Flaky(Task):
        name = "flaky"
        param_space = {"z": [0, 1, 2, 3]}

        def run(self, ctx, params):
            if params["z"] % 2:
                raise RuntimeError("kaput")
            return Samples(times_s=[1e-3])

    reg._register_for_tests(_Flaky())
    box = Box.from_dict(
        {
            "name": "b",
            "tasks": [
                {"task": "flaky", "params": {"z": [0, 1, 2, 3]}},
                {"task": "sweep", "params": {"a": [1], "b": ["x"]}},
            ],
        }
    )
    res = SweepExecutor(workers=4).run_box(box)
    assert len(res.errors) == 2 and all("kaput" in e["error"] for e in res.errors)
    assert any(r.task == "sweep" for r in res.results)  # other tasks still ran


def test_runner_facade_parallel(sweep_task):
    r1 = Runner().run_box(_box())
    r4 = Runner(workers=4).run_box(_box())
    assert r1.rows == r4.rows
    assert "platform" not in r1.rows[0]  # single-platform rows stay untagged


# -- result cache ------------------------------------------------------------
def test_cache_hit_miss_and_persistence(sweep_task, tmp_path):
    path = tmp_path / "cache.json"
    first = SweepExecutor(workers=2, cache=ResultCache(path)).run_box(_box())
    assert first.stats.cached == 0 and first.stats.executed == 8
    assert path.exists()

    # Fresh executor + fresh cache object: all 8 points come from disk.
    second = SweepExecutor(workers=2, cache=ResultCache(path)).run_box(_box())
    assert second.stats.cached == 8 and second.stats.executed == 0
    assert second.rows == first.rows  # identical report rows from cache


def test_cache_counts_run_calls(sweep_task, tmp_path):
    cache = ResultCache(tmp_path / "c.json")
    SweepExecutor(cache=cache).run_box(_box())
    assert sweep_task.run_calls == 8
    SweepExecutor(cache=cache).run_box(_box())
    assert sweep_task.run_calls == 8  # nothing re-measured


def test_cache_invalidation_on_measurement_identity(sweep_task, tmp_path):
    path = tmp_path / "c.json"
    SweepExecutor(iters=3, cache=ResultCache(path)).run_box(_box())
    # Different iteration count -> different key -> full remeasure.
    res = SweepExecutor(iters=5, cache=ResultCache(path)).run_box(_box())
    assert res.stats.cached == 0
    # Different platform -> different key.
    res = SweepExecutor(
        iters=3, platforms=["dpu-sim"], cache=ResultCache(path)
    ).run_box(_box())
    assert res.stats.cached == 0
    # Same identity again -> all hits.
    res = SweepExecutor(iters=3, cache=ResultCache(path)).run_box(_box())
    assert res.stats.cached == 8


def test_cache_clear_and_corruption(sweep_task, tmp_path):
    path = tmp_path / "c.json"
    cache = ResultCache(path)
    SweepExecutor(cache=cache).run_box(_box())
    cache.clear()
    assert SweepExecutor(cache=ResultCache(path)).run_box(_box()).stats.cached == 0

    path.write_text("{ not json")  # corrupt file: treated as empty, not fatal
    assert SweepExecutor(cache=ResultCache(path)).run_box(_box()).stats.cached == 0


def test_cache_key_sensitivity():
    base = dict(
        task="t", params={"a": 1}, platform={"name": "p"}, iters=3, warmup=1,
        metrics=("m",),
    )
    k = cache_key(**base)
    assert cache_key(**{**base, "params": {"a": 2}}) != k
    assert cache_key(**{**base, "platform": {"name": "q"}}) != k
    assert cache_key(**{**base, "warmup": 0}) != k
    assert cache_key(**base) == k  # stable
    # Task-source fingerprint is part of measurement identity.
    assert cache_key(**base, fingerprint="abc123") != k
    assert cache_key(**base, fingerprint="abc123") == cache_key(**base, fingerprint="abc123")


def test_task_source_fingerprint_is_stable_and_nonempty(sweep_task):
    fp = sweep_task.source_fingerprint()
    assert fp and fp == sweep_task.source_fingerprint()
    # Two different task classes in different modules fingerprint differently
    # (this test module vs. a built-in task module).
    from repro.core import registry

    registry.load_builtin_tasks()
    assert registry.get("pushdown").source_fingerprint() != fp


# -- platform backends -------------------------------------------------------
def test_platform_registry():
    assert {"default", "cpu-host", "dpu-sim"} <= set(known_platforms())
    sim = get_platform("dpu-sim")
    assert sim.kind == "sim" and sim.time_scale > 1.0
    assert resolve(None).name == "default"
    assert resolve("cpu-host").name == "cpu-host"
    legacy = resolve({"name": "cpu-host", "numa": 1})
    assert legacy.name == "cpu-host" and legacy.flags["numa"] == 1
    with pytest.raises(KeyError, match="unknown platform"):
        get_platform("gpu-moon")


def test_multi_platform_rows_carry_platform_column(sweep_task):
    res = SweepExecutor(platforms=["cpu-host", "dpu-sim"], workers=3).run_box(_box())
    assert res.stats.total == 16
    assert all("platform" in row for row in res.rows)
    assert {row["platform"] for row in res.rows} == {"cpu-host", "dpu-sim"}
    assert "platform" in res.csv().splitlines()[0]

    host = [r for r in res.rows if r["platform"] == "cpu-host"]
    sim = [r for r in res.rows if r["platform"] == "dpu-sim"]
    scale = get_platform("dpu-sim").time_scale
    for h, s in zip(host, sim):
        assert s["avg_latency_us"] == pytest.approx(scale * h["avg_latency_us"])

    sp = speedup_table(res.rows, "ops_per_s", "cpu-host")
    assert sp and sp[0]["speedup:dpu-sim"] == pytest.approx(1 / scale)


def test_box_declared_platform_sweep(sweep_task):
    box = Box.from_dict(
        {
            "name": "b",
            "platforms": ["cpu-host", "dpu-sim"],
            "tasks": [{"task": "sweep", "params": {"a": [1], "b": ["x"]}}],
        }
    )
    # Runner with no explicit platforms: the box declaration wins.
    res = Runner().run_box(box)
    assert {row["platform"] for row in res.rows} == {"cpu-host", "dpu-sim"}
    # Explicit executor platforms override the box.
    res2 = SweepExecutor(platforms=["cpu-host"]).run_box(box)
    assert all("platform" not in row for row in res2.rows)


def test_platform_context_isolation(sweep_task):
    ex = SweepExecutor(platforms=["cpu-host", "dpu-sim"])
    ex.run_box(_box(n_a=1))
    assert sweep_task.prepare_calls == 2  # one prepared context per platform
    ctx_host = ex._context(resolve("cpu-host"), "sweep")
    ctx_sim = ex._context(resolve("dpu-sim"), "sweep")
    assert ctx_host is not ctx_sim
    assert ctx_sim.platform["wimpy_cores"] is True


def test_clean_reaches_box_declared_platforms(sweep_task):
    box = Box.from_dict(
        {
            "name": "b",
            "platforms": ["cpu-host", "dpu-sim"],
            "tasks": [{"task": "sweep", "params": {"a": [1], "b": ["x"]}}],
        }
    )
    ex = SweepExecutor()  # default platforms; the box declares the sweep
    ex.run_box(box)
    host_ctx = ex._contexts[("cpu-host", "sweep")]
    assert host_ctx.scratch.get("ready")
    ex.clean("sweep")
    assert host_ctx.scratch == {}  # Task.clean saw the REAL prepared context
    assert not ex._contexts and not ex._prep
    # A re-run must prepare again from scratch.
    ex.run_box(box)
    assert sweep_task.prepare_calls == 4


def test_cache_invalidation_on_platform_flags(sweep_task, tmp_path):
    path = tmp_path / "c.json"
    SweepExecutor(
        platforms=[{"name": "cpu-host"}], cache=ResultCache(path)
    ).run_box(_box())
    # Same platform name but different capability flags -> different key.
    res = SweepExecutor(
        platforms=[{"name": "cpu-host", "numa": 1}], cache=ResultCache(path)
    ).run_box(_box())
    assert res.stats.cached == 0


def test_fail_fast_still_flushes_cache(tmp_path):
    class _Dies(Task):
        name = "dies"
        param_space = {"z": [0, 1, 2]}

        def run(self, ctx, params):
            if params["z"] == 2:
                raise RuntimeError("boom")
            return Samples(times_s=[1e-3])

    reg._register_for_tests(_Dies())
    box = Box.from_dict({"name": "b", "tasks": [{"task": "dies", "params": {"z": [0, 1, 2]}}]})
    path = tmp_path / "c.json"
    with pytest.raises(RuntimeError, match="boom"):
        SweepExecutor(fail_fast=True, cache=ResultCache(path)).run_box(box)
    # The two completed points survived the abort and are reused.
    res = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert res.stats.cached == 2 and len(res.errors) == 1


# -- sharding at the executor level ------------------------------------------
def test_run_box_shard_partitions_units(sweep_task):
    from repro.core import ShardSpec, merge_shard_reports

    full = SweepExecutor(workers=2).run_box(_box())
    shards = [SweepExecutor(workers=2).run_box(_box(), shard=ShardSpec(i, 3)) for i in range(3)]
    assert sum(s.stats.total for s in shards) == full.stats.total == 8
    assert merge_shard_reports([s.rows for s in shards], box=_box()) == full.rows
    # Shard partition is over the same grid regardless of worker count/pool.
    seq = [SweepExecutor().run_box(_box(), shard=ShardSpec(i, 3)) for i in range(3)]
    assert [s.stats.total for s in seq] == [s.stats.total for s in shards]


def test_shard_can_be_empty_without_erroring(sweep_task):
    from repro.core import ShardSpec

    # With more shards than units at least one shard must be empty.
    shards = [
        SweepExecutor().run_box(_box(n_a=1), shard=ShardSpec(i, 8)) for i in range(8)
    ]
    totals = [s.stats.total for s in shards]
    assert sum(totals) == 2 and 0 in totals
    for s in shards:
        assert not s.errors


def test_json_box_file_platform_sweep(tmp_path, sweep_task):
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "file_box",
                "platforms": ["cpu-host", "dpu-sim"],
                "tasks": [{"task": "sweep", "params": {"a": [1, 2], "b": ["x"]}}],
            }
        )
    )
    res = Runner().run_box(Box.load(bf))
    assert res.stats.total == 4  # 2 tests x 2 platforms
    assert {row["platform"] for row in res.rows} == {"cpu-host", "dpu-sim"}
