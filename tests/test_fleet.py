"""Elastic-fleet conformance: membership, failure detection, fault recovery.

Four pillars, matching the fleet layer's contract:

  1. *Transport hardening* — endpoint parsing rejects junk (out-of-range
     ports, unbracketed IPv6), a dispatch crash serializes back as an error
     response instead of killing the connection thread, wildcard binds
     announce a routable address, and deadline expiry / dead endpoints
     raise ``WorkerUnreachable`` (transport evidence) while clean task
     errors stay plain ``RemoteExecutionError`` (the endpoint is healthy).
  2. *Membership* — the registry's failure detector classifies workers
     alive/suspect/dead on a fake clock, heartbeats re-admit unknown
     endpoints, and the register/heartbeat/deregister ops work over the
     real wire protocol.
  3. *Elastic scheduling* — ``add_sink`` makes queued dynamic units
     claimable by a mid-run joiner, ``mark_dead`` re-homes queued tickets
     and re-enqueues in-flight units on survivors, and the FleetWatcher
     turns registry deltas into exactly those calls.
  4. *Fault recovery* — workers killed / hung / slowed / corrupting the
     wire mid-sweep: every scenario must finish with a report
     byte-identical to the fault-free sequential run, within the detection
     bound (seconds, never the 600 s request timeout).

Fault tests use deterministic directory-plugin tasks (metrics are pure
functions of params), so byte-equality checks are exact regardless of
which worker executed what.
"""
from __future__ import annotations

import json
import socket
import threading
import time

import pytest
from test_shard import make_plugin, plugin_box

from repro.core import config as config_mod
from repro.core import registry as reg
from repro.core import remote as remote_mod
from repro.core.cache import BLACKLIST_AFTER, EndpointHealthStore, ResultCache
from repro.core.executor import SweepExecutor
from repro.core.faults import FaultPlan, FaultSpec, inject
from repro.core.remote import (
    LocalWorker,
    RemoteExecutionError,
    RemoteTransport,
    WorkerServer,
    WorkerUnreachable,
    parse_endpoint,
    routable_host,
    unit_deadline_s,
)
from repro.core.scheduler import FleetScheduler, Sink, WorkItem
from repro.runtime.elastic import FleetWatcher
from repro.runtime.membership import MembershipRegistry, MembershipServer


# -- 1. transport hardening --------------------------------------------------
def test_parse_endpoint_accepts_hosts_ports_and_bracketed_ipv6():
    assert parse_endpoint("host:7177") == ("host", 7177)
    assert parse_endpoint("tcp://10.0.0.2:1") == ("10.0.0.2", 1)
    assert parse_endpoint(":8080") == ("127.0.0.1", 8080)
    assert parse_endpoint("[::1]:65535") == ("::1", 65535)
    assert parse_endpoint("[fe80::1%eth0]:80") == ("fe80::1%eth0", 80)


@pytest.mark.parametrize(
    "bad",
    ["host:99999", "host:0", "host:-1", "host:", "nope", "::1:8080", "a:b:80"],
)
def test_parse_endpoint_rejects_junk(bad):
    with pytest.raises(ValueError):
        parse_endpoint(bad)


def test_parse_endpoint_port_error_names_the_range():
    with pytest.raises(ValueError, match=r"\[1, 65535\]"):
        parse_endpoint("host:70000")


def test_routable_host_never_returns_a_wildcard():
    for wildcard in ("0.0.0.0", "::", ""):
        resolved = routable_host(wildcard)
        assert resolved not in ("0.0.0.0", "::", "")
    # non-wildcard binds pass through untouched
    assert routable_host("192.168.1.7") == "192.168.1.7"
    assert routable_host("localhost") == "localhost"


def test_worker_bound_to_wildcard_announces_routable_endpoint():
    srv = WorkerServer("0.0.0.0", 0)
    try:
        host, port = parse_endpoint(srv.endpoint)
        assert host != "0.0.0.0"
        assert port == srv.server_address[1]
        # and the announced endpoint really is connectable
        socket.create_connection((host, port), timeout=5).close()
    finally:
        srv.server_close()


def test_advertise_host_overrides_resolution():
    srv = WorkerServer("127.0.0.1", 0, advertise_host="worker-3.fleet.local")
    try:
        assert srv.endpoint.startswith("worker-3.fleet.local:")
    finally:
        srv.server_close()


def test_unit_deadline_layers():
    assert unit_deadline_s(None) == remote_mod.REQUEST_TIMEOUT_S  # no evidence
    assert unit_deadline_s(0.01) == remote_mod.MIN_UNIT_DEADLINE_S  # floor
    assert unit_deadline_s(2.0) == 20.0  # factor x estimate
    assert unit_deadline_s(1e9) == remote_mod.REQUEST_TIMEOUT_S  # ceiling


def test_dispatch_crash_serializes_error_and_connection_survives():
    """Satellite bugfix: an unexpected dispatch exception must write an
    error response back, not kill the connection thread (which left the
    client blocking until the 600 s request timeout)."""
    srv = WorkerServer("127.0.0.1", 0)
    real_dispatch = srv.dispatch

    def flaky_dispatch(req):
        if req.get("op") == "boom":
            raise RuntimeError("dispatch exploded")
        return real_dispatch(req)

    srv.dispatch = flaky_dispatch
    srv.serve_in_thread()
    try:
        t = RemoteTransport(srv.endpoint)
        resp = t.request({"op": "boom"}, timeout=10.0)
        assert resp["ok"] is False
        assert "dispatch exploded" in resp["error"]
        assert "RuntimeError" in resp.get("traceback", "")
        # same transport (and pooled connection) keeps working
        assert t.request({"op": "ping"}, timeout=10.0)["ok"] is True
        t.close()
    finally:
        srv.shutdown()
        srv.server_close()


def test_bad_request_json_answers_error_line():
    srv = WorkerServer("127.0.0.1", 0)
    srv.serve_in_thread()
    try:
        host, port = parse_endpoint(srv.endpoint)
        with socket.create_connection((host, port), timeout=5) as s:
            s.sendall(b"this is not json\n")
            line = s.makefile("rb").readline()
        resp = json.loads(line)
        assert resp["ok"] is False and "bad request JSON" in resp["error"]
    finally:
        srv.shutdown()
        srv.server_close()


def test_deadline_expiry_raises_worker_unreachable_fast():
    """A request past its deadline is a transport failure, detected at the
    deadline — never retried blind (the worker may still be executing)."""
    srv = WorkerServer("127.0.0.1", 0)
    real_dispatch = srv.dispatch

    def slow_dispatch(req):
        if req.get("op") == "stall":
            time.sleep(30)
        return real_dispatch(req)

    srv.dispatch = slow_dispatch
    srv.serve_in_thread()
    try:
        t = RemoteTransport(srv.endpoint)
        t0 = time.monotonic()
        with pytest.raises(WorkerUnreachable):
            t.request({"op": "stall"}, timeout=0.5)
        elapsed = time.monotonic() - t0
        assert elapsed < 5.0  # one deadline, not 2x (no blind re-send)
        t.close()
    finally:
        srv.shutdown()
        srv.server_close()


def test_dead_endpoint_raises_worker_unreachable():
    with socket.socket() as s:  # grab a port that is then closed
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    t = RemoteTransport(f"127.0.0.1:{port}")
    with pytest.raises(WorkerUnreachable):
        t.request({"op": "ping"}, connect_retries=1)


def test_task_error_is_not_worker_unreachable(tmp_path):
    """A worker that cleanly reports a task failure is a HEALTHY endpoint:
    the error must not be classified as transport evidence."""
    srv = WorkerServer("127.0.0.1", 0)
    srv.serve_in_thread()
    try:
        t = RemoteTransport(srv.endpoint)
        with pytest.raises(RemoteExecutionError) as exc_info:
            t.run_unit({"task": "no-such-task", "params": {}, "metrics": [],
                        "platform": {"name": "cpu-host"}, "iters": 1, "warmup": 0})
        assert not isinstance(exc_info.value, WorkerUnreachable)
        t.close()
    finally:
        srv.shutdown()
        srv.server_close()


# -- 2. membership -----------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_registry_failure_detector_alive_suspect_dead():
    clock = FakeClock()
    r = MembershipRegistry(heartbeat_interval_s=1.0, suspect_beats=3, dead_beats=10, now=clock)
    r.register("w:7001", capacity=2)
    assert [m["status"] for m in r.members()] == ["alive"]
    clock.t += 3.0  # exactly at the bound: still alive
    assert [m["status"] for m in r.members()] == ["alive"]
    clock.t += 0.5  # past 3 missed beats -> suspect
    assert [m["status"] for m in r.members()] == ["suspect"]
    assert r.alive() == []
    clock.t += 7.0  # past 10 beats -> dead, pruned from the table
    assert r.members() == []
    assert len(r) == 0


def test_registry_heartbeat_refreshes_and_readmits():
    clock = FakeClock()
    r = MembershipRegistry(heartbeat_interval_s=1.0, now=clock)
    r.register("w:7001")
    clock.t += 2.9
    r.heartbeat("w:7001")
    clock.t += 2.9  # 2.9 since last beat: alive again
    assert r.alive() == ["w:7001"]
    # a beat from an endpoint the registry never saw (restart) re-admits it
    resp = r.heartbeat("w:7002", capacity=4)
    assert resp["ok"] is True and resp["known"] is False
    members = {m["endpoint"]: m for m in r.members()}
    assert members["w:7002"]["capacity"] == 4


def test_registry_rejects_junk_endpoints_and_knobs():
    r = MembershipRegistry()
    with pytest.raises(ValueError):
        r.register("host:99999")
    assert r.handle({"op": "register", "endpoint": "host:99999"})["ok"] is False
    assert r.handle({"op": "register"})["ok"] is False
    assert r.handle({"op": "wat"})["ok"] is False
    with pytest.raises(ValueError):
        MembershipRegistry(heartbeat_interval_s=0.0)
    with pytest.raises(ValueError):
        MembershipRegistry(suspect_beats=5, dead_beats=3)


def test_register_heartbeat_deregister_over_the_wire():
    srv = MembershipServer("127.0.0.1", 0)
    srv.serve_in_thread()
    try:
        ack = remote_mod.register(srv.endpoint, "127.0.0.1:7501", capacity=3,
                                  meta={"rack": "r1"})
        assert ack["heartbeat_interval_s"] == remote_mod.HEARTBEAT_INTERVAL_S
        remote_mod.heartbeat(srv.endpoint, "127.0.0.1:7501")
        members = remote_mod.fleet_members(srv.endpoint)
        assert [(m["endpoint"], m["capacity"], m["meta"]) for m in members] == [
            ("127.0.0.1:7501", 3, {"rack": "r1"})
        ]
        remote_mod.deregister(srv.endpoint, "127.0.0.1:7501")
        assert remote_mod.fleet_members(srv.endpoint) == []
        # the registry answers ping like any worker (wait_ready works on it)
        assert remote_mod.wait_ready(srv.endpoint, timeout=5)
    finally:
        srv.shutdown()
        srv.server_close()


def test_worker_registers_beats_and_deregisters_on_close():
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=0.1)
    )
    srv.serve_in_thread()
    try:
        w = WorkerServer("127.0.0.1", 0, capacity=2,
                         register=srv.endpoint, heartbeat_interval_s=0.1)
        w.serve_in_thread()
        members = remote_mod.wait_members(srv.endpoint, count=1, timeout=10)
        assert [m["endpoint"] for m in members] == [w.endpoint]
        assert members[0]["capacity"] == 2
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:  # beats keep arriving
            beats = {m["endpoint"]: m["beats"] for m in remote_mod.fleet_members(srv.endpoint)}
            if beats.get(w.endpoint, 0) >= 2:
                break
            time.sleep(0.05)
        assert beats[w.endpoint] >= 2
        w.shutdown()
        w.server_close()  # graceful leave: deregisters, no detection wait
        assert remote_mod.fleet_members(srv.endpoint) == []
    finally:
        srv.shutdown()
        srv.server_close()


# -- 3. elastic scheduling ---------------------------------------------------
def _instant_sink(name, log=None, delay=0.0):
    def run(unit):
        if delay:
            time.sleep(delay)
        if log is not None:
            log.append((name, unit))
        return (f"{name}:{unit}", False)

    return Sink(name=name, capacity=1, run=run)


def test_add_sink_mid_run_takes_dynamic_work():
    log: list = []
    sched = FleetScheduler([_instant_sink("slow", log, delay=0.05)], poll_s=0.01)

    def join():
        time.sleep(0.1)
        sched.add_sink(_instant_sink("fast", log, delay=0.0))

    threading.Thread(target=join, daemon=True).start()
    outcomes = sched.run([WorkItem(i) for i in range(30)])
    assert all(o.error is None for o in outcomes)
    assert {name for name, _ in log} == {"slow", "fast"}
    assert set(sched.live_sinks()) == {"slow", "fast"}


def test_add_sink_does_not_take_pinned_work():
    log: list = []
    sched = FleetScheduler([_instant_sink("pinned", log, delay=0.02)], poll_s=0.01)

    def join():
        time.sleep(0.05)
        sched.add_sink(_instant_sink("other", log))

    threading.Thread(target=join, daemon=True).start()
    outcomes = sched.run([WorkItem(i, sinks=(0,)) for i in range(10)])
    assert all(o.error is None for o in outcomes)
    assert {name for name, _ in log} == {"pinned"}


def test_mark_dead_reenqueues_in_flight_and_queued_units():
    hang = threading.Event()

    def wedged(unit):
        hang.wait(30)
        return ("wedged", False)

    log: list = []
    sched = FleetScheduler(
        [Sink("wedged", 1, wedged), _instant_sink("healthy", log, delay=0.01)],
        poll_s=0.01,
    )

    def reap():
        time.sleep(0.2)
        sched.mark_dead("wedged")

    threading.Thread(target=reap, daemon=True).start()
    t0 = time.monotonic()
    outcomes = sched.run([WorkItem(i) for i in range(10)])
    elapsed = time.monotonic() - t0
    hang.set()
    assert all(o.error is None for o in outcomes)
    assert elapsed < 10.0  # detection + re-dispatch, not a timeout wait
    assert sum(o.redispatched for o in outcomes) >= 1  # the in-flight unit
    assert all(o.sink == "healthy" for o in outcomes)
    assert sched.live_sinks() == ["healthy"]


def test_mark_dead_sole_pinned_sink_is_terminal_error_not_hang():
    sched = FleetScheduler(
        [_instant_sink("a", delay=0.2), _instant_sink("b")], poll_s=0.01
    )

    def reap():
        time.sleep(0.05)
        sched.mark_dead("a")

    threading.Thread(target=reap, daemon=True).start()
    outcomes = sched.run(
        [WorkItem("pinned-to-a", cost=0.0, sinks=(0,)) for _ in range(3)]
        + [WorkItem(f"free-{i}") for i in range(3)]
    )
    frees = [o for o in outcomes if str(o.item.unit).startswith("free")]
    assert all(o.error is None for o in frees)
    pinned = [o for o in outcomes if str(o.item.unit).startswith("pinned")]
    # queued pinned units whose only sink died error out instead of hanging
    assert any(o.error is not None for o in pinned) or all(
        o.sink == "a" for o in pinned
    )


def test_fleet_watcher_applies_membership_deltas():
    clock = FakeClock()
    registry = MembershipRegistry(heartbeat_interval_s=1.0, now=clock)
    srv = MembershipServer("127.0.0.1", 0, registry=registry)
    srv.serve_in_thread()
    try:
        registry.register("127.0.0.1:7601")
        sched = FleetScheduler([_instant_sink("127.0.0.1:7601")], poll_s=0.01)
        watcher = FleetWatcher(srv.endpoint, sched, make_sink=_instant_sink)
        # join: a new registration becomes a sink
        registry.register("127.0.0.1:7602")
        watcher.poll_once()
        assert set(sched.live_sinks()) == {"127.0.0.1:7601", "127.0.0.1:7602"}
        assert watcher.joined == ["127.0.0.1:7602"]
        # leave: beats stop -> suspect -> marked dead
        clock.t += 2.0
        registry.heartbeat("127.0.0.1:7602")
        clock.t += 1.5  # 7601 silent 3.5s (suspect); 7602 beat 1.5s ago (alive)
        watcher.poll_once()
        assert sched.live_sinks() == ["127.0.0.1:7602"]
        assert watcher.left == ["127.0.0.1:7601"]
        # a stale suspect row must not re-kill; a re-registration re-joins
        registry.register("127.0.0.1:7601")
        watcher.poll_once()
        assert "127.0.0.1:7601" in sched.live_sinks()
    finally:
        srv.shutdown()
        srv.server_close()


# -- health sidecar ----------------------------------------------------------
def test_health_store_persists_streaks_and_blacklists(tmp_path):
    path = tmp_path / "health.json"
    h = EndpointHealthStore(path)
    for _ in range(BLACKLIST_AFTER):
        h.observe_failure("w:7001")
    h.observe_success("w:7002", latency_s=0.25)
    h.flush()

    h2 = EndpointHealthStore(path)  # cross-run: reload from disk
    assert h2.blacklisted("w:7001")
    assert not h2.blacklisted("w:7002")
    rec = h2.get("w:7002")
    assert rec["ewma_latency_s"] == pytest.approx(0.25)
    assert rec["last_seen_unix"] > 0
    # one success resets the streak (recovery is cheap)
    h2.observe_success("w:7001")
    assert not h2.blacklisted("w:7001")
    assert h2.get("w:7001")["failures"] == BLACKLIST_AFTER  # history kept


def test_health_store_survives_corrupt_file(tmp_path):
    path = tmp_path / "health.json"
    path.write_text("{not json")
    h = EndpointHealthStore(path)
    assert len(h) == 0
    h.observe_failure("w:1234")
    h.flush()
    assert json.loads(path.read_text())["entries"]["w:1234"]["failures"] == 1


def test_result_cache_owns_health_sidecar(tmp_path):
    cache = ResultCache(tmp_path / "cache.json")
    assert cache.health is not None
    cache.health.observe_failure("w:7001")
    cache.flush()
    assert (tmp_path / "health.json").exists()
    # clear() erases results but health evidence survives (like costs)
    cache.clear()
    again = ResultCache(tmp_path / "cache.json")
    assert again.health.get("w:7001")["failures"] == 1


def test_executor_blacklists_chronic_endpoint_only_with_alternatives(tmp_path):
    d = make_plugin(tmp_path, "blt", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("blt")
    with LocalWorker(plugin_dirs=[d]) as w:
        dead = "127.0.0.1:9"  # discard port: nothing listens
        cache = ResultCache(tmp_path / "cache.json")
        for _ in range(BLACKLIST_AFTER):
            cache.health.observe_failure(dead)
        ex = SweepExecutor(
            platforms=["cpu-host"], workers=2, iters=1, warmup=0,
            remote=f"{w.endpoint},{dead}", cache=cache,
        )
        res = ex.run_box(box)
        assert res.stats.errors == 0
        assert res.stats.blacklisted == 1  # the dead endpoint never got a sink
        baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
        assert res.csv() == baseline.csv()


# -- 4. fault recovery (kill / hang / slow / partial) ------------------------
@pytest.fixture()
def fleet_env(tmp_path):
    """A 2-worker registered fleet over a deterministic plugin task."""
    d = make_plugin(tmp_path, "flt", 3)
    reg.load_plugin_dir(d)
    box = plugin_box("flt")
    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=0.2)
    )
    srv.serve_in_thread()
    workers = [
        LocalWorker(plugin_dirs=[d], register=srv.endpoint,
                    heartbeat_interval_s=0.2, allow_faults=True).__enter__()
        for _ in range(2)
    ]
    remote_mod.wait_members(srv.endpoint, count=2, timeout=30)
    # max_entries=0: flush evicts raw entries, so every pass re-executes
    # while costs/health evidence still accumulates in the sidecars.
    cache = ResultCache(tmp_path / "cache.json", max_entries=0)
    ex = SweepExecutor(
        platforms=["cpu-host"], workers=2, iters=1, warmup=0,
        fleet_registry=srv.endpoint, cache=cache,
    )
    first = ex.run_box(box)  # seed the costs sidecar (unit deadlines)
    assert first.csv() == baseline.csv()
    cache.clear()
    try:
        yield {"box": box, "baseline": baseline, "ex": ex, "cache": cache,
               "srv": srv, "workers": workers, "plugin": d}
    finally:
        for w in workers:
            w.__exit__(None, None, None)
        srv.shutdown()
        srv.server_close()


def test_worker_killed_mid_unit_recovers_fast(fleet_env):
    inject(fleet_env["workers"][0].endpoint, FaultSpec("kill"))
    t0 = time.monotonic()
    res = fleet_env["ex"].run_box(fleet_env["box"])
    elapsed = time.monotonic() - t0
    assert res.stats.errors == 0
    assert res.csv() == fleet_env["baseline"].csv()
    assert elapsed < 10.0, f"kill detection took {elapsed:.1f}s"


def test_worker_hung_mid_unit_recovers_within_bound(fleet_env):
    # hang: accepts the unit, never replies — but KEEPS heartbeating, so
    # only deadlines/speculation (not membership) can catch it.
    inject(fleet_env["workers"][1].endpoint, FaultSpec("hang", seconds=300))
    t0 = time.monotonic()
    res = fleet_env["ex"].run_box(fleet_env["box"])
    elapsed = time.monotonic() - t0
    assert res.stats.errors == 0
    assert res.csv() == fleet_env["baseline"].csv()
    assert elapsed < 10.0, f"hang detection took {elapsed:.1f}s"


def test_worker_slow_then_recovers_is_not_blacklisted(fleet_env):
    ep = fleet_env["workers"][0].endpoint
    inject(ep, FaultSpec("slow", seconds=0.5, units=2))
    res = fleet_env["ex"].run_box(fleet_env["box"])
    assert res.stats.errors == 0
    assert res.csv() == fleet_env["baseline"].csv()
    health = fleet_env["cache"].health
    assert not health.blacklisted(ep)  # transient slowness is not failure
    rec = health.get(ep)
    assert rec is None or rec["consecutive_failures"] < BLACKLIST_AFTER


def test_partial_garbage_on_wire_recovers(fleet_env):
    # truncated JSON + dropped connection on two units: the transport's
    # fresh-dial retry absorbs it without losing either unit.
    inject(fleet_env["workers"][0].endpoint, FaultSpec("partial", units=2))
    res = fleet_env["ex"].run_box(fleet_env["box"])
    assert res.stats.errors == 0
    assert res.csv() == fleet_env["baseline"].csv()


def test_replacement_worker_joins_mid_sweep(fleet_env):
    """Kill one worker AND register a replacement while the sweep runs:
    the watcher must fold the joiner in and the report stay identical."""
    inject(fleet_env["workers"][0].endpoint, FaultSpec("kill"))
    spare = LocalWorker(
        plugin_dirs=[fleet_env["plugin"]],
        register=fleet_env["srv"].endpoint,
        heartbeat_interval_s=0.2,
        allow_faults=True,
    )

    def late_join():
        time.sleep(0.1)
        spare.__enter__()

    joiner = threading.Thread(target=late_join, daemon=True)
    joiner.start()
    try:
        res = fleet_env["ex"].run_box(fleet_env["box"])
        assert res.stats.errors == 0
        assert res.csv() == fleet_env["baseline"].csv()
    finally:
        joiner.join()
        spare.__exit__(None, None, None)


# -- fault harness + config surface ------------------------------------------
def test_fault_plan_is_seed_deterministic():
    a = [FaultPlan(7).draw() for _ in range(20)]
    b = [FaultPlan(7).draw() for _ in range(20)]
    assert a == b
    assert {s.mode for s in a} <= {"kill", "hang", "slow", "partial"}
    assert [FaultPlan(9).draw() for _ in range(20)] != a  # seed changes the stream


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("explode")
    with pytest.raises(ValueError):
        FaultSpec("slow", seconds=-1)
    with pytest.raises(ValueError):
        FaultSpec("slow", units=0)


def test_worker_without_allow_faults_refuses_injection():
    srv = WorkerServer("127.0.0.1", 0)  # allow_faults defaults OFF
    srv.serve_in_thread()
    try:
        with pytest.raises(RemoteExecutionError, match="disabled"):
            inject(srv.endpoint, FaultSpec("kill"))
    finally:
        srv.shutdown()
        srv.server_close()


def test_remote_and_registry_are_mutually_exclusive():
    errors: list[str] = []
    cfg = config_mod.SweepConfig(remote="h:1", registry="h:2")
    config_mod.validate_sweep(cfg, errors.append, ping_remote=False)
    assert any("mutually exclusive" in e for e in errors)
    with pytest.raises(ValueError):
        SweepExecutor(remote="h:1", fleet_registry="h:2")


def test_registry_flag_threads_through_config(tmp_path):
    import argparse

    p = argparse.ArgumentParser()
    config_mod.add_sweep_args(p)
    ns = p.parse_args(["--registry", "127.0.0.1:7170"])
    cfg = config_mod.SweepConfig.from_args(ns)
    assert cfg.registry == "127.0.0.1:7170"
    errors: list[str] = []
    config_mod.validate_sweep(cfg, errors.append, ping_remote=False)
    assert errors == []
    bad = config_mod.SweepConfig(registry="host:99999")
    config_mod.validate_sweep(bad, errors.append, ping_remote=False)
    assert any("65535" in e for e in errors)


def test_runner_cli_runs_box_through_registry(tmp_path, capsys):
    from repro.core import runner as runner_mod

    d = make_plugin(tmp_path, "clireg", 2)
    box_path = tmp_path / "box.json"
    box_path.write_text(json.dumps({
        "name": "clireg_box",
        "tasks": [{"task": "clireg", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
    }))
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=0.2)
    )
    srv.serve_in_thread()
    try:
        with LocalWorker(plugin_dirs=[d], register=srv.endpoint,
                         heartbeat_interval_s=0.2):
            remote_mod.wait_members(srv.endpoint, count=1, timeout=30)
            out = tmp_path / "rows.csv"
            rc = runner_mod.main([
                "--box", str(box_path), "--plugin-dir", str(d),
                "--iters", "1", "--warmup", "0", "--workers", "2",
                "--registry", srv.endpoint, "--out", str(out),
            ])
            assert rc == 0
            assert out.read_text().count("\n") > 1
    finally:
        srv.shutdown()
        srv.server_close()
