"""Infrastructure units: roofline HLO parsing, data pipeline, optimizers,
schedules, mesh rules / ZeRO-1 spec assignment."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_arch
from repro.data.pipeline import DataConfig, SyntheticLM, for_model
from repro.launch import roofline as rf
from repro.optim import make_optimizer, make_schedule


# -- roofline HLO parsing -------------------------------------------------------
HLO_SAMPLE = """
  %ag = f32[64,256] all-gather(f32[4,256] %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = bf16[1024] all-reduce(bf16[1024] %y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[128] reduce-scatter(f32[2048] %z), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %cp = u8[512] collective-permute(u8[512] %w), source_target_pairs={{0,1}}
"""


def test_parse_collectives_kinds_and_bytes():
    stats = rf.parse_collectives(HLO_SAMPLE)
    assert set(stats) == {"all-gather", "all-reduce", "reduce-scatter", "collective-permute"}
    # all-gather result 64*256*4 bytes, ring wire = (n-1)/n * result
    ag = stats["all-gather"]
    assert ag.result_bytes == 64 * 256 * 4
    np.testing.assert_allclose(ag.wire_bytes, 15 / 16 * 64 * 256 * 4)
    # all-reduce bf16[1024] -> 2(n-1)/n * 2048 bytes with n=16 (iota groups)
    ar = stats["all-reduce"]
    assert ar.result_bytes == 2048
    np.testing.assert_allclose(ar.wire_bytes, 2 * 15 / 16 * 2048)
    # reduce-scatter result f32[128] -> wire (n-1)*result
    rs = stats["reduce-scatter"]
    np.testing.assert_allclose(rs.wire_bytes, 15 * 128 * 4)
    # permute moves exactly its buffer
    np.testing.assert_allclose(stats["collective-permute"].wire_bytes, 512)


def test_analyze_bottleneck_and_ratio():
    cost = {"flops": 1e12, "bytes accessed": 1e9}
    roof = rf.analyze(cost, HLO_SAMPLE, n_chips=256, model_flops_total=200e12)
    assert roof.compute_s == pytest.approx(1e12 / rf.PEAK_FLOPS)
    assert roof.memory_s == pytest.approx(1e9 / rf.HBM_BW)
    assert roof.bottleneck == "compute"
    assert roof.useful_flops_ratio == pytest.approx(200e12 / (1e12 * 256))


def test_shape_bytes_tuple_shapes():
    # tuple-shaped collective results sum every component
    assert rf._shape_bytes("(f32[8], bf16[4])") == 8 * 4 + 4 * 2


def test_model_flops_dense_vs_moe():
    from repro.configs.base import SHAPES

    dense = get_arch("granite-3-8b")
    moe = get_arch("kimi-k2-1t-a32b")
    cell = SHAPES["train_4k"]
    toks = cell.global_batch * cell.seq_len
    assert rf.model_flops(dense, cell) == pytest.approx(6.0 * dense.n_params() * toks)
    assert rf.model_flops(moe, cell) == pytest.approx(6.0 * moe.n_active_params() * toks)
    assert moe.n_active_params() < 0.1 * moe.n_params()  # 32B active of 1T


# -- data pipeline ---------------------------------------------------------------
def test_pipeline_deterministic_and_structured():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch_at(3), ds.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))
    assert not np.array_equal(np.asarray(ds.batch_at(4)["inputs"]), np.asarray(b1["inputs"]))
    # labels are the declared function of inputs (learnable structure)
    t = np.asarray(b1["inputs"])
    np.testing.assert_array_equal(
        np.asarray(b1["labels"]), (cfg.struct_a * t + cfg.struct_b) % cfg.struct_mod
    )


def test_pipeline_matches_arch_contract():
    cfg = get_arch("qwen2-vl-72b")  # mrope + embeddings stub? (embed_inputs False?)
    ds = for_model(cfg, seq_len=16, global_batch=2)
    batch = ds.batch_at(0)
    assert set(batch) == {"inputs", "labels", "positions"}
    if cfg.rope == "mrope":
        assert batch["positions"].shape == (3, 2, 16)

    enc = get_arch("seamless-m4t-medium")
    ds2 = for_model(enc, seq_len=8, global_batch=2)
    b2 = ds2.batch_at(0)
    assert set(b2) == {"frames", "tgt_tokens", "labels"}
    assert b2["frames"].shape == (2, 8, enc.d_model)


# -- optimizers ------------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_step_reduces_quadratic(name):
    opt = make_optimizer(name)
    params = {"w": jnp.full((4, 8), 2.0), "b": jnp.zeros((8,))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    l0 = float(loss(params))
    for i in range(20):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params, 0.1)
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_state_is_factored():
    """Adafactor must NOT keep a full second-moment matrix for 2D params."""
    opt = make_optimizer("adafactor")
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    sizes = [int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(state)]
    assert max(sizes) <= 64, f"factored state should be O(n+m), got {sizes}"


def test_schedules():
    s = make_schedule("warmup_cosine", peak_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(s(0)) == pytest.approx(0.0, abs=1e-9)
    assert float(s(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(100)) < 2e-4
    r = make_schedule("warmup_rsqrt", peak_lr=1e-3, warmup_steps=10)
    assert float(r(40)) == pytest.approx(1e-3 * (10 / 40) ** 0.5, rel=1e-3)


# -- mesh rules / ZeRO-1 ----------------------------------------------------------
def test_zero1_spec_assignment_properties():
    """ZeRO-1: every optimizer-state leaf with a free dim divisible by the
    data-axis size gets sharded over data; already-data-sharded leaves are
    left alone. Checked structurally (no 256-device mesh needed)."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import Rules, zero1_specs

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = Rules({"embed": None, "mlp": "model", "vocab": "model"})
    logical = {"m": ("embed", "mlp"), "v": ("vocab", None)}
    abstract = {
        "m": jax.ShapeDtypeStruct((4096, 1024), jnp.float32),
        "v": jax.ShapeDtypeStruct((50304, 64), jnp.float32),
    }
    specs = zero1_specs(logical, abstract, rules, FakeMesh())
    # "m": embed dim free (None), 4096 % 16 == 0 -> data lands on dim 0
    assert specs["m"] == P("data", "model")
    # "v": vocab -> model on dim 0; dim 1 = 64 % 16 == 0 -> data on dim 1
    assert specs["v"] == P("model", "data")


@given(st.integers(1, 4096))
@settings(max_examples=30, deadline=None)
def test_padded_vocab_divisibility(v):
    import dataclasses

    cfg = dataclasses.replace(get_arch("olmo-1b"), vocab_size=v)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= v
    assert cfg.padded_vocab - v < 256
