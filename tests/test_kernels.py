"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle across
shapes/dtypes (interpret mode on CPU), plus algebraic property tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,dh,bq,bk",
    [
        (1, 128, 4, 4, 64, 128, 128),  # MHA single block
        (2, 256, 8, 2, 64, 128, 128),  # GQA group 4
        (1, 512, 4, 1, 128, 128, 256),  # MQA, rectangular blocks
        (2, 256, 6, 2, 32, 64, 64),  # head_dim 32, 3-way groups
    ],
)
def test_flash_attention_sweep(dtype, b, s, hq, hkv, dh, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=128)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,dh,bk,lens",
    [
        (2, 256, 8, 4, 64, 128, (100, 256)),
        (1, 512, 4, 1, 128, 256, (1,)),  # single valid token
        (3, 128, 6, 2, 32, 64, (128, 64, 17)),
    ],
)
def test_decode_attention_sweep(dtype, b, s, hq, hkv, dh, bk, lens):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    kv_len = jnp.asarray(lens, jnp.int32)
    out = ops.decode_attention(q, k, v, kv_len, block_k=bk)
    exp = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


def test_decode_attention_ignores_tail():
    """Cache contents past kv_len must not affect the output."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (1, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    kv_len = jnp.array([100], jnp.int32)
    out1 = ops.decode_attention(q, k, v, kv_len, block_k=64)
    k2 = k.at[:, 100:].set(jax.random.normal(ks[3], (1, 156, 2, 64)) * 50)
    out2 = ops.decode_attention(q, k2, v, kv_len, block_k=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 128, 2, 16, 16, 128), (2, 256, 4, 32, 16, 128), (1, 256, 2, 64, 32, 256)],
)
def test_ssd_intra_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.5
    cm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    a = -jnp.exp(jnp.linspace(0.0, 1.5, h))
    y, st_ = ops.ssd_intra(x, bm, cm, dt, a, chunk=chunk)
    ye, ste = ops.ssd_intra(x, bm, cm, dt, a, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(ste), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_naive_recurrence():
    """The model's full chunked SSD path == a naive O(S) recurrent scan."""
    from repro.models.ssm import ssd_chunked
    from repro.configs.base import get_arch, tiny

    cfg = tiny(get_arch("mamba2-2.7b"), ssm_chunk=8)
    b, s, h, p, n = 2, 32, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.3
    cm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    a = -jnp.exp(jnp.linspace(0.0, 1.0, h))
    y_chunk, final = ssd_chunked(cfg, x, bm, cm, dt, a)

    # naive recurrence
    def step(state, i):
        decay = jnp.exp(dt[:, i] * a)  # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, i], bm[:, i], x[:, i])
        state = decay[:, :, None, None] * state + upd
        y = jnp.einsum("bn,bhpn->bhp", cm[:, i], state)
        return state, y

    state0 = jnp.zeros((b, h, p, n))
    final_naive, ys = jax.lax.scan(step, state0, jnp.arange(s))
    y_naive = jnp.moveaxis(ys, 0, 1)  # [B,S,H,P]
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_naive), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,c,d,f,bc,bf,bd",
    [(2, 128, 128, 128, 128, 128, 128), (4, 256, 512, 256, 128, 128, 256),
     (8, 128, 256, 384, 64, 128, 128)],
)
def test_gmm_sweep(dtype, e, c, d, f, bc, bf, bd):
    ks = jax.random.split(KEY, 2)
    lhs = jax.random.normal(ks[0], (e, c, d), dtype)
    rhs = jax.random.normal(ks[1], (e, d, f), dtype)
    out = ops.gmm(lhs, rhs, block_c=bc, block_f=bf, block_d=bd)
    exp = ref.gmm_ref(lhs, rhs)
    tol = dict(rtol=3e-2, atol=0.5) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32), **tol)


# ---------------------------------------------------------------------------
@given(
    n=st.sampled_from([4096, 8192, 20000]),
    lo=st.floats(0.0, 0.5),
    width=st.floats(0.01, 0.5),
)
@settings(max_examples=10, deadline=None)
def test_filter_agg_property(n, lo, width):
    """Kernel == oracle == plain numpy for random predicates (incl. padding)."""
    cols = jax.random.uniform(jax.random.fold_in(KEY, n), (4, n), jnp.float32)
    hi = lo + width
    out = ops.filter_agg(cols, lo, hi, 0.2, 0.9, block_n=4096)
    exp = ref.filter_agg_ref(cols, lo, hi, 0.2, 0.9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4, atol=1e-4)
    c = np.asarray(cols)
    mask = (c[0] >= lo) & (c[0] < hi) & (c[1] >= 0.2) & (c[1] < 0.9)
    assert int(out[1]) == int(mask.sum())
