"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle across
shapes/dtypes (interpret mode on CPU), plus algebraic property tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.group_filter_agg import encode_aggregates, encode_predicates

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,dh,bq,bk",
    [
        (1, 128, 4, 4, 64, 128, 128),  # MHA single block
        (2, 256, 8, 2, 64, 128, 128),  # GQA group 4
        (1, 512, 4, 1, 128, 128, 256),  # MQA, rectangular blocks
        (2, 256, 6, 2, 32, 64, 64),  # head_dim 32, 3-way groups
    ],
)
def test_flash_attention_sweep(dtype, b, s, hq, hkv, dh, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=128)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,dh,bk,lens",
    [
        (2, 256, 8, 4, 64, 128, (100, 256)),
        (1, 512, 4, 1, 128, 256, (1,)),  # single valid token
        (3, 128, 6, 2, 32, 64, (128, 64, 17)),
    ],
)
def test_decode_attention_sweep(dtype, b, s, hq, hkv, dh, bk, lens):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    kv_len = jnp.asarray(lens, jnp.int32)
    out = ops.decode_attention(q, k, v, kv_len, block_k=bk)
    exp = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


def test_decode_attention_ignores_tail():
    """Cache contents past kv_len must not affect the output."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (1, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    kv_len = jnp.array([100], jnp.int32)
    out1 = ops.decode_attention(q, k, v, kv_len, block_k=64)
    k2 = k.at[:, 100:].set(jax.random.normal(ks[3], (1, 156, 2, 64)) * 50)
    out2 = ops.decode_attention(q, k2, v, kv_len, block_k=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 128, 2, 16, 16, 128), (2, 256, 4, 32, 16, 128), (1, 256, 2, 64, 32, 256)],
)
def test_ssd_intra_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.5
    cm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    a = -jnp.exp(jnp.linspace(0.0, 1.5, h))
    y, st_ = ops.ssd_intra(x, bm, cm, dt, a, chunk=chunk)
    ye, ste = ops.ssd_intra(x, bm, cm, dt, a, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(ste), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_naive_recurrence():
    """The model's full chunked SSD path == a naive O(S) recurrent scan."""
    from repro.models.ssm import ssd_chunked
    from repro.configs.base import get_arch, tiny

    cfg = tiny(get_arch("mamba2-2.7b"), ssm_chunk=8)
    b, s, h, p, n = 2, 32, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.3
    cm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    a = -jnp.exp(jnp.linspace(0.0, 1.0, h))
    y_chunk, final = ssd_chunked(cfg, x, bm, cm, dt, a)

    # naive recurrence
    def step(state, i):
        decay = jnp.exp(dt[:, i] * a)  # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, i], bm[:, i], x[:, i])
        state = decay[:, :, None, None] * state + upd
        y = jnp.einsum("bn,bhpn->bhp", cm[:, i], state)
        return state, y

    state0 = jnp.zeros((b, h, p, n))
    final_naive, ys = jax.lax.scan(step, state0, jnp.arange(s))
    y_naive = jnp.moveaxis(ys, 0, 1)  # [B,S,H,P]
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_naive), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,c,d,f,bc,bf,bd",
    [(2, 128, 128, 128, 128, 128, 128), (4, 256, 512, 256, 128, 128, 256),
     (8, 128, 256, 384, 64, 128, 128)],
)
def test_gmm_sweep(dtype, e, c, d, f, bc, bf, bd):
    ks = jax.random.split(KEY, 2)
    lhs = jax.random.normal(ks[0], (e, c, d), dtype)
    rhs = jax.random.normal(ks[1], (e, d, f), dtype)
    out = ops.gmm(lhs, rhs, block_c=bc, block_f=bf, block_d=bd)
    exp = ref.gmm_ref(lhs, rhs)
    tol = dict(rtol=3e-2, atol=0.5) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32), **tol)


# ---------------------------------------------------------------------------
@given(
    n=st.sampled_from([4096, 8192, 20000]),
    lo=st.floats(0.0, 0.5),
    width=st.floats(0.01, 0.5),
)
@settings(max_examples=10, deadline=None)
def test_filter_agg_property(n, lo, width):
    """Kernel == oracle == plain numpy for random predicates (incl. padding)."""
    cols = jax.random.uniform(jax.random.fold_in(KEY, n), (4, n), jnp.float32)
    hi = lo + width
    out = ops.filter_agg(cols, lo, hi, 0.2, 0.9, block_n=4096)
    exp = ref.filter_agg_ref(cols, lo, hi, 0.2, 0.9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4, atol=1e-4)
    c = np.asarray(cols)
    mask = (c[0] >= lo) & (c[0] < hi) & (c[1] >= 0.2) & (c[1] < 0.9)
    assert int(out[1]) == int(mask.sum())


# ---------------------------------------------------------------------------
# group_filter_agg: the generalized single-pass grouped filter+aggregate.
def _gfa_case(n, num_groups, lo, width, seed):
    cols = jax.random.uniform(jax.random.fold_in(KEY, seed), (5, n), jnp.float32)
    keys = jax.random.randint(jax.random.fold_in(KEY, seed + 1), (n,), 0, num_groups)
    pred_ops, pred_consts = encode_predicates(
        [("range", 0, lo, lo + width), ("lt", 1, 2)]
    )
    agg_ops, agg_consts = encode_aggregates(
        [
            [("col", 3)],
            [("col", 3), ("one_minus", 4)],
            [("col", 3), ("one_minus", 4), ("one_plus", 2)],
            [("le", 1, 0.5)],
            [("gt", 1, 0.5)],
        ]
    )
    return cols, keys, pred_ops, pred_consts, agg_ops, agg_consts


@given(
    n=st.sampled_from([512, 4096, 20000, 100_000]),  # ragged tails force padding
    num_groups=st.sampled_from([1, 6, 128]),
    lo=st.floats(0.0, 0.5),
    width=st.floats(0.01, 0.5),
)
@settings(max_examples=10, deadline=None)
def test_group_filter_agg_property(n, num_groups, lo, width):
    """Kernel == oracle == numpy across group counts, predicates, padding."""
    cols, keys, po, pc, ao, ac = _gfa_case(n, num_groups, lo, width, n + num_groups)
    out = ops.group_filter_agg(cols, keys, po, pc, ao, ac,
                               num_groups=num_groups, block_n=4096)
    exp = ref.group_filter_agg_ref(cols, keys, po, pc, ao, ac, num_groups)
    assert out.shape == (num_groups, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=1e-3)
    # counts are integer sums: exact, and cross-checked against plain numpy
    c, k = np.asarray(cols), np.asarray(keys)
    m = (c[0] >= lo) & (c[0] < lo + width) & (c[1] < c[2])
    np.testing.assert_array_equal(np.asarray(exp[:, -1]), np.asarray(out[:, -1]))
    for g in range(num_groups):
        assert int(out[g, -1]) == int(((k == g) & m).sum())


@pytest.mark.parametrize("all_pass", [True, False])
def test_group_filter_agg_degenerate_masks(all_pass):
    """All-pass (open range) and all-fail (empty range) predicate programs."""
    n = 5000  # ragged vs block 4096
    cols = jax.random.uniform(jax.random.fold_in(KEY, 33), (3, n), jnp.float32)
    keys = jax.random.randint(jax.random.fold_in(KEY, 34), (n,), 0, 6)
    preds = [("range", 0, None, None)] if all_pass else [("range", 0, 0.5, 0.5)]
    po, pc = encode_predicates(preds)
    ao, ac = encode_aggregates([[("col", 1)], [("col", 1), ("col", 2)]])
    out = ops.group_filter_agg(cols, keys, po, pc, ao, ac, num_groups=6, block_n=4096)
    exp = ref.group_filter_agg_ref(cols, keys, po, pc, ao, ac, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=1e-4)
    assert int(np.asarray(out[:, -1]).sum()) == (n if all_pass else 0)


def test_group_filter_agg_ref_escape_hatch():
    """use_pallas=False routes to the oracle (modulo jit) — same values."""
    cols, keys, po, pc, ao, ac = _gfa_case(4096, 6, 0.1, 0.6, 77)
    a = ops.group_filter_agg(cols, keys, po, pc, ao, ac, num_groups=6, use_pallas=False)
    b = ref.group_filter_agg_ref(cols, keys, po, pc, ao, ac, 6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_encode_program_validation():
    with pytest.raises(ValueError, match="unknown predicate kind"):
        encode_predicates([("ge", 0, 1.0, 2.0)])
    with pytest.raises(ValueError, match="unknown term kind"):
        encode_aggregates([[("sqrt", 0)]])
    with pytest.raises(ValueError, match="terms"):
        encode_aggregates([[("col", 0)] * 4])
    po, pc = encode_predicates([])  # empty program = always-true
    assert po.shape == (1, 3) and pc.shape == (1, 2)


# ---------------------------------------------------------------------------
# block_compact: fused capacity-bounded row compaction.
@given(
    n=st.sampled_from([512, 2048, 5000, 20000]),  # ragged tails force padding
    sel=st.floats(0.0, 1.0),
    cap_slack=st.floats(0.25, 2.0),  # caps below AND above the true count
)
@settings(max_examples=10, deadline=None)
def test_block_compact_property(n, sel, cap_slack):
    """Kernel == oracle bit-for-bit, including capacity overflow."""
    k = jax.random.fold_in(KEY, n + int(100 * sel))
    cols = jax.random.uniform(k, (4, n), jnp.float32)
    mask = jax.random.uniform(jax.random.fold_in(k, 1), (n,)) < sel
    cap = max(1, int(cap_slack * max(int(jnp.sum(mask)), 8)))
    out, cnt = ops.block_compact(cols, mask, cap, block_n=2048)
    exp, ecnt = ref.block_compact_ref(cols, mask, cap)
    assert int(cnt) == int(ecnt) == int(np.asarray(mask).sum())
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("fill", [0.0, 1.0])
def test_block_compact_degenerate_masks(fill):
    n = 3000
    cols = jax.random.uniform(jax.random.fold_in(KEY, 55), (3, n), jnp.float32)
    mask = jnp.full((n,), bool(fill))
    out, cnt = ops.block_compact(cols, mask, 1024, block_n=1024)
    exp, ecnt = ref.block_compact_ref(cols, mask, 1024)
    assert int(cnt) == int(ecnt) == (n if fill else 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_block_compact_keeps_zero_valued_rows():
    """Zero-valued qualifying rows are data, not padding: they must survive
    compaction at their slot (the pushdown bug this PR fixes assumed
    value != 0 implied validity)."""
    n = 1024
    cols = jnp.stack([jnp.zeros((n,)), jnp.arange(n, dtype=jnp.float32)])
    mask = jnp.arange(n) % 3 == 0
    cap = int(np.asarray(mask).sum()) + 16
    out, cnt = ops.block_compact(cols, mask, cap, block_n=512)
    exp, ecnt = ref.block_compact_ref(cols, mask, cap)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # row 0 qualifies and is all-zero in col 0; it still occupies slot 0
    assert int(cnt) == int(ecnt)
    assert float(out[1, 0]) == 0.0 and float(out[1, 1]) == 3.0


# ---------------------------------------------------------------------------
# block_compact streaming variant: HBM-resident output, double-buffered DMA.
def _stream_case(n, sel, cap, seed, c=4, **kw):
    k = jax.random.fold_in(KEY, seed)
    cols = jax.random.normal(k, (c, n), jnp.float32)
    mask = jax.random.uniform(jax.random.fold_in(k, 1), (1, n)) < sel
    out, cnt = block_compact_stream(
        cols, mask.astype(jnp.int32), cap, interpret=True, **kw
    )
    exp, ecnt = ref.block_compact_ref(cols, mask, cap)
    assert int(cnt) == int(ecnt), (n, sel, cap)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    return cols, mask


from repro.kernels.block_compact import (  # noqa: E402 - grouped with its tests
    SUB,
    block_compact_stream,
    stream_chunk,
    stream_finalize,
    stream_init,
)


def test_stream_matches_oracle_below_and_above_vmem_bound():
    """Bit-for-bit oracle equality on both sides of the resident kernel's
    capacity ceiling (VMEM_BUDGET_BYTES / 16 rows at 4 columns)."""
    bound = ops.VMEM_BUDGET_BYTES // 16
    _stream_case(65536, 0.4, bound // 4, seed=11, block_n=8192)
    _stream_case(65536, 0.4, bound * 2, seed=12, block_n=8192)


def test_stream_runs_at_4m_cap():
    """The acceptance bar: cap >= 4M rows (output far past the 8 MB VMEM
    budget) streams byte-identically to the oracle."""
    cap = 4 * 1024 * 1024
    assert 4 * (cap + SUB) * 4 > ops.VMEM_BUDGET_BYTES
    _stream_case(65536, 0.9, cap, seed=13, block_n=16384)


def test_stream_overflow_clamps_at_cap_boundary():
    """Counts past cap are dropped exactly like nonzero(size=cap): sweep
    caps straddling the qualifying count, including mid-sub-tile caps."""
    n = 16384
    for cap in (100, SUB, SUB + 1, 3 * SUB - 7, 8000):
        _stream_case(n, 0.5, cap, seed=cap, block_n=4096)


def test_stream_ragged_carry_flush():
    """Counts engineered to straddle SUB-tile slots: the carry buffer must
    flush exactly when it fills and the epilogue must place the ragged
    tail at the right offset."""
    n = 8192
    for count in (SUB - 1, SUB, SUB + 1, 2 * SUB - 1, 2 * SUB + 3, 5 * SUB):
        cols = jax.random.normal(jax.random.fold_in(KEY, count), (4, n), jnp.float32)
        mask = (jnp.arange(n) < count).astype(jnp.int32).reshape(1, -1)
        out, cnt = block_compact_stream(cols, mask, 4096, block_n=2048, interpret=True)
        exp, ecnt = ref.block_compact_ref(cols, mask, 4096)
        assert int(cnt) == int(ecnt) == count
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_stream_empty_and_all_pass_blocks():
    """Whole grid blocks with zero qualifiers (no emission at all) and
    all-qualifier blocks (an emission every sub-tile), plus alternating
    full/empty blocks."""
    n = 8192
    _stream_case(n, 0.0, 2048, seed=21, block_n=2048)
    _stream_case(n, 1.0, n, seed=22, block_n=2048)
    cols = jax.random.normal(jax.random.fold_in(KEY, 23), (4, n), jnp.float32)
    mask = ((jnp.arange(n) // 2048) % 2 == 0).astype(jnp.int32).reshape(1, -1)
    out, cnt = block_compact_stream(cols, mask, n, block_n=2048, interpret=True)
    exp, ecnt = ref.block_compact_ref(cols, mask, n)
    assert int(cnt) == int(ecnt) == n // 2
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_stream_chunked_driver_equals_single_call():
    """stream_init/chunk/finalize across 4 chunks == one-shot call == the
    dispatcher's chunked path (chunk_n smaller than the input)."""
    n, cap = 8192, 3000
    k = jax.random.fold_in(KEY, 31)
    cols = jax.random.normal(k, (4, n), jnp.float32)
    mask = (jax.random.uniform(jax.random.fold_in(k, 1), (1, n)) < 0.6).astype(jnp.int32)
    state = stream_init(4, cap)
    for i in range(4):
        sl = slice(i * 2048, (i + 1) * 2048)
        state = stream_chunk(
            state, cols[:, sl], mask[:, sl], cap, block_n=1024, interpret=True
        )
    out_c, cnt_c = stream_finalize(state, cap)
    out_s, cnt_s = block_compact_stream(cols, mask, cap, block_n=1024, interpret=True)
    out_d, cnt_d = ops.block_compact(
        cols, mask, cap, stream="always", chunk_n=2048, block_n=1024
    )
    exp, ecnt = ref.block_compact_ref(cols, mask, cap)
    assert int(cnt_c) == int(cnt_s) == int(cnt_d) == int(ecnt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(exp))
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(exp))
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(exp))


def test_auto_dispatch_streams_past_vmem_budget():
    """stream='auto' routes small caps to the resident kernel and big caps
    to the streaming kernel; both agree with the oracle."""
    n = 4096
    k = jax.random.fold_in(KEY, 41)
    cols = jax.random.normal(k, (4, n), jnp.float32)
    mask = (jax.random.uniform(jax.random.fold_in(k, 1), (1, n)) < 0.5).astype(jnp.int32)
    small = 1024  # resident route
    big = ops.VMEM_BUDGET_BYTES // 16 + SUB  # first cap past the budget
    for cap in (small, big):
        out, cnt = ops.block_compact(cols, mask, cap, block_n=2048)
        exp, ecnt = ref.block_compact_ref(cols, mask, cap)
        assert int(cnt) == int(ecnt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
