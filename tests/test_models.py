"""Per-architecture smoke tests + model invariants.

Every assigned arch instantiates a REDUCED same-family config, runs one
forward/train step on CPU, asserts output shapes and no NaNs; decode is
checked against the teacher-forced forward (exact causality)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell, all_archs, cells_for, get_arch, tiny
from repro.models import transformer as tfm
from repro.models.model import Model, batch_like, input_specs

ARCHS = all_archs()


def _is_axes(v):
    return isinstance(v, tuple) and all(a is None or isinstance(a, str) for a in v)


@pytest.fixture(scope="module")
def tiny_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = tiny(get_arch(arch))
            m = Model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, tiny_models):
    cfg, m, params = tiny_models(arch)
    batch = batch_like(input_specs(cfg, ShapeCell("t", 32, 2, "train")))
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch, tiny_models):
    cfg, m, params = tiny_models(arch)
    cache = m.init_cache(2, 64)
    pb = batch_like(input_specs(cfg, ShapeCell("p", 32, 2, "prefill")))
    logits, cache = m.prefill(params, pb, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits)), arch
    db = batch_like(input_specs(cfg, ShapeCell("d", 32, 2, "decode")))
    logits2, cache = m.decode(params, db, cache, jnp.int32(32))
    assert logits2.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_trees_match(arch, tiny_models):
    cfg, m, _ = tiny_models(arch)
    params = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = m.param_specs()
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        specs, is_leaf=_is_axes
    ), arch
    for p, s in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(specs, is_leaf=_is_axes)
    ):
        assert len(s) == len(p.shape), (arch, p.shape, s)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_spec_trees_match(arch, tiny_models):
    cfg, m, _ = tiny_models(arch)
    cache = m.init_cache(2, 16, abstract=True)
    specs = m.cache_specs()
    leaves_c = jax.tree_util.tree_leaves(
        cache, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    leaves_s = jax.tree_util.tree_leaves(specs, is_leaf=_is_axes)
    assert len(leaves_c) == len(leaves_s), arch
    for c, s in zip(leaves_c, leaves_s):
        assert len(s) == len(c.shape), (arch, c.shape, s)
        assert "batch" in s, (arch, s)


def test_decode_matches_forward_decoder_only():
    """Greedy decode equals teacher-forced forward (causality + cache)."""
    for arch in ("granite-3-8b", "mamba2-2.7b", "jamba-v0.1-52b", "kimi-k2-1t-a32b"):
        cfg = tiny(get_arch(arch))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(1))
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab_size)
        pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, 2, 9))
        full, _, _ = tfm.forward(cfg, params, toks, pos)
        cache = m.init_cache(2, 16)
        _, cache = m.prefill(params, {"inputs": toks[:, :8]}, cache)
        lg, _ = m.decode(params, {"tokens": toks[:, 8:9]}, cache, jnp.int32(8))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, 8]), rtol=5e-2, atol=5e-2,
        )


def test_per_slot_decode_index():
    """Vector cache_index (continuous batching): each slot decodes at its own
    position and matches the scalar-index path."""
    cfg = tiny(get_arch("granite-3-8b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    cache = m.init_cache(2, 16)
    _, cache = m.prefill(params, {"inputs": toks}, cache)
    # scalar path
    lg_s, _ = m.decode(params, {"tokens": toks[:, :1]}, cache, jnp.int32(6))
    # vector path, equal indices
    lg_v, _ = m.decode(params, {"tokens": toks[:, :1]}, cache, jnp.array([6, 6], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v), rtol=1e-5, atol=1e-5)


def test_causality_future_tokens_do_not_matter():
    cfg = tiny(get_arch("olmo-1b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    t2 = t1.at[:, 8:].set((t1[:, 8:] + 7) % cfg.vocab_size)
    pos = jnp.arange(12)[None]
    l1, _, _ = tfm.forward(cfg, params, t1, pos)
    l2, _, _ = tfm.forward(cfg, params, t2, pos)
    np.testing.assert_allclose(
        np.asarray(l1[:, :8]), np.asarray(l2[:, :8]), rtol=1e-4, atol=1e-4
    )


def test_cells_for_applicability():
    # full-attention archs skip long_500k; ssm/hybrid run it
    assert "long_500k" not in cells_for(get_arch("granite-3-8b"))
    assert "long_500k" not in cells_for(get_arch("qwen2-vl-72b"))
    assert "long_500k" in cells_for(get_arch("mamba2-2.7b"))
    assert "long_500k" in cells_for(get_arch("jamba-v0.1-52b"))
    total = sum(len(cells_for(get_arch(a))) for a in ARCHS)
    assert total == 32  # 10 archs x 3 + 2 long-context


def test_n_params_against_published():
    published = {
        "olmo-1b": 1.18e9, "granite-3-8b": 8.2e9, "internlm2-20b": 19.9e9,
        "mistral-nemo-12b": 12.2e9, "grok-1-314b": 314e9, "kimi-k2-1t-a32b": 1.04e12,
        "jamba-v0.1-52b": 52e9, "mamba2-2.7b": 2.7e9, "qwen2-vl-72b": 72e9,
    }
    for arch, expect in published.items():
        got = get_arch(arch).n_params()
        assert 0.7 * expect < got < 1.35 * expect, (arch, got, expect)


def test_moe_active_params():
    kimi = get_arch("kimi-k2-1t-a32b")
    active = kimi.n_active_params()
    assert 2.0e10 < active < 4.5e10, active  # ~32B active
    dense = get_arch("granite-3-8b")
    assert dense.n_active_params() == dense.n_params()


def test_moe_grouped_dispatch_matches_flat():
    """moe_groups>1 must not change routed outputs when capacity is ample
    (per-group routing only changes drop behaviour, which ample cap removes)."""
    import dataclasses

    from repro.models import moe

    cfg = dataclasses.replace(tiny(get_arch("kimi-k2-1t-a32b")), capacity_factor=8.0)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y1, _ = moe.apply_moe(cfg, p, x)
    y2, _ = moe.apply_moe(dataclasses.replace(cfg, moe_groups=2), p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5, atol=2e-5)


def test_cross_entropy_gradient_is_softmax_minus_onehot():
    """Regression: stop_gradient must cover BOTH uses of the max-shift, or
    an extra onehot(argmax) leaks into every training gradient."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (2, 4, 8))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (2, 4), 0, 8)

    g = jax.grad(lambda lg: tfm.softmax_cross_entropy(lg, labels))(logits)
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, 8)
    expect = (p - onehot) / (2 * 4)  # mean over tokens
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_chunked_ce_matches_dense():
    """ce_vocab_chunk path == dense path for loss and all parameter grads."""
    import dataclasses

    cfg = tiny(get_arch("olmo-1b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = batch_like(input_specs(cfg, ShapeCell("t", 16, 2, "train")))
    m2 = Model(dataclasses.replace(cfg, ce_vocab_chunk=64))
    l1, _ = m.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-6)
