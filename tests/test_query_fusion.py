"""Fused query plans vs the unfused engine: the q*_fused variants and the
pushdown compaction plans must agree with their jnp counterparts.

Counts and integer-valued aggregates (Q12's conditional counts, Q1's group
counts and quantity sums) must match EXACTLY — they are integer sums, which
f32 accumulates without rounding at these magnitudes.  Float product-sums
agree to accumulation-order tolerance (blocked kernel accumulation vs
segment_sum ordering).
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.engine import datagen, ops, queries

KEY = jax.random.PRNGKey(21)
SUM_TOL = dict(rtol=2e-5, atol=1e-3)


@pytest.fixture(scope="module")
def li():
    return datagen.lineitem(KEY, rows=20_000)


@pytest.fixture(scope="module")
def od():
    return datagen.orders(KEY, rows=5_000)


# -- fused DBMS queries -------------------------------------------------------
def test_q1_fused_equals_q1(li):
    ref = jax.jit(queries.q1)(li)
    fused = jax.jit(queries.q1_fused)(li)
    assert set(ref) == set(fused)
    # integer-valued aggregates: exact
    np.testing.assert_array_equal(np.asarray(ref["count"]), np.asarray(fused["count"]))
    np.testing.assert_array_equal(np.asarray(ref["sum_qty"]), np.asarray(fused["sum_qty"]))
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(fused[k]), **SUM_TOL)


def test_q1_fused_zero_delta_includes_all_rows(li):
    """delta_days far in the past: the <= cutoff predicate passes every row
    (group counts must sum to the table), exercising the all-pass path."""
    fused = jax.jit(lambda t: queries.q1_fused(t, delta_days=-10_000.0))(li)
    assert int(np.asarray(fused["count"]).sum()) == li.num_rows


def test_q6_fused_equals_q6(li):
    ref = jax.jit(queries.q6)(li)
    fused = jax.jit(queries.q6_fused)(li)
    # unlike q6_columns+filter_agg, the general program expresses ALL THREE
    # predicates, so the row count matches exactly too
    assert int(ref["rows"]) == int(fused["rows"])
    np.testing.assert_allclose(float(ref["revenue"]), float(fused["revenue"]), rtol=2e-5)


def test_q12_fused_equals_q12_exactly(li, od):
    ref = jax.jit(queries.q12)(li, od)
    fused = jax.jit(queries.q12_fused)(li, od)
    assert set(ref) == set(fused)
    for k in ref:  # conditional counts are integer sums: bit-for-bit
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(fused[k]))


def test_fused_escape_hatch_matches_kernel(li, od):
    """use_pallas=False (the ref-oracle route) returns the same results the
    kernel route does — one code path for CPU smoke and TPU runs."""
    a = jax.jit(lambda t: queries.q1_fused(t, use_pallas=False))(li)
    b = jax.jit(queries.q1_fused)(li)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]), **SUM_TOL)
    c = jax.jit(lambda t, o: queries.q12_fused(t, o, use_pallas=False))(li, od)
    d = jax.jit(queries.q12_fused)(li, od)
    for k in c:
        np.testing.assert_array_equal(np.asarray(c[k]), np.asarray(d[k]))


def test_dbms_task_runs_fused_impl():
    from repro.core.registry import get
    from repro.core.task import TaskContext

    task = get("dbms")
    ctx = TaskContext(iters=1, warmup=0)
    task.prepare(ctx)
    try:
        for impl in ("unfused", "fused"):
            s = task.run(
                ctx, {"scale": "0.001", "query": "q1", "mode": "hot", "impl": impl}
            )
            assert s.times_s and s.items_per_iter == 6_000
    finally:
        task.clean(ctx)


# -- pushdown compaction plans ------------------------------------------------
def test_compact_kernel_route_matches_jnp(li):
    scanned = li.select("l_shipdate", "l_extendedprice", "l_discount", "l_quantity")
    mask = ops.pred_between(scanned["l_shipdate"], 8035.0, 8035.0 + 800.0)
    cap = int(np.asarray(mask).sum()) + 100
    out_j, cnt_j = ops.compact(scanned, mask, cap)
    out_k, cnt_k = ops.compact(scanned, mask, cap, use_pallas=True)
    assert int(cnt_j) == int(cnt_k)
    for name in scanned.names:
        np.testing.assert_array_equal(np.asarray(out_j[name]), np.asarray(out_k[name]))


def test_pushdown_plans_agree_at_every_param_point():
    """baseline / pushdown(jnp) / pushdown(kernel) / pushdown_kernel report
    the same qualifying-row count (and consistent sums) at every
    (scale, selectivity) point of the task's param_space."""
    from repro.kernels import ops as kops
    from repro.tasks.pushdown import (
        _SCALES,
        PushdownTask,
        _pred_bounds,
        kernel_scan_columns,
    )

    task = PushdownTask()
    sels = task.param_space["selectivity"]
    key = jax.random.PRNGKey(7)
    for scale, rows in _SCALES.items():
        table = datagen.lineitem(key, rows=rows)
        scanned = table.select(
            "l_shipdate", "l_extendedprice", "l_discount", "l_quantity"
        )
        for sel in sels:
            lo, hi = _pred_bounds(sel)
            cap = max(1024, int(1.5 * sel * rows))
            mask = ops.pred_between(scanned["l_shipdate"], lo, hi)
            baseline_cnt = int(ops.masked_count(mask))
            baseline_sum = float(ops.masked_sum(scanned["l_extendedprice"], mask))

            out_j, cnt_j = ops.compact(scanned, mask, cap)
            out_k, cnt_k = ops.compact(scanned, mask, cap, use_pallas=True)
            assert int(cnt_j) == int(cnt_k) == baseline_cnt, (scale, sel)
            for name in scanned.names:
                np.testing.assert_array_equal(
                    np.asarray(out_j[name]), np.asarray(out_k[name])
                )

            # the fully-fused plan's count agrees too (its aggregate matches
            # to accumulation-order tolerance)
            agg = kops.filter_agg(kernel_scan_columns(table), lo, hi, -1.0, 1.0)
            assert int(agg[1]) == baseline_cnt, (scale, sel)
            np.testing.assert_allclose(float(agg[0]), baseline_sum, rtol=2e-5)


# -- min-time measurement floor ----------------------------------------------
def test_dbms_hot_mode_honors_min_time():
    """min_time_s keeps sampling past `iters` until enough wall time has
    accumulated — microsecond-scale points stop being 1-sample noise."""
    from repro.core.registry import get
    from repro.core.task import TaskContext

    task = get("dbms")
    # warmup=1 so compile lands outside the timed samples: every measured
    # iteration is then a genuine hot-path microsecond-scale run.
    ctx = TaskContext(iters=1, warmup=1, min_time_s=0.05)
    key = jax.random.PRNGKey(3)
    ctx.scratch["li_0.001"] = datagen.lineitem(key, rows=6_000)
    ctx.scratch["od_0.001"] = datagen.orders(key, rows=1_500)
    s = task.run(ctx, {"scale": "0.001", "query": "q6", "mode": "hot", "impl": "unfused"})
    assert sum(s.times_s) >= 0.05
    assert len(s.times_s) > 1  # a hot q6 at 6k rows is far under 50 ms


def test_min_time_is_part_of_cache_identity():
    from repro.core.cache import cache_key

    base = dict(task="dbms", params={"q": 1}, platform={"name": "p"},
                iters=1, warmup=0, metrics=("items_per_s",))
    k0 = cache_key(**base)
    assert cache_key(**base, min_time_s=0.0) == k0  # unset: legacy keys intact
    assert cache_key(**base, min_time_s=0.5) != k0


def test_pushdown_task_kernel_impl_runs():
    from repro.core.registry import get
    from repro.core.task import TaskContext

    task = get("pushdown")
    ctx = TaskContext(iters=1, warmup=0)
    key = jax.random.PRNGKey(7)
    # prepare() builds all scales including 6M rows; keep this test light
    ctx.scratch["0.01"] = datagen.lineitem(key, rows=60_000)
    for impl in ("jnp", "kernel"):
        s = task.run(
            ctx,
            {"scale": "0.01", "selectivity": 0.1, "plan": "pushdown", "impl": impl},
        )
        assert s.times_s and s.extra["moved_bytes"] > 0
