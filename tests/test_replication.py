"""Replicated membership plane conformance: quorum, sync, failover, chaos.

The control plane's contract, per layer:

  1. *Merge laws* — anti-entropy records are last-beat-wins per worker,
     relative-age encoded (no clock agreement between replicas), junk and
     already-dead records are skipped, and two synced replicas answer
     ``fleet`` **byte-identically** under a shared clock.
  2. *Warm-up* — a restarted replica refuses ``fleet`` (consumers treat it
     as unreachable and merge the others) until a sync with a ready peer
     lands or a full suspect window passes; transitions are clock-driven.
  3. *Fan-out* — workers beat every replica; a replica outage never kills
     the beat daemon, and beats resume (re-registering) on recovery.
  4. *Failover* — ``fleet_view`` merges whatever subset of replicas answers
     in one concurrent wave; the FleetWatcher keeps its last view over a
     fully dark plane and counts the dark polls into ``SweepStats``.
  5. *Restart under fire* — a threaded hammer beats N workers through a
     kill+restart cycle: the merged view must never flap through
     ``suspect`` and must re-converge to all-alive.
"""
from __future__ import annotations

import json
import threading
import time

import pytest
from test_fleet import FakeClock, _instant_sink
from test_shard import make_plugin, plugin_box

from repro.core import config as config_mod
from repro.core import registry as reg
from repro.core import remote as remote_mod
from repro.core.aiotransport import get_async_transport
from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor
from repro.core.faults import FaultSpec, RegistryChaos, RegistryReplicas
from repro.core.remote import (
    LocalWorker,
    RemoteExecutionError,
    WorkerServer,
    fleet_view,
    merge_member_rows,
    wait_members,
)
from repro.core.scheduler import FleetScheduler
from repro.runtime.elastic import DARK_POLLS_WARN, FleetWatcher
from repro.runtime.membership import (
    MembershipServer,
    ReplicatedRegistry,
)


def _replica(clock=None, peers=(), warmup=False, interval=1.0):
    kwargs = {"heartbeat_interval_s": interval}
    if clock is not None:
        kwargs["now"] = clock
    return ReplicatedRegistry(peers=peers, warmup=warmup, **kwargs)


# -- 1. merge laws ------------------------------------------------------------
def test_merge_adopts_strictly_fresher_records_only():
    clock = FakeClock()
    r = _replica(clock)
    r.register("w:7001", capacity=1)
    clock.t += 5.0
    # Peer heard the worker 1s ago (fresher than our 5s-old evidence).
    adopted = r.merge_records(
        [{"endpoint": "w:7001", "age_s": 1.0, "beats": 9, "capacity": 4}]
    )
    assert adopted == 1
    m = r.members()[0]
    assert (m["age_s"], m["beats"], m["capacity"]) == (1.0, 9, 4)
    # A staler record (or an equally fresh one) never overwrites.
    assert r.merge_records([{"endpoint": "w:7001", "age_s": 3.0, "beats": 99}]) == 0
    assert r.merge_records([{"endpoint": "w:7001", "age_s": 1.0, "beats": 99}]) == 0
    assert r.members()[0]["beats"] == 9


def test_merge_skips_dead_and_junk_records():
    clock = FakeClock()
    r = _replica(clock)  # dead bound = 10 beats x 1s
    assert r.merge_records(
        [
            {"endpoint": "w:7001", "age_s": 11.0},  # sender would prune this
            {"endpoint": "not-an-endpoint"},  # junk endpoint
            {"endpoint": "w:7002", "age_s": "wat"},  # junk age
            {},  # no endpoint at all
        ]
    ) == 0
    assert r.members() == []


def test_synced_replicas_answer_fleet_byte_identically_over_the_wire():
    """Acceptance: one shared (injected) clock, real wire sync — the two
    replicas' ``fleet`` payloads must be byte-equal, ages included."""
    clock = FakeClock()
    a_srv = MembershipServer("127.0.0.1", 0, registry=_replica(clock))
    b_srv = MembershipServer("127.0.0.1", 0, registry=_replica(clock))
    a_srv.registry.peers = [b_srv.endpoint]
    b_srv.registry.peers = [a_srv.endpoint]
    # Serve WITHOUT the background sync daemon: the test drives sync_once()
    # itself so the merge round is deterministic.
    ta = threading.Thread(target=a_srv.serve_forever, daemon=True)
    tb = threading.Thread(target=b_srv.serve_forever, daemon=True)
    ta.start()
    tb.start()
    try:
        remote_mod.register(a_srv.endpoint, "10.0.0.1:7177", capacity=2)
        clock.t += 0.5
        remote_mod.heartbeat(a_srv.endpoint, "10.0.0.1:7177", capacity=2)
        assert a_srv.registry.sync_once() >= 0  # push-pull: b pulls our table
        fa = json.dumps(remote_mod.fleet_members(a_srv.endpoint), sort_keys=True)
        fb = json.dumps(remote_mod.fleet_members(b_srv.endpoint), sort_keys=True)
        assert fa == fb
        assert json.loads(fa)[0]["endpoint"] == "10.0.0.1:7177"
    finally:
        for srv in (a_srv, b_srv):
            srv.shutdown()
            srv.server_close()


def test_restarted_replica_converges_in_one_sync_round():
    clock = FakeClock()
    a = _replica(clock)
    a.register("w:7001", capacity=3)
    a.heartbeat("w:7001")
    # The restarted peer starts empty; one merge of a's export converges it.
    b = _replica(clock, peers=["unused:1"], warmup=True)
    assert not b.ready  # warming up, no sync yet
    assert b.merge_records(a.export_records()) == 1
    assert [
        (m["endpoint"], m["capacity"], m["beats"]) for m in b.members()
    ] == [("w:7001", 3, 1)]
    # members() are identical under the shared clock
    assert a.members() == b.members()


def test_failure_detector_transitions_stay_clock_driven_after_merge():
    """A merged record obeys the SAME alive/suspect/dead bounds as a
    directly-registered one — replication must not skew detection."""
    clock = FakeClock()
    a = _replica(clock)
    b = _replica(clock)
    a.register("w:7001")
    b.merge_records(a.export_records())
    for bump, status in ((3.0, "alive"), (0.5, "suspect")):
        clock.t += bump
        assert [m["status"] for m in a.members()] == [status]
        assert a.members() == b.members()
    clock.t += 7.0  # past dead_beats x interval: pruned everywhere
    assert a.members() == b.members() == []


# -- 2. warm-up gating --------------------------------------------------------
def test_warming_replica_refuses_fleet_until_peer_sync_or_window():
    clock = FakeClock()
    r = _replica(clock, peers=["unused:1"], warmup=True, interval=1.0)
    assert r.handle({"op": "fleet"})["ok"] is False  # gated
    # register/heartbeat/sync are always served during warmup
    assert r.handle({"op": "register", "endpoint": "w:7001"})["ok"] is True
    assert r.handle({"op": "heartbeat", "endpoint": "w:7001"})["ok"] is True
    # a sync FROM a ready peer opens the gate immediately
    assert r.handle({"op": "sync", "workers": [], "ready": True})["ok"] is True
    assert r.handle({"op": "fleet"})["ok"] is True


def test_warming_replica_opens_after_a_full_suspect_window():
    clock = FakeClock()
    r = _replica(clock, peers=["unused:1"], warmup=True, interval=1.0)
    assert not r.ready
    clock.t += 3.0  # suspect_beats x interval: every live worker has beaten us
    assert r.ready
    assert r.handle({"op": "fleet"})["ok"] is True


# -- merged-view client helpers ----------------------------------------------
def test_merge_member_rows_keeps_freshest_row_per_endpoint():
    merged = merge_member_rows(
        [
            [{"endpoint": "w:7001", "age_s": 2.0, "beats": 5, "status": "suspect"}],
            [{"endpoint": "w:7001", "age_s": 0.1, "beats": 7, "status": "alive"},
             {"endpoint": "w:7002", "age_s": 0.2, "beats": 1, "status": "alive"}],
        ]
    )
    assert [(m["endpoint"], m["status"]) for m in merged] == [
        ("w:7001", "alive"),
        ("w:7002", "alive"),
    ]
    # age tie -> larger beat count wins (re-admitted record has fewer)
    merged = merge_member_rows(
        [
            [{"endpoint": "w:7001", "age_s": 1.0, "beats": 2}],
            [{"endpoint": "w:7001", "age_s": 1.0, "beats": 8}],
        ]
    )
    assert merged[0]["beats"] == 8


def test_fleet_view_merges_answering_replicas_and_reports_who_answered():
    with RegistryReplicas(2, heartbeat_interval_s=0.5) as plane:
        remote_mod.register(plane.endpoints[0], "10.0.0.1:7177")
        remote_mod.register(plane.endpoints[1], "10.0.0.2:7177")
        members, answered = fleet_view(plane.register)
        assert answered == plane.endpoints
        assert [m["endpoint"] for m in members] == ["10.0.0.1:7177", "10.0.0.2:7177"]
        # one replica down: same merged view from the survivor + sync'd state
        plane.kill(0)
        members, answered = fleet_view(plane.register)
        assert answered == [plane.endpoints[1]]
        assert "10.0.0.2:7177" in [m["endpoint"] for m in members]
    assert fleet_view([]) == ([], [])


def test_request_many_settles_every_slot_in_order():
    srv = MembershipServer("127.0.0.1", 0)
    srv.serve_in_thread()
    try:
        results = get_async_transport().request_many(
            [
                (srv.endpoint, {"op": "ping"}),
                ("not an endpoint", {"op": "ping"}),  # sync submit error
                ("127.0.0.1:1", {"op": "ping"}),  # nothing listens
            ],
            timeout=5.0,
        )
        assert results[0][0]["ok"] is True and results[0][1] is None
        assert results[1][0] is None and isinstance(results[1][1], ValueError)
        assert results[2][0] is None and isinstance(results[2][1], Exception)
    finally:
        srv.shutdown()
        srv.server_close()


def test_wait_members_required_reports_the_partial_view():
    with RegistryReplicas(2, heartbeat_interval_s=0.5) as plane:
        remote_mod.register(plane.endpoints[0], "10.0.0.1:7177")
        dark = "127.0.0.1:1"
        with pytest.raises(RemoteExecutionError) as err:
            wait_members(
                plane.register + "," + dark, count=3, timeout=0.5, required=True
            )
    msg = str(err.value)
    assert "needed 3 alive worker(s), saw 1" in msg
    assert "10.0.0.1:7177" in msg
    assert "replicas answered: 2/3" in msg
    assert f"silent replicas: {dark}" in msg


# -- 3. worker heartbeat fan-out ----------------------------------------------
def test_worker_beats_every_replica_and_survives_an_outage():
    """Satellite bugfix: the beat daemon must outlive a registry outage and
    resume (re-registering) when the replica returns."""
    with RegistryReplicas(2, heartbeat_interval_s=0.1) as plane:
        w = WorkerServer(
            "127.0.0.1", 0, capacity=2, register=plane.register,
            heartbeat_interval_s=0.1,
        )
        w.serve_in_thread()
        hb = w.start_heartbeat()
        try:
            # both replicas hear the worker directly (not only via sync)
            for ep in plane.endpoints:
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline:
                    rows = plane.servers[plane.endpoints.index(ep)].registry.members()
                    if any(r["endpoint"] == w.endpoint and r["beats"] >= 2 for r in rows):
                        break
                    time.sleep(0.05)
                else:
                    pytest.fail(f"replica {ep} never heard 2 beats directly")
            # kill replica 0: the daemon must keep beating replica 1
            plane.kill(0)
            time.sleep(0.5)
            assert hb.is_alive(), "heartbeat daemon died on a registry outage"
            alive, answered = fleet_view(plane.register)
            assert [m["endpoint"] for m in alive] == [w.endpoint]
            # restart replica 0 EMPTY: the worker must re-register into it
            plane.restart(0)
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                if any(
                    r["endpoint"] == w.endpoint
                    for r in plane.servers[0].registry.members()
                ):
                    break
                time.sleep(0.05)
            else:
                pytest.fail("worker never re-registered with the restarted replica")
            assert hb.is_alive()
        finally:
            w.shutdown()
            w.server_close()


# -- 4. consumer failover -----------------------------------------------------
def test_fleet_watcher_fails_over_within_one_tick():
    # Long beat interval (the fake workers never beat — keep them 'alive'
    # throughout), fast anti-entropy (the property under test is that the
    # records reach replica 1 via sync alone before replica 0 dies).
    with RegistryReplicas(2, heartbeat_interval_s=5.0, sync_interval_s=0.3) as plane:
        remote_mod.register(plane.endpoints[0], "127.0.0.1:7601")
        sched = FleetScheduler([_instant_sink("127.0.0.1:7601")], poll_s=0.01)
        watcher = FleetWatcher(plane.register, sched, make_sink=_instant_sink)
        # a second worker registers at replica 0 only; anti-entropy fans it
        remote_mod.register(plane.endpoints[0], "127.0.0.1:7602")
        time.sleep(1.2)
        # replica 0 — the only one that heard the registrations directly —
        # dies: the SAME tick's wave has replica 1's synced answer.
        plane.kill(0)
        watcher.poll_once()
        assert watcher.joined == ["127.0.0.1:7602"]
        assert watcher.left == []
        assert watcher.poll_failures == 0
        assert set(sched.live_sinks()) == {"127.0.0.1:7601", "127.0.0.1:7602"}


def test_fleet_watcher_counts_dark_polls_and_keeps_last_view(caplog):
    sched = FleetScheduler([_instant_sink("127.0.0.1:7601")], poll_s=0.01)
    watcher = FleetWatcher("127.0.0.1:1,127.0.0.1:2", sched, make_sink=_instant_sink)
    with caplog.at_level("WARNING", logger="repro.runtime.elastic"):
        for _ in range(DARK_POLLS_WARN + 1):
            watcher.poll_once()
    assert watcher.poll_failures == DARK_POLLS_WARN + 1
    assert watcher.dark_polls == DARK_POLLS_WARN + 1
    assert sched.live_sinks() == ["127.0.0.1:7601"]  # view kept, no flapping
    darks = [r for r in caplog.records if "registry dark" in r.getMessage()]
    assert len(darks) == 1  # one warning per dark spell, not one per tick


def test_sweep_stats_expose_registry_poll_failures(tmp_path):
    d = make_plugin(tmp_path, "rpf", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("rpf")
    with RegistryReplicas(2, heartbeat_interval_s=0.2) as plane:
        with LocalWorker(
            plugin_dirs=[d], register=plane.register, heartbeat_interval_s=0.2
        ):
            wait_members(plane.register, count=1, timeout=30)
            ex = SweepExecutor(
                platforms=["cpu-host"], workers=2, iters=1, warmup=0,
                fleet_registry=plane.register,
                cache=ResultCache(tmp_path / "cache.json"),
            )
            res = ex.run_box(box)
            assert res.stats.errors == 0
            assert res.stats.registry_poll_failures == 0
    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    assert res.csv() == baseline.csv()


def test_registry_ckey_is_stable_across_replica_order_and_failover():
    a = SweepExecutor(platforms=["cpu-host"], fleet_registry="h2:7170,h1:7170")
    b = SweepExecutor(platforms=["cpu-host"], fleet_registry="h1:7170,h2:7170")
    assert a._fleet_identity() == b._fleet_identity() == "registry://h1:7170,h2:7170"


def test_config_validates_registry_replica_lists():
    errors: list[str] = []
    cfg = config_mod.SweepConfig(registry="h1:7170,h2:7170")
    config_mod.validate_sweep(cfg, errors.append, ping_remote=False)
    assert errors == []
    cfg = config_mod.SweepConfig(registry="h1:7170,nope")
    config_mod.validate_sweep(cfg, errors.append, ping_remote=False)
    assert errors and "nope" in errors[0]


# -- 5. chaos harness + restart under fire ------------------------------------
def test_registry_fault_modes_are_known_to_faultspec_but_not_workers():
    FaultSpec("registry-kill")
    FaultSpec("registry-partition")
    with pytest.raises(ValueError):
        FaultSpec("registry-wat")
    # workers reject control-plane modes: they are harness-side only
    w = WorkerServer("127.0.0.1", 0, allow_faults=True)
    try:
        resp = w.dispatch({"op": "fault", "mode": "registry-kill"})
        assert resp["ok"] is False
    finally:
        w.server_close()


def test_partitioned_replica_heals_with_stale_state_reconciled():
    with RegistryReplicas(2, heartbeat_interval_s=0.2) as plane:
        remote_mod.register(plane.endpoints[0], "10.0.0.1:7177", capacity=1)
        time.sleep(0.5)  # replicate
        plane.partition(1)
        # while 1 is away, the worker's state advances on 0
        for _ in range(3):
            remote_mod.heartbeat(plane.endpoints[0], "10.0.0.1:7177", capacity=5)
            time.sleep(0.05)
        plane.heal(1)
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            rows = plane.servers[1].registry.members()
            row = next((r for r in rows if r["endpoint"] == "10.0.0.1:7177"), None)
            if row is not None and row["capacity"] == 5:
                break  # stale parked record was overwritten by the merge
            time.sleep(0.05)
        else:
            pytest.fail("healed replica kept its stale pre-partition record")


def test_registry_chaos_repairs_everything_on_stop():
    with RegistryReplicas(3, heartbeat_interval_s=0.2) as plane:
        chaos = RegistryChaos(plane, seed=11, max_sleep_s=0.3, min_up=1)
        chaos.start(period_s=0.05)
        time.sleep(1.0)
        events = chaos.stop()
        assert plane.up() == [0, 1, 2]
        assert events, "seeded chaos injected nothing in 1s"
        assert {e.spec.mode for e in events} <= {"registry-kill", "registry-partition"}


def test_hammer_replica_restart_under_concurrent_heartbeats():
    """Satellite: N fake workers beat concurrently while a replica is killed
    and restarted — the merged view must re-converge with NO worker ever
    flapping through ``suspect``."""
    n_workers = 4
    interval = 0.25
    endpoints = [f"127.0.0.1:{7700 + i}" for i in range(n_workers)]
    flapped: list[tuple[str, str]] = []
    stop = threading.Event()
    with RegistryReplicas(3, heartbeat_interval_s=interval) as plane:
        def beat(worker_ep: str) -> None:
            while not stop.is_set():
                for replica in plane.endpoints:
                    try:
                        remote_mod.heartbeat(replica, worker_ep, timeout=2.0)
                    except RemoteExecutionError:
                        pass  # downed replica: best effort, like the daemon
                stop.wait(0.1)

        def watch() -> None:
            while not stop.is_set():
                members, answered = fleet_view(plane.register, timeout=2.0)
                if answered:
                    for m in members:
                        if m["endpoint"] in endpoints and m["status"] != "alive":
                            flapped.append((m["endpoint"], m["status"]))
                stop.wait(0.05)

        threads = [
            threading.Thread(target=beat, args=(ep,), daemon=True) for ep in endpoints
        ] + [threading.Thread(target=watch, daemon=True)]
        for t in threads:
            t.start()
        try:
            wait_members(plane.register, count=n_workers, timeout=30, required=True)
            plane.kill(0)
            time.sleep(3 * interval)  # a full suspect window with 0 down
            plane.restart(0)
            time.sleep(3 * interval)  # warmup + re-admission window
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=5.0)
        assert flapped == [], f"merged view flapped: {flapped[:5]}"
        members, answered = fleet_view(plane.register)
        assert len(answered) == 3
        assert sorted(m["endpoint"] for m in members if m["status"] == "alive") == sorted(
            endpoints
        )
        # the restarted replica itself converged (directly or via sync)
        assert sorted(
            r["endpoint"] for r in plane.servers[0].registry.members()
        ) == sorted(endpoints)
