"""Roofline report loader + shipped box files parse and validate."""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.core.box import Box
from repro.core.registry import get as get_task
from repro.launch.report import _CELL_ORDER, load_rows, to_csv, to_markdown

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module", autouse=True)
def dryrun_fixtures():
    """Generate missing dryrun JSONs analytically (no compile, no artifacts).

    Real dry-run output, when present, is never overwritten — the loader
    tests then validate the genuine measurements instead.
    """
    from repro.launch.synth import ensure_dryrun_fixtures

    ensure_dryrun_fixtures(REPO / "results" / "dryrun", "pod")


def test_shipped_boxes_parse_and_validate():
    box_files = sorted((REPO / "boxes").glob("*.json"))
    assert box_files, "boxes/ should ship ready-to-run measurement boxes"
    for bf in box_files:
        box = Box.load(bf)
        assert box.total_tests() > 0
        for spec in box.tasks:
            task = get_task(spec.task)  # raises on unknown task
            task.validate_params(spec.params)  # raises on unknown param


def test_report_loads_dryrun_results():
    rows = load_rows(REPO / "results" / "dryrun", mesh="pod")
    assert len(rows) >= 32  # full baseline table (+ perf variants)
    base = [r for r in rows if r["profile"] == "base"]
    assert len(base) == 32
    for r in base:
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["mfu_bound"] >= 0
        assert r["cell"] in _CELL_ORDER
    md = to_markdown(base)
    assert md.count("\n") == len(base) + 1  # header + separator + rows
    csv = to_csv(base)
    assert csv.splitlines()[0].startswith("arch,")


def test_dryrun_jsons_have_roofline_terms():
    sample = REPO / "results" / "dryrun" / "pod" / "olmo-1b" / "train_4k.json"
    d = json.loads(sample.read_text())
    r = d["roofline"]
    assert r["compute_s"] > 0 and r["bytes_per_device"] > 0
    assert d["n_chips"] == 256
    assert "all-reduce" in r["collectives"] or "all-gather" in r["collectives"]
