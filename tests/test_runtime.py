"""Fault-tolerant runtime: checkpoint/restart, failure injection, straggler
monitoring, elastic re-meshing, and the serving loop's batching invariants."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs.base import get_arch, tiny
from repro.data.pipeline import for_model
from repro.models.model import Model
from repro.runtime import elastic
from repro.runtime.serve_loop import Request, SlotServer
from repro.runtime.train_loop import (
    SimulatedFailure,
    StragglerMonitor,
    TrainConfig,
    run_with_restarts,
    train,
)


@pytest.fixture(scope="module")
def tiny_olmo():
    cfg = tiny(get_arch("olmo-1b"), vocab_size=128)
    return Model(cfg)


# -- checkpointing -------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(tmp_path, 7, tree)
    like = jax.eval_shape(lambda: tree)
    got, step = ckpt.restore(tmp_path, like=like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), np.asarray(tree["b"]["c"]))
    assert got["b"]["c"].dtype == jnp.int32


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (10, 20, 30, 40):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.committed_steps(tmp_path) == [30, 40]
    assert ckpt.latest_step(tmp_path) == 40


def test_checkpoint_crash_mid_save_invisible(tmp_path):
    """A stale .tmp staging dir (simulated crash) is never listed as committed."""
    tree = {"x": jnp.zeros((2,))}
    ckpt.save(tmp_path, 5, tree)
    stage = tmp_path / "step_00000009.tmp-999-123"
    stage.mkdir()
    (stage / "partial.npy").write_bytes(b"junk")
    assert ckpt.latest_step(tmp_path) == 5
    got, step = ckpt.restore(tmp_path, like=jax.eval_shape(lambda: tree))
    assert step == 5


def test_async_checkpointer_commits(tmp_path):
    w = ckpt.AsyncCheckpointer(tmp_path, keep=3)
    w.save(3, {"x": jnp.full((4,), 3.0)})
    w.wait()
    assert w.last_committed == 3
    got, _ = ckpt.restore(tmp_path, like=jax.eval_shape(lambda: {"x": jnp.zeros((4,))}))
    np.testing.assert_allclose(np.asarray(got["x"]), 3.0)


def test_restore_rejects_wrong_template(tmp_path):
    ckpt.save(tmp_path, 1, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(tmp_path, like=jax.eval_shape(lambda: {"a": jnp.zeros((2,))}))


# -- train loop ----------------------------------------------------------------
def test_train_decreases_loss_and_checkpoints(tmp_path, tiny_olmo):
    data = for_model(tiny_olmo.cfg, seq_len=32, global_batch=4)
    tc = TrainConfig(steps=30, ckpt_every=10, ckpt_dir=str(tmp_path), lr=2e-3,
                     warmup_steps=5)
    res = train(tiny_olmo, data, tc)
    assert res.final_step == 30
    assert res.losses[-1] < res.losses[0]
    assert ckpt.latest_step(tmp_path) == 30


def test_failure_injection_and_restart(tmp_path, tiny_olmo):
    data = for_model(tiny_olmo.cfg, seq_len=32, global_batch=4)
    tc = TrainConfig(steps=24, ckpt_every=8, ckpt_dir=str(tmp_path), lr=1e-3,
                     warmup_steps=4, failure_at=13)
    res = run_with_restarts(tiny_olmo, data, tc)
    assert res.restarts == 1
    assert res.final_step == 24
    # the restart resumed from the last committed step (8), not from scratch
    assert res.restored_from == 8
    assert ckpt.latest_step(tmp_path) == 24


def test_unrecoverable_failure_raises(tmp_path, tiny_olmo):
    data = for_model(tiny_olmo.cfg, seq_len=32, global_batch=4)
    tc = TrainConfig(steps=10, ckpt_every=100, ckpt_dir=str(tmp_path), failure_at=0)
    with pytest.raises(SimulatedFailure):
        # failing at step 0 of every attempt exhausts restarts only if the
        # failure persists; run_with_restarts clears failure_at after the
        # first retry, so this must SUCCEED after exactly one restart.
        res = run_with_restarts(tiny_olmo, data, tc, max_restarts=0)


def test_straggler_monitor_counts():
    hits = []
    mon = StragglerMonitor(factor=3.0, window=10, on_straggler=lambda s, dt, med: hits.append(s))
    for i in range(10):
        mon.observe(0.1, i)
    mon.observe(1.0, 10)  # 10x median -> straggler
    assert mon.count == 1 and hits == [10]
    mon.observe(0.1, 11)
    assert mon.count == 1


def test_grad_accum_matches_flat_batch(tiny_olmo):
    """accum_steps=2 over half-batches == one step over the full batch."""
    from repro.optim import make_optimizer
    from repro.runtime.train_loop import make_train_step

    model = tiny_olmo
    data = for_model(model.cfg, seq_len=16, global_batch=4)
    batch = data.batch_at(0)
    opt = make_optimizer("adamw")
    sched = lambda step: 1e-3

    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    flat_step = jax.jit(make_train_step(model, opt, sched, accum_steps=1))
    p1, _, m1 = flat_step(params, state, batch, 0)

    micro = jax.tree_util.tree_map(lambda x: x.reshape(2, 2, *x.shape[1:]), batch)
    acc_step = jax.jit(make_train_step(model, opt, sched, accum_steps=2))
    p2, _, m2 = acc_step(params, state, micro, 0)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


# -- elastic -------------------------------------------------------------------
def test_plan_mesh_shrinks_model_axis():
    assert elastic.plan_mesh(8, prev_model=4) == (2, 4)
    assert elastic.plan_mesh(6, prev_model=4) == (3, 2)
    assert elastic.plan_mesh(5, prev_model=4) == (5, 1)


def test_fit_batch():
    assert elastic.fit_batch(256, 16) == 256
    assert elastic.fit_batch(250, 16) == 240
    assert elastic.fit_batch(7, 8) == 0


def test_reshard_to_smaller_mesh(tiny_olmo):
    """Live params keep their values across a re-mesh (1-device degenerate)."""
    from repro.launch.mesh import logical_rules

    model = tiny_olmo
    params = model.init(jax.random.PRNGKey(0))
    devs = jax.devices()
    data, mdl = elastic.plan_mesh(len(devs), prev_model=1)
    mesh = elastic.remesh(devs, data, mdl)
    rules = logical_rules(model.cfg, mesh)
    moved = elastic.reshard(params, rules, model.param_specs(), mesh)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- serving -------------------------------------------------------------------
def test_slot_server_batched_equals_solo(tiny_olmo):
    model = tiny_olmo
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(5)
    reqs = []
    for uid in range(5):
        k = jax.random.fold_in(key, uid)
        plen = int(jax.random.randint(k, (), 3, 9))
        prompt = jax.random.randint(jax.random.fold_in(k, 1), (plen,), 0, model.cfg.vocab_size)
        reqs.append(Request(uid=uid, prompt=prompt.astype(jnp.int32), max_new_tokens=4))

    batched = SlotServer(model, n_slots=3, max_len=32)
    batched.load(params)
    for r in reqs:
        batched.submit(r)
    got = {c.uid: c.tokens for c in batched.run()}
    assert set(got) == {r.uid for r in reqs}

    for r in reqs:
        solo = SlotServer(model, n_slots=1, max_len=32)
        solo.load(params)
        solo.submit(r)
        ref = solo.run()[0]
        assert got[r.uid] == ref.tokens, f"uid={r.uid}"


def test_slot_server_respects_budget(tiny_olmo):
    model = tiny_olmo
    params = model.init(jax.random.PRNGKey(0))
    s = SlotServer(model, n_slots=2, max_len=32)
    s.load(params)
    prompt = jnp.arange(4, dtype=jnp.int32)
    s.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = s.run()
    assert len(done) == 1 and len(done[0].tokens) == 6
