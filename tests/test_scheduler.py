"""Dynamic fleet scheduler suite: pull dispatch, speculative straggler
re-dispatch, auto-calibrated shard weights, and cost-model persistence.

Contract pillars, mirroring the scheduler's claims:

  1. *Schedule-invariance* — dynamic (pull-based) execution produces report
     rows byte-for-bit identical to sequential execution, for thread and
     process local sinks and for a skewed-capacity remote fleet
     (deterministic plugin tasks make equality exact).
  2. *Straggler tolerance* — with one sink wedged on a single unit, the
     sweep re-dispatches a speculative copy to an idle sink and finishes in
     bounded time; the first completion wins and the loser is discarded.
  3. *Calibration* — ``@auto`` shard weights resolved from worker-ping
     throughput EWMAs converge toward a synthetic 4:1 speed skew, and the
     ``costs.json`` EWMA sidecar keeps feeding CostModel after every raw
     cache entry has been evicted.

Scheduler-level tests drive controllable-latency fake sinks (a
:class:`Sink` is just a name, capacity, and callable), so no timing
assertion depends on real task execution speed.
"""
from __future__ import annotations

import json
import threading
import time

import pytest
from test_shard import make_plugin, plugin_box

from repro.core import (
    CostModel,
    ResultCache,
    ShardSpec,
    SweepExecutor,
    merge_shard_reports,
    resolve_auto_weights,
)
from repro.core import registry as reg
from repro.core import runner as runner_mod
from repro.core.platform import get_platform
from repro.core.report import to_csv
from repro.core.scheduler import FleetScheduler, Sink, WorkItem
from repro.core.shard import AUTO_WEIGHTS


# -- fake-sink helpers -------------------------------------------------------
def _fast_sink(name: str, capacity: int = 1, latency: float = 0.005, log=None):
    def run(unit):
        if log is not None:
            log.append((name, unit))
        time.sleep(latency)
        return (f"{name}:{unit}", False)

    return Sink(name, capacity, run)


# -- pull dispatch basics ----------------------------------------------------
def test_scheduler_outcomes_in_input_order_all_complete():
    log: list = []
    sinks = [_fast_sink("A", 1, log=log), _fast_sink("B", 2, log=log)]
    items = [WorkItem(f"u{i}", cost=float(8 - i)) for i in range(8)]
    outcomes = FleetScheduler(sinks).run(items)
    assert [oc.item.unit for oc in outcomes] == [f"u{i}" for i in range(8)]
    assert all(oc.error is None and oc.result is not None for oc in outcomes)
    assert all(oc.attempts == 1 and not oc.speculated for oc in outcomes)
    assert len(log) == 8  # no unit executed twice
    assert {u for _, u in log} == {f"u{i}" for i in range(8)}


def test_scheduler_respects_sink_eligibility():
    log: list = []
    sinks = [_fast_sink("A", 2, log=log), _fast_sink("B", 2, log=log)]
    items = [WorkItem(f"a{i}", sinks=(0,)) for i in range(3)]
    items += [WorkItem(f"b{i}", sinks=(1,)) for i in range(3)]
    outcomes = FleetScheduler(sinks).run(items)
    assert all(oc.error is None for oc in outcomes)
    ran_on = {u: n for n, u in log}
    assert all(ran_on[f"a{i}"] == "A" for i in range(3))
    assert all(ran_on[f"b{i}"] == "B" for i in range(3))
    with pytest.raises(ValueError, match="no eligible sink"):
        FleetScheduler(sinks).run([WorkItem("x", sinks=())])
    with pytest.raises(ValueError, match="unknown sink"):
        FleetScheduler(sinks).run([WorkItem("x", sinks=(7,))])


def test_scheduler_records_errors_per_unit():
    def run(unit):
        if unit == "bad":
            raise RuntimeError("kaput")
        return (f"ok:{unit}", False)

    sinks = [Sink("A", 2, run)]
    outcomes = FleetScheduler(sinks).run([WorkItem("bad"), WorkItem("good")])
    by_unit = {oc.item.unit: oc for oc in outcomes}
    assert "kaput" in str(by_unit["bad"].error)
    assert by_unit["good"].error is None and by_unit["good"].result == "ok:good"


def test_scheduler_fail_fast_stops_early():
    started: list = []

    def run(unit):
        started.append(unit)
        if unit == "bad":
            raise RuntimeError("kaput")
        time.sleep(0.01)
        return (unit, False)

    # One slot: "bad" (heaviest) goes first; fail_fast must stop the rest.
    items = [WorkItem("bad", cost=10.0)] + [WorkItem(f"u{i}", cost=1.0) for i in range(20)]
    outcomes = FleetScheduler([Sink("A", 1, run)], fail_fast=True).run(items)
    assert outcomes[0].error is not None
    assert len(started) < len(items)  # the tail was never claimed


# -- speculative straggler re-dispatch ---------------------------------------
def test_straggler_redispatched_to_idle_sink():
    """Acceptance: one sink wedged on a single unit; the sweep finishes in
    bounded time (vs. the 15s the wedge would block), the speculative copy
    wins, and the loser is discarded."""
    stall = threading.Event()
    attempts: dict = {}
    lock = threading.Lock()
    log: list = []

    def make(name):
        def run(unit):
            with lock:
                n = attempts[unit] = attempts.get(unit, 0) + 1
                log.append((name, unit, n))
            if unit == "slow" and n == 1:
                stall.wait(timeout=15.0)  # first attempt wedges
                return (f"{name}:slow:hung", False)
            time.sleep(0.01)
            return (f"{name}:{unit}", False)

        return run

    sinks = [Sink("A", 1, make("A")), Sink("B", 1, make("B"))]
    sched = FleetScheduler(
        sinks, straggler_factor=2.0, min_straggler_s=0.05, poll_s=0.02
    )
    # "slow" is heaviest, so it is claimed first and wedges one sink while
    # the other drains the queue — the exact tail-blocking scenario.
    items = [WorkItem("slow", cost=5.0)] + [WorkItem(f"u{i}", cost=1.0) for i in range(8)]
    t0 = time.monotonic()
    try:
        outcomes = sched.run(items)
    finally:
        stall.set()  # release the wedged thread
    wall = time.monotonic() - t0
    by_unit = {oc.item.unit: oc for oc in outcomes}
    slow = by_unit["slow"]
    assert slow.error is None
    assert not slow.result.endswith(":hung")  # the speculative copy won
    assert slow.speculated and slow.attempts == 2
    assert attempts["slow"] == 2  # exactly one speculative copy
    first_sink = next(n for n, u, a in log if u == "slow" and a == 1)
    second_sink = next(n for n, u, a in log if u == "slow" and a == 2)
    assert second_sink != first_sink  # re-dispatched to the OTHER (idle) sink
    assert all(oc.result is not None for oc in outcomes)
    assert wall < 5.0  # finished without waiting on the wedged attempt


def test_no_speculation_on_a_healthy_fleet():
    sinks = [_fast_sink("A", 2), _fast_sink("B", 2)]
    outcomes = FleetScheduler(sinks).run([WorkItem(f"u{i}") for i in range(10)])
    assert all(not oc.speculated and oc.attempts == 1 for oc in outcomes)


def test_errored_unit_hands_off_to_remaining_sinks():
    """A crashed fleet worker fast-fails its claims; every unit it errored
    must be retried on the healthy sink before any error is terminal."""

    def dead(unit):
        raise RuntimeError("connection refused")

    log: list = []
    sinks = [Sink("dead", 2, dead), _fast_sink("ok", 1, log=log)]
    outcomes = FleetScheduler(sinks).run([WorkItem(f"u{i}") for i in range(10)])
    assert all(oc.error is None for oc in outcomes)  # nothing terminal-errored
    assert {u for _, u in log} == {f"u{i}" for i in range(10)}
    assert any(oc.attempts == 2 for oc in outcomes)  # dead sink did claim some
    # When EVERY eligible sink has failed the unit, the error is terminal.
    only_dead = FleetScheduler([Sink("dead", 1, dead)]).run([WorkItem("x")])
    assert "connection refused" in str(only_dead[0].error)


def test_cache_hits_do_not_calibrate_straggler_scale():
    """Warm-cache completions return in microseconds; feeding them into the
    seconds-per-cost scale would flag every real unit as a straggler."""

    def run(unit):
        if unit == "real":
            time.sleep(0.4)  # >> min_straggler_s: would be speculated if the
            return ("real", False)  # hits had collapsed the scale
        return (f"hit:{unit}", True)

    sinks = [Sink("A", 1, run), Sink("B", 1, run)]
    sched = FleetScheduler(sinks, straggler_factor=2.0, min_straggler_s=0.05, poll_s=0.02)
    items = [WorkItem("real", cost=1.0)] + [WorkItem(f"h{i}", cost=1.0) for i in range(8)]
    outcomes = sched.run(items)
    by_unit = {oc.item.unit: oc for oc in outcomes}
    assert by_unit["real"].error is None
    assert not by_unit["real"].speculated  # hits alone calibrated nothing
    assert by_unit["real"].attempts == 1
    assert by_unit["real"].elapsed_s > 0.3  # winner wall time is reported


# -- executor integration: schedule invariance -------------------------------
def test_dynamic_rows_byte_identical_to_sequential(tmp_path):
    make_plugin(tmp_path, "dynplug")
    reg.load_plugin_dir(tmp_path / "dynplug")
    box = plugin_box("dynplug")
    seq = SweepExecutor(workers=1).run_box(box)
    dyn = SweepExecutor(workers=4, schedule="dynamic").run_box(box)
    assert not dyn.errors and dyn.stats.total == 6
    assert dyn.rows == seq.rows
    assert to_csv(dyn.rows) == to_csv(seq.rows)  # byte-for-bit
    assert dyn.stats.speculated == 0
    static = SweepExecutor(workers=4, schedule="static").run_box(box)
    assert static.rows == seq.rows  # the fallback path is preserved


def test_dynamic_process_pool_rows_identical(tmp_path):
    make_plugin(tmp_path, "dynproc")
    reg.load_plugin_dir(tmp_path / "dynproc")
    box = plugin_box("dynproc")
    seq = SweepExecutor(workers=1).run_box(box)
    path = tmp_path / "cache.json"
    dyn = SweepExecutor(workers=2, pool="process", cache=ResultCache(path)).run_box(box)
    assert not dyn.errors
    assert dyn.rows == seq.rows
    # The dynamic process sink records elapsed_s scheduling evidence too.
    entries = ResultCache(path).snapshot()
    assert len(entries) == 6
    assert all(e.get("elapsed_s", 0) > 0 for e in entries.values())


def test_dynamic_remote_fleet_rows_identical(tmp_path):
    from repro.core.remote import WorkerServer

    make_plugin(tmp_path, "fleetplug")
    d = tmp_path / "fleetplug"
    reg.load_plugin_dir(d)
    box = plugin_box("fleetplug")
    seq = SweepExecutor(workers=1).run_box(box)
    a, b = WorkerServer(capacity=1), WorkerServer(capacity=4)
    a.serve_in_thread()
    b.serve_in_thread()
    try:
        fleet = f"{a.endpoint},{b.endpoint}"
        rem = SweepExecutor(workers=2, remote=fleet).run_box(box)
        assert not rem.errors
        assert rem.rows == seq.rows
        ta, tb = a.throughput(), b.throughput()
        assert ta["units"] + tb["units"] == 6  # every unit ran exactly once
        # Workers advertise their measured EWMA for @auto calibration.
        done = [t for t in (ta, tb) if t["units"]]
        assert all(t["ewma_s"] and t["ewma_s"] > 0 for t in done)
    finally:
        a.shutdown()
        a.server_close()
        b.shutdown()
        b.server_close()


# -- @auto weight calibration ------------------------------------------------
def test_shard_spec_auto_parse_and_resolve():
    s = ShardSpec.parse("0/2@auto")
    assert s.is_auto and s.weights == AUTO_WEIGHTS
    assert str(s) == "0/2@auto"
    assert ShardSpec.parse(str(s)) == s
    with pytest.raises(ValueError, match="unresolved"):
        _ = s.weight
    concrete = s.resolved((0.25, 0.75))
    assert not concrete.is_auto and concrete.weights == (0.25, 0.75)
    with pytest.raises(ValueError):
        ShardSpec(0, 2, "automatic")  # only the exact sentinel is accepted
    from repro.core.shard import shard_of

    with pytest.raises(ValueError, match="unresolved"):
        shard_of("k", 2, AUTO_WEIGHTS)


def test_auto_weights_converge_toward_throughput_skew():
    """Acceptance (c): a 4:1 synthetic throughput skew resolves to ~4:1
    weights, within the determinism-lattice quantization."""
    w = resolve_auto_weights(
        2, [{"capacity": 1, "ewma_s": 1.0}, {"capacity": 1, "ewma_s": 0.25}]
    )
    assert sum(w) == pytest.approx(1.0)
    assert w[1] / w[0] == pytest.approx(4.0, rel=0.15)
    # Capacity-only skew (fresh workers, no measurements yet).
    w = resolve_auto_weights(2, [{"capacity": 1}, {"capacity": 4}])
    assert w[1] / w[0] == pytest.approx(4.0, rel=0.15)
    # Worker-side EWMA converges onto the true per-unit time, so the
    # resolved ratio approaches 4:1 as observations accumulate.
    from repro.core.remote import WorkerServer

    a, b = WorkerServer(), WorkerServer()
    try:
        for _ in range(40):
            a._observe("t", 1.0)
            b._observe("t", 0.25)
        ewma_a, ewma_b = a.throughput()["ewma_s"], b.throughput()["ewma_s"]
        assert ewma_a == pytest.approx(1.0, rel=0.05)
        assert ewma_b == pytest.approx(0.25, rel=0.05)
        w = resolve_auto_weights(
            2,
            [{"capacity": 1, "ewma_s": ewma_a}, {"capacity": 1, "ewma_s": ewma_b}],
        )
        assert w[1] / w[0] == pytest.approx(4.0, rel=0.15)
    finally:
        a.server_close()
        b.server_close()
    # Quantization absorbs EWMA jitter: two near-identical resolutions
    # produce the exact same vector (partition agreement across runners).
    w1 = resolve_auto_weights(2, [{"ewma_s": 1.0}, {"ewma_s": 0.2504}])
    w2 = resolve_auto_weights(2, [{"ewma_s": 1.001}, {"ewma_s": 0.25}])
    assert w1 == w2
    # No evidence at all degrades to uniform.
    assert resolve_auto_weights(3) == pytest.approx((1 / 3,) * 3)


def test_auto_shard_fleet_union_matches_full(tmp_path):
    """Two runners sharding ``@auto`` against the same quiescent fleet
    resolve identical weight vectors, so their union covers the grid and
    the merged report is byte-identical to the full run."""
    from repro.core.remote import WorkerServer

    make_plugin(tmp_path, "autoplug")
    reg.load_plugin_dir(tmp_path / "autoplug")
    box = plugin_box("autoplug")
    path = tmp_path / "cache.json"
    a, b = WorkerServer(capacity=1), WorkerServer(capacity=4)
    a.serve_in_thread()
    b.serve_in_thread()
    try:
        fleet = f"{a.endpoint},{b.endpoint}"
        # Seed run executes on the fleet and fills the shared cache, so the
        # shard runs below are fully cached (workers quiescent between the
        # two runners' @auto resolutions — the documented requirement).
        full = SweepExecutor(workers=2, remote=fleet, cache=ResultCache(path)).run_box(box)
        assert not full.errors
        shards = [
            SweepExecutor(workers=2, remote=fleet, cache=ResultCache(path)).run_box(
                box, shard=ShardSpec.parse(f"{i}/2@auto")
            )
            for i in range(2)
        ]
        assert all(not s.errors for s in shards)
        assert sum(s.stats.total for s in shards) == full.stats.total == 6
        assert all(s.stats.cached == s.stats.total for s in shards)
        merged = merge_shard_reports([s.rows for s in shards], box=box)
        assert merged == full.rows
        # The capacity skew actually moved work: the fat worker got more.
        weights = SweepExecutor(workers=2, remote=fleet, cache=ResultCache(path))._auto_weights(2)
        assert weights[1] > weights[0]
    finally:
        a.shutdown()
        a.server_close()
        b.shutdown()
        b.server_close()


# -- cost-model persistence (EWMA sidecar) -----------------------------------
def test_ewma_sidecar_survives_cache_eviction(tmp_path):
    make_plugin(tmp_path, "evplug")
    reg.load_plugin_dir(tmp_path / "evplug")
    box = plugin_box("evplug")
    path = tmp_path / "cache.json"
    res = SweepExecutor(cache=ResultCache(path, max_entries=0)).run_box(box)
    assert not res.errors
    assert len(ResultCache(path)) == 0  # every raw entry was evicted...
    assert (tmp_path / "costs.json").exists()  # ...but the evidence persists
    model = CostModel(ResultCache(path))
    assert model.measured_points == 0
    cost, src = model.explain("unseen", task="evplug", platform=get_platform("default"))
    assert src == "ewma" and cost > 0
    assert model.mean_elapsed_s and model.mean_elapsed_s > 0
    # clear() erases results, never the scheduling evidence.
    c2 = ResultCache(path)
    c2.clear()
    assert (tmp_path / "costs.json").exists()
    assert CostModel(ResultCache(path)).explain(
        "unseen", task="evplug", platform=get_platform("default")
    )[1] == "ewma"


def test_sidecar_roundtrip_and_validation(tmp_path):
    from repro.core.cache import EwmaCostStore

    store = EwmaCostStore(tmp_path / "costs.json", alpha=0.5)
    store.observe("t", "p", 1.0)
    store.observe("t", "p", 3.0)  # 0.5*3 + 0.5*1
    store.observe("t", "", 2.0)  # empty platform is still keyed
    store.observe("", "p", 9.0)  # no task: ignored
    store.observe("t", "p", -1.0)  # non-positive: ignored
    store.observe("t", "p", "nan")  # junk: ignored
    assert store.get("t", "p") == pytest.approx(2.0)
    store.flush()
    again = EwmaCostStore(tmp_path / "costs.json", alpha=0.5)
    assert again.get("t", "p") == pytest.approx(2.0)
    assert len(again) == 2
    # Corrupt sidecars are ignored, not fatal.
    (tmp_path / "costs.json").write_text("{ nope")
    assert len(EwmaCostStore(tmp_path / "costs.json")) == 0
    with pytest.raises(ValueError):
        EwmaCostStore(tmp_path / "c.json", alpha=0.0)


# -- satellite: concurrent/crash-safe cache flush ----------------------------
def test_concurrent_flushes_never_corrupt_cache_file(tmp_path):
    """Several writers flushing the same path + a racing reader: every
    observable file state must parse (unique temp file + os.replace)."""
    path = tmp_path / "c.json"
    caches = [ResultCache(path) for _ in range(3)]
    stop = threading.Event()
    corrupt: list = []

    def reader():
        while not stop.is_set():
            if path.exists():
                try:
                    json.loads(path.read_text())
                except json.JSONDecodeError as e:  # pragma: no cover - failure
                    corrupt.append(str(e))

    def hammer(c, i):
        for k in range(30):
            c.put(f"k{i}:{k}", {"m": float(k)}, task="t", platform="p", elapsed_s=0.01)
            c.flush()

    rt = threading.Thread(target=reader)
    rt.start()
    writers = [threading.Thread(target=hammer, args=(c, i)) for i, c in enumerate(caches)]
    for t in writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    rt.join()
    assert not corrupt
    assert json.loads(path.read_text())["entries"]  # final state is valid
    assert not list(tmp_path.glob("*.tmp"))  # no temp litter left behind


# -- satellite: wait_ready connection-refused vs error payload ----------------
def test_wait_ready_fast_fails_on_error_payload():
    from repro.core.remote import RemoteExecutionError, WorkerServer, wait_ready

    class _Broken(WorkerServer):
        def dispatch(self, req):
            return {"ok": False, "error": "plugin exploded on load"}

    server = _Broken()
    server.serve_in_thread()
    try:
        t0 = time.monotonic()
        with pytest.raises(RemoteExecutionError, match="plugin exploded"):
            wait_ready(server.endpoint, timeout=30.0)
        assert time.monotonic() - t0 < 5.0  # fail fast, not the full timeout
    finally:
        server.shutdown()
        server.server_close()


def test_wait_ready_keeps_polling_when_unreachable():
    from repro.core.remote import wait_ready

    t0 = time.monotonic()
    assert wait_ready("127.0.0.1:9", timeout=0.4) is False
    assert time.monotonic() - t0 < 10.0


# -- CLI ---------------------------------------------------------------------
def test_runner_cli_dynamic_matches_static(tmp_path):
    d = make_plugin(tmp_path, "dyncli")
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "dyncli_box",
                "tasks": [{"task": "dyncli", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
            }
        )
    )
    common = ["--box", str(bf), "--plugin-dir", str(d), "--iters", "1", "--warmup", "0"]
    out_dyn, out_static = tmp_path / "dyn.csv", tmp_path / "static.csv"
    rc = runner_mod.main(
        [*common, "--workers", "4", "--schedule", "dynamic",
         "--straggler-factor", "8", "--out", str(out_dyn)]
    )
    assert rc == 0
    rc = runner_mod.main(
        [*common, "--workers", "4", "--schedule", "static", "--out", str(out_static)]
    )
    assert rc == 0
    assert out_dyn.read_text() == out_static.read_text()
